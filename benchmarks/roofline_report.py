"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits one row per (arch x shape x mesh x tag): the three roofline terms,
the dominant bottleneck, and the useful-flop ratio.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_reports():
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as fh:
            out.append(json.load(fh))
    return out


def run() -> list[str]:
    rows: list[str] = []
    reports = load_reports()
    if not reports:
        return ["roofline/none,0,run `python -m repro.launch.dryrun --all` first"]
    for r in reports:
        dominant = {"compute": r["compute_s"], "memory": r["memory_s"],
                    "collective": r["collective_s"]}
        total = max(dominant.values())
        rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('tag','baseline')},0,"
            f"variant={r['variant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
            f"bottleneck={r['bottleneck']};useful_flop_ratio={r['useful_flop_ratio']:.3f};"
            f"dominant_s={total:.3g}"
        )
    return rows


def markdown_table(tag: str = "baseline", mesh: str = "16x16") -> str:
    """Render §Roofline markdown for EXPERIMENTS.md."""
    reports = [r for r in load_reports() if r.get("tag") == tag and r["mesh"] == mesh]
    lines = [
        "| arch | shape | variant | compute (s) | memory (s) | collective (s) "
        "| bottleneck | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(reports, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} | **{r['bottleneck']}** "
            f"| {r['useful_flop_ratio']:.3f} |"
        )
    return "\n".join(lines)
