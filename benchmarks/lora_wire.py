"""Parameter-efficient uplink: wire bytes + encode throughput vs LoRA
rank, against the fp32 and nf4 baselines (ISSUE 8 tentpole).

A ≥1M-param synthetic model (4 x 512x512 fp32 matrices) is encoded
through ``lora:r`` stacks and the baselines; rows report the uplink
payload bytes each variant actually frames and the encode rate. The
``run()`` asserts the headline acceptance claim — ``lora:8`` ships
>=20x fewer payload bytes than dense fp32 — so a violation fails the
nightly suite, not just a diff.

The metered rows (``peak_bytes``/``copied``) are deterministic
byte-accounting via MemoryMeter — a full streamed transfer per variant,
plus the streaming low-rank fold (4 clients into one
LoRAFedAvgAggregator) whose server peak stays factor-sized while the
dense model is 4 MB. Wall-clock on the SVD-bound rows is reported as a
derived key only (``us=0.0``): CPU SVD timing is too noisy to gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import pipeline as pl
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.fl.aggregator import LoRAFedAvgAggregator
from repro.utils.mem import MemoryMeter

DIM = 512
TENSORS = 4
CLIENTS = 4

VARIANTS = {
    "fp32": [],
    "nf4": ["quantize:nf4"],
    "lora4": ["lora:4"],
    "lora8": ["lora:8"],
    "lora16": ["lora:16"],
}


def model_dict():
    rng = np.random.default_rng(0)
    return {f"layers.{i}.w": rng.standard_normal((DIM, DIM)).astype(np.float32)
            for i in range(TENSORS)}


def _encode_bytes(stack, sd):
    """One full encode: (payload_bytes, items, elapsed_s). Payload bytes
    exclude the meta item so the ratio is about tensors, not headers."""
    p = pl.build_pipeline(list(stack))
    msg, ctx = p.begin_encode(
        Message(MessageKind.TASK_RESULT, dict(sd), {"num_samples": 1}))
    t0 = time.perf_counter()
    blobs = [(n, len(b)) for n, b in p.iter_encode(msg, ctx)]
    dt = time.perf_counter() - t0
    payload = sum(nb for n, nb in blobs[1:])
    return payload, len(blobs) - 1, dt


def _metered_transfer(stack, sd):
    """Container-streamed transfer over loopback; returns the meter."""
    p = pl.build_pipeline(list(stack), decode_values=False)
    meter = MemoryMeter()
    with meter.activate():
        msg = Message(MessageKind.TASK_RESULT, dict(sd), {"num_samples": 1})
        enc, ctx = p.begin_encode(msg)
        dec = p.decoder()
        recv = sm.ContainerReceiver(consume=lambda n, v: None,
                                    decode_item=dec.decode_item)
        driver = sm.LoopbackDriver()
        driver.connect(recv.on_chunk)
        sm.ContainerStreamer(driver, 1 << 16).send_items(
            p.iter_encode_views(enc, ctx), p.n_items(enc))
        dec.finish(msg.kind, p.unsent_headers(enc))
    return meter


def _fold_peak(sd):
    """CLIENTS lora:8 uplinks streamed into one aggregator; the server
    peak (transmission holds + factor state) via MemoryMeter."""
    agg = LoRAFedAvgAggregator()
    meter = MemoryMeter()
    with meter.activate():
        for i in range(CLIENTS):
            p = pl.build_pipeline(["lora:8"], decode_values=False)
            msg = Message(MessageKind.TASK_RESULT, dict(sd),
                          {"num_samples": 1, "client": f"site-{i}"})
            enc, ctx = p.begin_encode(msg)
            dec = p.decoder(sink=agg)
            recv = sm.ContainerReceiver(consume=dec.on_item,
                                        decode_item=dec.decode_item)
            driver = sm.LoopbackDriver()
            driver.connect(recv.on_chunk)
            sm.ContainerStreamer(driver, 1 << 16).send_items(
                p.iter_encode_views(enc, ctx), p.n_items(enc))
            dec.finish(msg.kind, p.unsent_headers(enc))
    agg.finish()
    return meter.peak


def run() -> list[str]:
    sd = model_dict()
    model_bytes = sum(v.nbytes for v in sd.values())
    n_params = sum(v.size for v in sd.values())
    rows = []
    payload_bytes = {}
    for name, stack in VARIANTS.items():
        payload, items, dt = _encode_bytes(stack, sd)
        payload_bytes[name] = payload
        rows.append(
            f"lora/bytes/{name},0.0,wire_payload_bytes={payload};"
            f"fp32_over={model_bytes / payload:.1f}x;"
            f"enc_items_per_s={items / dt:.0f};enc_ms={dt * 1e3:.1f};"
            f"n_params={n_params}"
        )
    reduction = model_bytes / payload_bytes["lora8"]
    ok = reduction >= 20.0
    rows.append(
        f"lora/reduction,0.0,fp32_over_lora8={reduction:.1f}x;"
        f"nf4_over_lora8={payload_bytes['nf4'] / payload_bytes['lora8']:.1f}x;"
        f"target=20x;ok={ok}"
    )
    if not ok:
        raise AssertionError(
            f"lora:8 uplink reduction {reduction:.1f}x < 20x acceptance floor"
        )
    for name in ("nf4", "lora8"):
        meter = _metered_transfer(VARIANTS[name], sd)
        rows.append(
            f"lora/transfer/{name},0.0,peak_bytes={meter.peak};"
            f"copied={meter.copied};model_bytes={model_bytes}"
        )
    fold_peak = _fold_peak(sd)
    rows.append(
        f"lora/fold/c{CLIENTS},0.0,peak_bytes={fold_peak};"
        f"model_bytes={model_bytes};clients={CLIENTS};"
        f"peak_over_model={fold_peak / model_bytes:.3f}"
    )
    return rows
