"""Heterogeneous-fleet scenario benchmark: tiered/async policies +
link-aware adaptive quantization over a mixed fiber-to-3G federation.

Eight clients spread across the canonical WAN classes run through
SyncPolicy, FedAsync, and TiFL-style tiered selection, with churn from a
seeded random availability trace. Sync and FedAsync share one
client-task budget (ROUNDS * NUM_CLIENTS); tiered runs 2*ROUNDS
one-tier rounds, so its rows trade fewer total tasks for more frequent
model updates — compare completions, not just makespan. Messages
cross the real streaming transport behind an
:class:`~repro.core.filters.AdaptiveQuantizeFilter` bound to the
runtime's per-client link model — so the fiber client ships fp32/fp16
while the 3G client ships NF4, and the per-client rows below show the
precision the *network* chose, not a config constant.

Emits ``name,us_per_call,derived`` rows (harness contract):
us_per_call = simulated microseconds per global model update for policy
rows, per completed client task for per-client rows.
"""
from __future__ import annotations

from collections.abc import Iterator

import numpy as np

from repro.core.filters import (
    AdaptiveQuantizeFilter,
    DequantizeFilter,
    FilterChain,
    FilterPoint,
    no_filters,
)
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import (
    EventKind,
    FedAsyncPolicy,
    TieredPolicy,
    RuntimeConfig,
    heterogeneous_network,
    random_availability,
)

NUM_CLIENTS = 8
ROUNDS = 4                      # sync rounds; fedasync gets the same task budget
DIM = 32 * 1024                 # 128 KiB of fp32 weights per message
BUDGET_S = 0.05                 # per-message transfer budget for adaptive precision
TIERS = ("fiber", "cable", "wifi", "lte", "dsl", "3g")


def _executors(w_true: np.ndarray) -> list[TrainExecutor]:
    def make(name: str, seed: int) -> TrainExecutor:
        rng = np.random.default_rng(seed)
        direction = rng.standard_normal(w_true.size).astype(np.float32)
        direction /= np.linalg.norm(direction)

        def train_fn(params, rnd):
            w = np.asarray(params["w"], np.float32)
            w = w + 0.5 * (w_true - w) + 0.01 * direction
            return {"w": w}, 32, {}

        return TrainExecutor(name, train_fn)

    return [make(f"site-{i}", i) for i in range(NUM_CLIENTS)]


def _adaptive_filters(network) -> tuple[dict, dict, AdaptiveQuantizeFilter]:
    filt = AdaptiveQuantizeFilter.from_network(network, budget_s=BUDGET_S)
    server = no_filters()
    server[FilterPoint.TASK_DATA_OUT] = FilterChain([filt])
    server[FilterPoint.TASK_RESULT_IN] = FilterChain([DequantizeFilter()])
    client = no_filters()
    client[FilterPoint.TASK_DATA_IN] = FilterChain([DequantizeFilter()])
    client[FilterPoint.TASK_RESULT_OUT] = FilterChain([filt])
    return server, client, filt


def _run(mode: str):
    names = [f"site-{i}" for i in range(NUM_CLIENTS)]
    network = heterogeneous_network(names, seed=7, tiers=TIERS,
                                    compute_base_s=0.5, compute_spread=6.0)
    server_f, client_f, filt = _adaptive_filters(network)
    w_true = np.linspace(-1.0, 1.0, DIM).astype(np.float32)
    policy = None
    if mode == "fedasync":
        policy = FedAsyncPolicy(total_tasks=ROUNDS * NUM_CLIENTS, mixing_rate=0.6)
    elif mode == "tiered":
        policy = TieredPolicy(FedAvgAggregator(), num_rounds=ROUNDS * 2,
                              num_tiers=3, network=network, seed=7)
    sim = FLSimulator(
        _executors(w_true),
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=server_f,
        client_filters=client_f,
        runtime=RuntimeConfig(seed=11, max_concurrency=NUM_CLIENTS),
        policy=policy,
        network=network,
        availability=random_availability(names, mean_online_s=120.0,
                                         mean_offline_s=30.0, horizon_s=600.0, seed=7),
    )
    sim.run({"w": np.zeros(DIM, np.float32)})
    return sim, network, filt


def run() -> Iterator[str]:
    for mode in ("sync", "fedasync", "tiered"):
        sim, network, filt = _run(mode)
        s = sim.scheduler.stats
        updates = max(1, s.model_updates)
        yield (
            f"hetero_fleet_{mode},{sim.sim_time_s * 1e6 / updates:.0f},"
            f"makespan_s={sim.sim_time_s:.2f};updates={updates};"
            f"completions={s.completions};deferrals={s.deferrals};"
            f"interruptions={s.interruptions};wire_mb={sim.stats.bytes_sent / 1e6:.2f}"
        )
        if mode != "fedasync":
            continue
        # per-client rows for the async run: the link each client sits on
        # and the precision the adaptive filter picked for that link
        completions = [e for e in sim.scheduler.timeline if e.kind is EventKind.COMPLETION]
        for i in range(NUM_CLIENTS):
            client = f"site-{i}"
            done = [e for e in completions if e.client == client]
            per_task_us = (sim.sim_time_s * 1e6 / len(done)) if done else 0.0
            link = network.link(client)
            fmt = filt.last_fmt_by_client.get(client, "n/a")
            yield (
                f"hetero_fleet_client_{client},{per_task_us:.0f},"
                f"link={link.name};bw_mbps={link.bandwidth_mbps:g};"
                f"fmt={fmt};tasks_done={len(done)}"
            )
        fast = filt.last_fmt_by_client.get("site-0", "n/a")   # fiber
        slow = filt.last_fmt_by_client.get("site-5", "n/a")   # 3g
        yield (
            f"hetero_fleet_adaptive_split,0,"
            f"fiber_fmt={fast};3g_fmt={slow};differs={fast != slow}"
        )


if __name__ == "__main__":
    for row in run():
        print(row)
