"""Paper §V future work: per-layer quantization sensitivity.

For a real (smoke llama-family) model, measures per-tensor quantization
SNR and the end-to-end logit distortion when quantizing one tensor class
at a time — identifying which layers tolerate 4-bit and which need
higher precision. Drives SelectiveQuantizeFilter policies.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.quantization import dequantize, quantize
from repro.models import create_model
from repro.utils.trees import flatten_state_dict, unflatten_state_dict


def _tensor_class(name: str) -> str:
    for tag in ("embedding", "lm_head", "norm"):
        if tag in name:
            return tag
    if "attn" in name:
        return "attention"
    if "mlp" in name or "moe" in name:
        return "mlp"
    return "other"


def run() -> list[str]:
    cfg = get_smoke_config("llama3.2-1b").with_overrides(remat=False)
    model = create_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    flat = flatten_state_dict(params)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    base_logits, _ = model.forward(params, tokens)
    base = np.asarray(base_logits, np.float32)

    # per-class SNR + end-to-end logit distortion at nf4
    classes: dict[str, list[str]] = {}
    for name in flat:
        classes.setdefault(_tensor_class(name), []).append(name)

    rows: list[str] = []
    for cls, names in sorted(classes.items()):
        # weight-space SNR
        snrs = []
        for n in names:
            w = np.asarray(flat[n], np.float32)
            if w.size < 2:
                continue
            deq = np.asarray(dequantize(quantize(jnp.asarray(w), "nf4")), np.float32)
            err = np.mean((w - deq) ** 2)
            sig = np.mean(w**2) + 1e-12
            snrs.append(10 * np.log10(sig / (err + 1e-20)))
        # end-to-end: quantize ONLY this class
        qflat = dict(flat)
        for n in names:
            if np.asarray(flat[n]).size >= 64:
                qflat[n] = dequantize(quantize(jnp.asarray(flat[n]), "nf4"))
        qparams = unflatten_state_dict(qflat)
        qlogits, _ = model.forward(qparams, tokens)
        dist = float(np.mean(np.abs(np.asarray(qlogits, np.float32) - base)))
        rows.append(
            f"layer_sensitivity/{cls},0,nf4_weight_snr_db={np.mean(snrs):.1f};"
            f"logit_l1_distortion={dist:.4f};tensors={len(names)}"
        )
    return rows
