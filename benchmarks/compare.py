"""Nightly benchmark regression gate: diff fresh ``--json`` report(s)
against the committed baseline (``BENCH_5.json`` / ``BENCH_7.json``).

    PYTHONPATH=src python -m benchmarks.compare BENCH_5.json \
        BENCH_w1.json [BENCH_w2.json ...] [--max-regression 30] [--prefix wire/]

Rows are the harness's ``name,us_per_call,derived`` CSV. Per row, the
first applicable metric gates (one threshold, ``--max-regression``
percent): the machine-independent ``new_over_legacy`` speedup ratio
(both paths timed in the same run, so runner hardware cancels out),
then deterministic ``peak_bytes`` (metered server/wire peak — same
payload means the same peak on any machine; growth is a real code
change), then deterministic ``copied`` byte volume (must not grow),
then absolute ``items_per_s`` (must not drop), then ``us_per_call``
(must not grow) — so cross-machine baselines gate on ratios and exact
byte accounting, never on another host's absolute wall-clock.

**Multiple current reports** merge best-of per row before gating (max
of throughput ratios, min of times/copies/peaks): CI runners fluctuate
±30% between runs on the same commit (see CHANGES.md), so the nightly
runs the timing-sensitive suites best-of-3 — a regression must survive
every repetition to go red, while a genuine one still fails all three.

``*/legacy`` rows (the re-enacted pre-refactor comparison path) never
gate. A gated baseline row missing from the current report is itself a
failure — a renamed suite must come with a deliberately regenerated
baseline, not a silently disarmed gate. Regressions exit non-zero so
the nightly job goes red instead of archiving a slower wire plane.
"""
from __future__ import annotations

import argparse
import json
import sys

# merge direction for best-of-N current reports: metrics where bigger is
# better take the max across runs, cost metrics take the min
_BIGGER_IS_BETTER = ("new_over_legacy", "items_per_s")
_SMALLER_IS_BETTER = ("us_per_call", "copied", "peak_bytes")


def _parse_rows(report: dict) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for row in report.get("rows", []):
        parts = row.split(",", 2)
        if len(parts) != 3:
            continue
        name, us, derived = parts
        fields: dict[str, float] = {}
        for kv in derived.split(";"):
            k, _, v = kv.partition("=")
            try:
                fields[k] = float(v)
            except ValueError:
                pass
        try:
            fields["us_per_call"] = float(us)
        except ValueError:
            continue
        out[name] = fields
    return out


def merge_best_of(reports: list[dict]) -> dict[str, dict]:
    """Best-of merge of several current reports' rows (see module doc)."""
    merged: dict[str, dict] = {}
    for report in reports:
        for name, fields in _parse_rows(report).items():
            have = merged.setdefault(name, dict(fields))
            for k, v in fields.items():
                if k in _BIGGER_IS_BETTER:
                    have[k] = max(have.get(k, v), v)
                elif k in _SMALLER_IS_BETTER:
                    have[k] = min(have.get(k, v), v)
                else:
                    have.setdefault(k, v)
    return merged


def compare(baseline: dict, current, max_regression_pct: float,
            prefix: str) -> list[str]:
    """Returns a list of human-readable failures (empty = gate passes).

    ``current`` may be one fresh report or a list of them; multiple
    reports are best-of merged per row before gating (runner-drift
    hardening — see module doc)."""
    base_rows = _parse_rows(baseline)
    cur_rows = merge_best_of(current if isinstance(current, list) else [current])
    failures: list[str] = []
    threshold = max_regression_pct / 100.0
    for name, base in sorted(base_rows.items()):
        if prefix and not name.startswith(prefix):
            continue
        if name.endswith("/legacy"):
            # the re-enacted pre-refactor path exists for comparison
            # only; its speed is not product behavior and must not gate
            continue
        cur = cur_rows.get(name)
        if cur is None:
            # a gated row silently disappearing (suite renamed, ambient
            # compressor changed the stack label, ...) must not turn the
            # gate into a no-op — regenerate the baseline deliberately
            failures.append(
                f"{name}: baseline row missing from current report "
                "(suite changed? regenerate the committed baseline)"
            )
            continue
        if "new_over_legacy" in base and "new_over_legacy" in cur:
            # machine-independent speedup ratio (both paths measured in
            # the same run on the same host) — robust across runner
            # hardware, unlike absolute items/s
            b, c = base["new_over_legacy"], cur["new_over_legacy"]
            if b > 0 and c < b * (1.0 - threshold):
                failures.append(
                    f"{name}: new_over_legacy {c:.2f} is "
                    f"{100 * (1 - c / b):.1f}% below baseline {b:.2f}"
                )
        elif "peak_bytes" in base and "peak_bytes" in cur:
            # metered peak is deterministic for serialized folds (same
            # payload => same buffer lifecycle on any machine): growth
            # means the memory envelope actually regressed
            b, c = base["peak_bytes"], cur["peak_bytes"]
            if b > 0 and c > b * (1.0 + threshold):
                failures.append(
                    f"{name}: peak_bytes {c:.0f} is "
                    f"{100 * (c / b - 1):.1f}% above baseline {b:.0f}"
                )
        elif "copied" in base and "copied" in cur:
            # byte-copy volume is deterministic (same payload => same
            # copies on any machine): any growth is a real code change
            b, c = base["copied"], cur["copied"]
            if b > 0 and c > b * (1.0 + threshold):
                failures.append(
                    f"{name}: copied bytes {c:.0f} are "
                    f"{100 * (c / b - 1):.1f}% above baseline {b:.0f}"
                )
        elif "items_per_s" in base and "items_per_s" in cur:
            b, c = base["items_per_s"], cur["items_per_s"]
            if b > 0 and c < b * (1.0 - threshold):
                failures.append(
                    f"{name}: items_per_s {c:.0f} is "
                    f"{100 * (1 - c / b):.1f}% below baseline {b:.0f}"
                )
        elif base.get("us_per_call", 0) > 0 and cur.get("us_per_call", 0) > 0:
            b, c = base["us_per_call"], cur["us_per_call"]
            if c > b * (1.0 + threshold):
                failures.append(
                    f"{name}: us_per_call {c:.0f} is "
                    f"{100 * (c / b - 1):.1f}% above baseline {b:.0f}"
                )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed baseline JSON (BENCH_5.json)")
    ap.add_argument("current", nargs="+",
                    help="fresh --json report(s); several are best-of merged "
                         "per row before gating (runner-drift hardening)")
    ap.add_argument("--max-regression", type=float, default=30.0,
                    metavar="PCT", help="allowed throughput drop (default 30%%)")
    ap.add_argument("--prefix", default="wire/",
                    help="only gate rows with this name prefix "
                         "(default 'wire/'; pass '' for all rows)")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    currents = []
    for path in args.current:
        with open(path) as fh:
            currents.append(json.load(fh))
    failures = compare(baseline, currents, args.max_regression, args.prefix)
    if failures:
        for f in failures:
            print(f"REGRESSION {f}", file=sys.stderr)
        return 1
    print(f"# benchmark gate passed (prefix={args.prefix!r}, "
          f"{len(currents)} current report(s), "
          f"max regression {args.max_regression:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
