"""Paper Table III: peak memory and job time under regular / container /

file transmission of one global-weight message (server -> client).

The paper measured host RSS for a 5.7 GB fp32 model (42.4 / 23.3 /
19.2 GB); we transmit a scaled llama-shaped dict (embed-dominated, like
Table I) and report byte-exact transmission-buffer peaks plus wall time,
verifying the paper's mechanism and ordering:

    regular  ~ whole serialized model (sender + receiver copies)
    container~ largest single item
    file     ~ one chunk
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import serialization as ser
from repro.core import streaming as sm
from repro.utils.mem import MemoryMeter


def model_dict(d: int = 512, layers: int = 8, vocab: int = 8192):
    rng = np.random.default_rng(0)
    sd = {"embed_tokens": rng.standard_normal((vocab, d)).astype(np.float32)}
    for i in range(layers):
        sd[f"layers.{i}.attn"] = rng.standard_normal((d, d)).astype(np.float32)
        sd[f"layers.{i}.mlp"] = rng.standard_normal((4 * d, d)).astype(np.float32)
    sd["lm_head"] = rng.standard_normal((vocab, d)).astype(np.float32)
    return sd


def run() -> list[str]:
    sd = model_dict()
    total = sum(v.nbytes for v in sd.values())
    max_item = max(v.nbytes for v in sd.values())
    chunk = 1 << 20
    tmp = tempfile.mkdtemp(prefix="stream_bench_")
    src = os.path.join(tmp, "model.bin")
    with open(src, "wb") as fh:
        fh.write(ser.serialize_container(sd))

    def run_mode(mode: str):
        meter = MemoryMeter()
        t0 = time.perf_counter()
        with meter.activate():
            driver = sm.LoopbackDriver()
            if mode == "regular":
                recv = sm.BlobReceiver()
                driver.connect(recv.on_chunk)
                sm.ObjectStreamer(driver, chunk).send_container(sd)
            elif mode == "container":
                recv = sm.ContainerReceiver(consume=lambda n, v: None)
                driver.connect(recv.on_chunk)
                sm.ContainerStreamer(driver, chunk).send_container(sd)
            else:
                recv = sm.FileReceiver(os.path.join(tmp, "out.bin"))
                driver.connect(recv.on_chunk)
                sm.FileStreamer(driver, chunk).send_file(src)
        return meter.peak, (time.perf_counter() - t0) * 1e6

    rows = []
    peaks = {}
    for mode in ("regular", "container", "file"):
        peak, us = run_mode(mode)
        peaks[mode] = peak
        rows.append(
            f"table3/{mode},{us:.0f},peak_bytes={peak};model_bytes={total};"
            f"max_item_bytes={max_item};chunk_bytes={chunk}"
        )
    ok = peaks["regular"] > peaks["container"] > peaks["file"]
    rows.append(
        f"table3/ordering,0,regular>container>file={ok};"
        f"container_over_max_item={peaks['container'] / max_item:.2f};"
        f"file_over_chunk={peaks['file'] / chunk:.2f}"
    )
    return rows
