"""Paper Table II: message size under quantization precisions.

Two parts:
1. byte-model on the exact 147-tensor Llama-3.2-1B layout (Table I) —
   must reproduce the paper's MB figures and fp32 percentages;
2. measured wire bytes of an actually-quantized, serialized message (a
   1/16-width llama dict) — validates the model against real payloads.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import serialization as ser
from repro.core.filters import QuantizeFilter
from repro.core.messages import Message, MessageKind
from repro.core.quantization import message_size_report


class _Shape:
    def __init__(self, *shape):
        self.shape = shape


def llama32_1b_layout() -> dict[str, _Shape]:
    sd: dict[str, _Shape] = {
        "embed_tokens": _Shape(128256, 2048),
        "norm": _Shape(2048),
        "lm_head": _Shape(128256, 2048),
    }
    for i in range(16):
        sd[f"layers.{i}.self_attn.q_proj"] = _Shape(2048, 2048)
        sd[f"layers.{i}.self_attn.k_proj"] = _Shape(512, 2048)
        sd[f"layers.{i}.self_attn.v_proj"] = _Shape(512, 2048)
        sd[f"layers.{i}.self_attn.o_proj"] = _Shape(2048, 2048)
        sd[f"layers.{i}.mlp.gate_proj"] = _Shape(8192, 2048)
        sd[f"layers.{i}.mlp.up_proj"] = _Shape(8192, 2048)
        sd[f"layers.{i}.mlp.down_proj"] = _Shape(2048, 8192)
        sd[f"layers.{i}.input_layernorm"] = _Shape(2048)
        sd[f"layers.{i}.post_attention_layernorm"] = _Shape(2048)
    return sd


PAPER_TABLE2 = {  # fmt: (model_mb, meta_mb, pct)
    "fp32": (5716.26, 0.00, 100.00),
    "fp16": (2858.13, 0.00, 50.00),
    "blockwise8": (1429.06, 1.54, 25.03),
    "nf4": (714.53, 89.33, 14.06),
}


def small_llama_dict(scale: int = 16) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(0)
    d = 2048 // scale
    sd = {"embed_tokens": rng.standard_normal((128256 // scale, d)).astype(np.float32)}
    for i in range(2):
        sd[f"layers.{i}.q"] = rng.standard_normal((d, d)).astype(np.float32)
        sd[f"layers.{i}.mlp"] = rng.standard_normal((8192 // scale, d)).astype(np.float32)
    return sd


def run() -> list[str]:
    rows: list[str] = []
    layout = llama32_1b_layout()
    for fmt, (want_mb, want_meta, want_pct) in PAPER_TABLE2.items():
        r = message_size_report(layout, fmt)
        rows.append(
            f"table2/{fmt},0,model_mb={r['model_mb']:.2f};meta_mb={r['meta_mb']:.2f};"
            f"pct={r['fp32_pct']:.2f};paper_pct={want_pct:.2f};"
            f"pct_err={abs(r['fp32_pct'] - want_pct):.3f}"
        )
    # measured payloads
    sd = small_llama_dict()
    base = len(ser.serialize_container(sd))
    for fmt in ("fp16", "blockwise8", "fp4", "nf4"):
        t0 = time.perf_counter()
        q = QuantizeFilter(fmt).process(Message(MessageKind.TASK_DATA, dict(sd)))
        blob = ser.serialize_container(q.payload)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"table2_measured/{fmt},{us:.0f},wire_bytes={len(blob)};fp32_bytes={base};"
            f"pct={100.0 * len(blob) / base:.2f}"
        )
    return rows
