"""Quantization codec micro-benchmarks (ref backend timing on CPU;

Pallas-interpret parity asserted — the compiled Pallas path is TPU-only).
Reports us/call and achieved GB/s for each codec over a 64 MiB tensor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as Q
from repro.kernels import ops

N = 16 * 1024 * 1024  # 64 MiB fp32


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def run() -> list[str]:
    rows: list[str] = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(N), jnp.float32)
    gb = x.nbytes / 1e9

    for fmt in ("fp16", "blockwise8", "fp4", "nf4"):
        us, qt = _time(lambda: Q.quantize(x, fmt))
        rows.append(
            f"kernels/quantize_{fmt},{us:.0f},GBps={gb / (us / 1e6):.2f};"
            f"wire_bytes={qt.total_bytes}"
        )
        us_d, _ = _time(lambda: Q.dequantize(qt))
        rows.append(f"kernels/dequantize_{fmt},{us_d:.0f},GBps={gb / (us_d / 1e6):.2f}")

    # fused server aggregation vs dequant-then-average (K=4 clients)
    K, nblocks = 4, 2048
    qs = jnp.asarray(rng.integers(-127, 128, (K, nblocks, 4096)), jnp.int8)
    ams = jnp.asarray(rng.random((K, nblocks)) + 0.5, jnp.float32)
    w = jnp.full((K,), 1.0 / K, jnp.float32)
    us_f, _ = _time(lambda: ops.dequant_accumulate8(qs, ams, w))

    def unfused():
        acc = 0
        for i in range(K):
            acc = acc + w[i] * ops.dequantize_blockwise8(qs[i], ams[i], (nblocks * 4096,))
        return acc

    us_u, _ = _time(jax.jit(unfused))
    rows.append(
        f"kernels/fused_dequant_agg_k4,{us_f:.0f},unfused_us={us_u:.0f};"
        f"speedup={us_u / us_f:.2f};note=cpu-ref-donated-fold-loop--kernel-targets-TPU-MXU;"
        f"memory_win=holds-1-not-K-fp32-copies"
    )
    return rows
