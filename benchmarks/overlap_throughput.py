"""Compute/IO overlap on the wire hot path: items/s and encode-stall
time for the nf4 container stack at encode-ahead depths 0/1/2/4.

Each case streams an LLM-shaped state dict through the full quantized
pipeline (quantize:nf4 -> zlib:6 -> crc32) over a **real localhost TCP
socket** (:class:`repro.core.streaming.TCPDriver`) paced to a
broadband-class 200 Mbps uplink (the wifi/cable tier of the runtime's
own network model — the regime real FL clients upload over): stage
encode, chunk framing, ``sendmsg``, receiver-thread reassembly, stage
decode, and a streaming-fold consume. Pacing matters: an unpaced
loopback socket runs at memory speed, so the transfer is encode-bound
and there is no IO time to hide — the regime federated deployments
actually run in is a link-limited uplink, where the sender spends most
of its wall clock blocked in ``sendmsg``. That blocked time is what
encode-ahead (:func:`repro.core.streaming.iter_encode_ahead`)
overlaps: depth 0 is the classic sequential encode-then-send loop
(total = encode + wire), depth >= 1 encodes item k+1 while item k's
bytes drain (total -> max(encode, wire)).

``zlib:6`` (not the wire suite's store-mode ``zlib:0``) is deliberate:
this is the bandwidth-starved uplink config where the client pays real
compressor CPU to shave bytes — exactly the regime where encode-ahead
earns its keep, because ``zlib.compress`` releases the GIL and so the
lookahead worker squeezes item k+1 *inside* item k's link wait even on
a single-core host.

Reported per depth:

* ``items_per_s`` / ``gbps`` — decoded payload items and bytes per
  second end to end,
* ``stall_us`` — total sender stall (the ``wire.encode_wait_us``
  histogram sum: time the send loop waited for the next encoded item;
  0 at depth 0 where the loop *is* the encoder).

The ``overlap/nf4-200mbps/speedup`` row reports the best depth>=1
throughput over depth 0 measured in the same run on the same host —
machine-independent, so it feeds the nightly regression gate
(``benchmarks/compare.py`` against ``BENCH_9.json``). Wire bytes are
asserted bitwise-identical across depths (once, outside the timed
region): lookahead reorders *work*, never bytes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import pipeline as pl
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.obs import MetricsRegistry
from repro.obs import metrics as obs_metrics

CHUNK = 1 << 18
# stdlib-only on purpose (deterministic across runners); see module doc
# for why this is the compressor-bound level, not the wire suite's
# store-mode zlib:0
STACK = ["quantize:nf4", "zlib:6", "crc32"]
DEPTHS = (0, 1, 2, 4)
LINK_BPS = 2e8 / 8  # 200 Mbps broadband-class uplink, in bytes/s


class _PacedTCP(sm.TCPDriver):
    """Real TCP sends paced to ``LINK_BPS``: after each chunk hits the
    socket, sleep out the remainder of its wire time. The sleep happens
    on the sender thread with the GIL released — exactly like a
    ``sendmsg`` blocked on a full link-limited send window — so the
    encode-ahead worker keeps encoding through it."""

    def _send(self, chunk):
        t0 = time.perf_counter()
        super()._send(chunk)
        budget = chunk.nbytes / LINK_BPS
        remaining = budget - (time.perf_counter() - t0)
        if remaining > 0:
            time.sleep(remaining)


def model_dict(layers: int = 8, d: int = 256):
    rng = np.random.default_rng(0)
    sd = {}
    for i in range(layers):
        sd[f"layers.{i}.attn.w"] = rng.standard_normal((d, d)).astype(np.float32)
        sd[f"layers.{i}.mlp.w"] = rng.standard_normal((2 * d, d)).astype(np.float32)
        sd[f"layers.{i}.norm"] = rng.standard_normal((d,)).astype(np.float32)
    return sd


def _message(sd):
    return Message(MessageKind.TASK_RESULT, dict(sd),
                   {"client": "site-0", "num_samples": 1})


class _FoldSink:
    """Streaming-aggregation-shaped consumer (count and drop)."""

    def __init__(self):
        self.items = 0

    def __call__(self, name, value):
        if name != pl.META_ITEM:
            self.items += 1


def _transfer_tcp(p, sd, depth: int) -> int:
    """One full transfer over paced TCP at the given encode-ahead depth;
    returns the number of decoded payload items."""
    driver = _PacedTCP()
    decoder = p.decoder()
    sink = _FoldSink()
    recv = sm.ContainerReceiver(consume=sink, decode_item=decoder.decode_item)
    driver.connect(recv.on_chunk)
    try:
        msg, ctx = p.begin_encode(_message(sd))
        sm.ContainerStreamer(driver, CHUNK, prefetch=depth).send_items(
            p.iter_encode_views(msg, ctx), p.n_items(msg))
    finally:
        driver.close()  # waits for the receiver thread to drain
    return sink.items


def _wire_bytes(p, sd, depth: int) -> bytes:
    """Deterministic wire capture over loopback (bitwise cross-check)."""
    sent = bytearray()

    class _Tap(sm.LoopbackDriver):
        def send(self, chunk):
            for seg in chunk.segments:
                sent.extend(seg)
            super().send(chunk)

    driver = _Tap()
    decoder = p.decoder()
    recv = sm.ContainerReceiver(consume=_FoldSink(),
                                decode_item=decoder.decode_item)
    driver.connect(recv.on_chunk)
    msg, ctx = p.begin_encode(_message(sd))
    sm.ContainerStreamer(driver, CHUNK, prefetch=depth).send_items(
        p.iter_encode_views(msg, ctx), p.n_items(msg))
    return bytes(sent)


def run(repeats: int = 5) -> list[str]:
    sd = model_dict()
    payload = sum(v.nbytes for v in sd.values())
    n_items = len(sd)
    p = pl.build_pipeline(list(STACK))

    # lookahead must never change the bytes on the wire — only when they
    # were computed (checked once, outside the timed region)
    baseline_bytes = _wire_bytes(p, sd, 0)
    for depth in DEPTHS[1:]:
        assert _wire_bytes(p, sd, depth) == baseline_bytes, \
            f"wire bytes diverged at encode-ahead depth {depth}"

    _transfer_tcp(p, sd, 0)  # warm jit caches + codec state untimed

    rows = []
    per_depth: dict[int, float] = {}
    for depth in DEPTHS:
        best = float("inf")
        stall_us = 0.0
        for _ in range(repeats):
            reg = MetricsRegistry()
            with obs_metrics.activate(reg):
                t0 = time.perf_counter()
                items = _transfer_tcp(p, sd, depth)
                dt = time.perf_counter() - t0
            assert items == n_items, (items, n_items)
            if dt < best:
                best = dt
                hist = reg.histogram("wire.encode_wait_us").as_value()
                stall_us = hist["sum"] or 0.0
        per_depth[depth] = best
        rows.append(
            f"overlap/nf4-200mbps/depth{depth},{best * 1e6:.0f},"
            f"items_per_s={n_items / best:.0f};"
            f"gbps={payload / best / 1e9:.3f};"
            f"stall_us={stall_us:.0f}"
        )
    best_overlapped = min(per_depth[d] for d in DEPTHS if d > 0)
    rows.append(
        f"overlap/nf4-200mbps/speedup,0,"
        f"new_over_legacy={per_depth[0] / best_overlapped:.2f}"
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
