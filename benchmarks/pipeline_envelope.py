"""Wire-pipeline peak-memory envelope: the tentpole claim, benchmarked.

One llama-shaped global-weight message crosses the simulator wire under
container streaming with an ``nf4 + zlib`` stack, three ways:

* ``pipeline`` — per-item stages inside the streamer loop (peak ~ one
  quantized item),
* ``legacy``   — the same transforms as whole-message FilterChain shim
  stages (peak ~ whole quantized payload),
* ``plain``    — no transforms (peak ~ one fp32 item, for scale).

Reported ``derived`` fields carry the byte-exact peaks and true wire
bytes, so the nightly ``--smoke`` run surfaces any regression of the
O(largest item) envelope in BENCH_*.json.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.filters import two_way_quantization
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor


def model_dict(d: int = 256, layers: int = 12):
    rng = np.random.default_rng(0)
    sd = {}
    for i in range(layers):
        sd[f"layers.{i}.attn"] = rng.standard_normal((d, d)).astype(np.float32)
        sd[f"layers.{i}.mlp"] = rng.standard_normal((2 * d, d)).astype(np.float32)
    return sd


def _run(sd, wire_kwargs):
    def train_fn(params, rnd):
        return {k: np.asarray(v) for k, v in params.items()}, 1, {}

    sim = FLSimulator(
        [TrainExecutor("site-0", train_fn)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=1, transmission="container", chunk_size=1 << 18),
        **wire_kwargs,
    )
    t0 = time.perf_counter()
    sim.run(dict(sd))
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return sim.meter.peak, sim.stats.bytes_sent, elapsed_us


def run() -> list[str]:
    sd = model_dict()
    total = sum(v.nbytes for v in sd.values())
    max_item = max(v.nbytes for v in sd.values())
    stack = ["quantize:nf4", "zlib"]
    filters = two_way_quantization("nf4")
    cases = {
        "pipeline": {"pipelines": {"task_data": stack, "task_result": stack}},
        "legacy": {"server_filters": filters, "client_filters": filters},
        "plain": {"pipelines": {}},
    }
    rows = []
    peaks = {}
    for name, wire_kwargs in cases.items():
        peak, wire_bytes, us = _run(sd, wire_kwargs)
        peaks[name] = peak
        rows.append(
            f"pipeline_envelope/{name},{us:.0f},"
            f"peak_bytes={peak};wire_bytes={wire_bytes};"
            f"payload_bytes={total};max_item_bytes={max_item}"
        )
    rows.append(
        "pipeline_envelope/ratio,0,"
        f"legacy_over_pipeline={peaks['legacy'] / max(peaks['pipeline'], 1):.2f}"
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
