"""Benchmark harness: one module per paper table/figure + kernel/system

extras. Prints ``name,us_per_call,derived`` CSV (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--only table2,table3,...]
                                            [--smoke] [--json PATH]

``--smoke`` runs the fast CI subset; ``--json`` writes a machine-readable
``BENCH_*.json`` report (rows, per-suite timings, failures, and a
metrics-registry snapshot) for the nightly workflow artifact. A suite
that raises is reported on stderr and the process exits non-zero, so CI
actually fails on benchmark regressions instead of passing silently.
``--trace PATH`` additionally runs one traced 2-round smoke federation
and writes its dual-clock Chrome trace-event file (open in Perfetto);
the nightly job uploads it next to the bench JSON.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback

SUITES: dict[str, str] = {
    "table2": "benchmarks.table2_message_size",
    "table3": "benchmarks.table3_streaming_memory",
    "fig45": "benchmarks.fig45_convergence",
    "kernels": "benchmarks.quant_kernels",
    "chunks": "benchmarks.streaming_chunks",
    "sensitivity": "benchmarks.layer_sensitivity",
    "roofline": "benchmarks.roofline_report",
    "async": "benchmarks.async_throughput",
    "hetero": "benchmarks.hetero_fleet",
    "envelope": "benchmarks.pipeline_envelope",
    "agg_memory": "benchmarks.agg_memory",
    "wire": "benchmarks.wire_throughput",
    "lora": "benchmarks.lora_wire",
    "live": "benchmarks.live_federation",
    "overlap": "benchmarks.overlap_throughput",
}

# fast subset for the nightly smoke run (skips the convergence sweeps);
# "envelope" keeps the wire pipeline's O(largest item) peak-memory claim
# under regression watch in BENCH_*.json, "agg_memory" does the same for
# the streaming aggregation plane's O(item) server peak, and "wire"
# carries the zero-copy plane's items/s rows that the nightly job diffs
# against the committed BENCH_5.json baseline (benchmarks/compare.py);
# "live" drives the real multi-process federation plane (TCP server +
# protocol-speaking clients) whose deterministic ordered-fold peaks diff
# against BENCH_7.json, "lora" pins the parameter-efficient uplink
# (bytes-vs-rank + streaming low-rank fold peak) against BENCH_8.json,
# and "overlap" pins the encode-ahead send path (depth>=1 must keep
# beating the sequential depth-0 loop on a paced link) against
# BENCH_9.json
SMOKE_SUITES = ("table2", "table3", "kernels", "chunks", "async", "hetero",
                "envelope", "agg_memory", "wire", "lora", "live", "overlap")


def _metrics_snapshot(timings: dict[str, float]) -> dict:
    """Harness-level metrics in the registry snapshot schema: per-suite
    elapsed gauges plus host peak RSS — embedded in the JSON report so
    the nightly artifact carries one uniform metrics shape."""
    from repro.obs import MetricsRegistry
    from repro.utils.mem import rss_peak_kb

    reg = MetricsRegistry()
    for name, secs in timings.items():
        reg.gauge("bench.suite_elapsed_s", suite=name).set(secs)
    rss = rss_peak_kb()
    if rss is not None:
        reg.gauge("bench.rss_peak_kb").set(rss)
    return reg.snapshot()


def _write_smoke_trace(path: str) -> dict:
    """One traced 2-round async smoke federation -> Chrome trace file.

    Exercises every instrumented layer at once: quantize+crc32 uplink
    stages, streaming server-side aggregation, the heterogeneous network
    model, and the event scheduler — so the artifact shows both clocks
    (wall spans per thread, simulated round anatomy per client)."""
    from repro.fl.job import run_job
    from repro.obs import validate_chrome_trace

    result = run_job({
        "arch": "llama3.2-1b",
        "rounds": 2,
        "clients": 2,
        "local_steps": 1,
        "pipeline": {"task_result_out": ["quantize:nf4", "crc32"]},
        "server_streaming_agg": True,
        "runtime": {"policy": "sync",
                    "network": {"kind": "hetero", "tiers": ["fiber", "lte"]}},
        "trace": path,
    })
    with open(path) as fh:
        validate_chrome_trace(json.load(fh))
    summary = dict(result["trace"])
    summary["telemetry"] = result["telemetry"]
    return summary


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast subset: {','.join(SMOKE_SUITES)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a JSON report (default BENCH_smoke.json with --smoke)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run a traced 2-round smoke federation and write its "
                         "Chrome trace-event JSON here (open in Perfetto)")
    args = ap.parse_args(argv)

    if args.only:
        unknown = set(args.only.split(",")) - set(SUITES)
        if unknown:
            ap.error(f"unknown suites: {sorted(unknown)} (have {sorted(SUITES)})")
        selected = [s for s in SUITES if s in set(args.only.split(","))]
    elif args.smoke:
        selected = list(SMOKE_SUITES)
    else:
        selected = list(SUITES)
    json_path = args.json or ("BENCH_smoke.json" if args.smoke else None)

    print("name,us_per_call,derived")
    rows: list[str] = []
    timings: dict[str, float] = {}
    failures: dict[str, str] = {}
    t0 = time.time()
    for name in selected:
        t_suite = time.time()
        try:
            mod = importlib.import_module(SUITES[name])
            for row in mod.run():
                print(row)
                rows.append(row)
        except Exception as exc:  # noqa: BLE001 — a failed suite must not hide the rest
            traceback.print_exc()
            failures[name] = f"{type(exc).__name__}: {exc}"
        timings[name] = round(time.time() - t_suite, 3)
    elapsed = time.time() - t0
    print(f"# total {elapsed:.1f}s", file=sys.stderr)

    trace_summary = None
    if args.trace:
        try:
            trace_summary = _write_smoke_trace(args.trace)
            print(f"# wrote {args.trace}", file=sys.stderr)
        except Exception as exc:  # noqa: BLE001 — same isolation as suites
            traceback.print_exc()
            failures["trace"] = f"{type(exc).__name__}: {exc}"

    if json_path:
        report = {
            "suites": selected,
            "rows": rows,
            "timings_s": timings,
            "failures": failures,
            "elapsed_s": round(elapsed, 3),
            "metrics": _metrics_snapshot(timings),
        }
        if trace_summary is not None:
            report["trace"] = trace_summary
        with open(json_path, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)

    if failures:
        for name, err in failures.items():
            print(f"# FAILED {name}: {err}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
