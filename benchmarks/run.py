"""Benchmark harness: one module per paper table/figure + kernel/system

extras. Prints ``name,us_per_call,derived`` CSV (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--only table2,table3,...]
                                            [--smoke] [--json PATH]

``--smoke`` runs the fast CI subset; ``--json`` writes a machine-readable
``BENCH_*.json`` report (rows, per-suite timings, failures) for the
nightly workflow artifact. A suite that raises is reported on stderr and
the process exits non-zero, so CI actually fails on benchmark
regressions instead of passing silently.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback

SUITES: dict[str, str] = {
    "table2": "benchmarks.table2_message_size",
    "table3": "benchmarks.table3_streaming_memory",
    "fig45": "benchmarks.fig45_convergence",
    "kernels": "benchmarks.quant_kernels",
    "chunks": "benchmarks.streaming_chunks",
    "sensitivity": "benchmarks.layer_sensitivity",
    "roofline": "benchmarks.roofline_report",
    "async": "benchmarks.async_throughput",
    "hetero": "benchmarks.hetero_fleet",
    "envelope": "benchmarks.pipeline_envelope",
    "agg_memory": "benchmarks.agg_memory",
    "wire": "benchmarks.wire_throughput",
}

# fast subset for the nightly smoke run (skips the convergence sweeps);
# "envelope" keeps the wire pipeline's O(largest item) peak-memory claim
# under regression watch in BENCH_*.json, "agg_memory" does the same for
# the streaming aggregation plane's O(item) server peak, and "wire"
# carries the zero-copy plane's items/s rows that the nightly job diffs
# against the committed BENCH_5.json baseline (benchmarks/compare.py)
SMOKE_SUITES = ("table2", "table3", "kernels", "chunks", "async", "hetero",
                "envelope", "agg_memory", "wire")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast subset: {','.join(SMOKE_SUITES)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a JSON report (default BENCH_smoke.json with --smoke)")
    args = ap.parse_args(argv)

    if args.only:
        unknown = set(args.only.split(",")) - set(SUITES)
        if unknown:
            ap.error(f"unknown suites: {sorted(unknown)} (have {sorted(SUITES)})")
        selected = [s for s in SUITES if s in set(args.only.split(","))]
    elif args.smoke:
        selected = list(SMOKE_SUITES)
    else:
        selected = list(SUITES)
    json_path = args.json or ("BENCH_smoke.json" if args.smoke else None)

    print("name,us_per_call,derived")
    rows: list[str] = []
    timings: dict[str, float] = {}
    failures: dict[str, str] = {}
    t0 = time.time()
    for name in selected:
        t_suite = time.time()
        try:
            mod = importlib.import_module(SUITES[name])
            for row in mod.run():
                print(row)
                rows.append(row)
        except Exception as exc:  # noqa: BLE001 — a failed suite must not hide the rest
            traceback.print_exc()
            failures[name] = f"{type(exc).__name__}: {exc}"
        timings[name] = round(time.time() - t_suite, 3)
    elapsed = time.time() - t0
    print(f"# total {elapsed:.1f}s", file=sys.stderr)

    if json_path:
        report = {
            "suites": selected,
            "rows": rows,
            "timings_s": timings,
            "failures": failures,
            "elapsed_s": round(elapsed, 3),
        }
        with open(json_path, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)

    if failures:
        for name, err in failures.items():
            print(f"# FAILED {name}: {err}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
