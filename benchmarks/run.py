"""Benchmark harness: one module per paper table/figure + kernel/system

extras. Prints ``name,us_per_call,derived`` CSV (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--only table2,table3,...]
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("table2", "table3", "fig45", "kernels", "chunks", "sensitivity", "roofline", "async")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    print("name,us_per_call,derived")
    t0 = time.time()
    if "table2" in only:
        from benchmarks import table2_message_size

        for row in table2_message_size.run():
            print(row)
    if "table3" in only:
        from benchmarks import table3_streaming_memory

        for row in table3_streaming_memory.run():
            print(row)
    if "fig45" in only:
        from benchmarks import fig45_convergence

        for row in fig45_convergence.run():
            print(row)
    if "kernels" in only:
        from benchmarks import quant_kernels

        for row in quant_kernels.run():
            print(row)
    if "chunks" in only:
        from benchmarks import streaming_chunks

        for row in streaming_chunks.run():
            print(row)
    if "sensitivity" in only:
        from benchmarks import layer_sensitivity

        for row in layer_sensitivity.run():
            print(row)
    if "roofline" in only:
        from benchmarks import roofline_report

        for row in roofline_report.run():
            print(row)
    if "async" in only:
        from benchmarks import async_throughput

        for row in async_throughput.run():
            print(row)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
