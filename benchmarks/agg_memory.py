"""Server aggregation-plane peak memory: batch vs streaming (Table
III-style rows, ISSUE 4 tentpole).

N concurrent clients upload a quantized+compressed model through the
container wire into one FedAvg aggregator. The *batch* plane decodes
each client's payload dict before aggregating — one model resident per
in-flight client, the O(model x clients) bottleneck container streaming
exists to remove. The *streaming* plane folds each item through
``begin/accept_item`` inside the receive loop — peak is ~one item per
sender. Byte-exact accounting via MemoryMeter, like table3.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import pipeline as pl
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.fl import CollectingSink, FedAvgAggregator
from repro.utils import mem
from repro.utils.mem import MemoryMeter

SENDERS = 8
STAGES = ("quantize:blockwise8", "zlib")


def model_dict(items: int = 64, elems: int = 16384):
    rng = np.random.default_rng(0)
    return {f"layers.{i}.w": rng.standard_normal(elems).astype(np.float32)
            for i in range(items)}


def _stream_one(sink, sd, client):
    p = pl.build_pipeline(list(STAGES))
    msg = Message(MessageKind.TASK_RESULT, dict(sd),
                  {"num_samples": 1, "client": client})
    enc, ctx = p.begin_encode(msg)
    dec = p.decoder(sink=sink)
    recv = sm.ContainerReceiver(consume=dec.on_item, decode_item=dec.decode_item)
    driver = sm.LoopbackDriver()
    driver.connect(recv.on_chunk)
    sm.ContainerStreamer(driver, 1 << 16).send_items(
        p.iter_encode_views(enc, ctx), p.n_items(enc)
    )
    return dec.finish(msg.kind, p.unsent_headers(enc))


def _run_mode(sd, streaming: bool):
    agg = FedAvgAggregator()
    meter = MemoryMeter()

    def send(i):
        client = f"site-{i}"
        if streaming:
            _stream_one(agg, sd, client)
        else:
            sink = CollectingSink()
            out = _stream_one(sink, sd, client)
            held = sum(v.nbytes for v in sink.payload.values())
            mem.record_alloc(held)  # decoded model resident until accept
            agg.accept(Message(out.kind, sink.payload, out.headers))
            mem.record_free(held)

    t0 = time.perf_counter()
    with meter.activate():
        threads = [threading.Thread(target=send, args=(i,)) for i in range(SENDERS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    agg.finish()
    return meter.peak, (time.perf_counter() - t0) * 1e6


def run() -> list[str]:
    sd = model_dict()
    model_bytes = sum(v.nbytes for v in sd.values())
    max_item = max(v.nbytes for v in sd.values())
    rows = []
    peaks = {}
    for mode, streaming in (("batch", False), ("streaming", True)):
        peak, us = _run_mode(sd, streaming)
        peaks[mode] = peak
        rows.append(
            f"agg_memory/{mode},{us:.0f},peak_bytes={peak};model_bytes={model_bytes};"
            f"max_item_bytes={max_item};senders={SENDERS}"
        )
    ok = peaks["streaming"] < model_bytes < peaks["batch"]
    rows.append(
        f"agg_memory/ordering,0,streaming<model<batch={ok};"
        f"batch_over_streaming={peaks['batch'] / max(1, peaks['streaming']):.1f}x;"
        f"streaming_items_per_sender="
        f"{peaks['streaming'] / (SENDERS * max_item):.2f}"
    )
    return rows
