"""Live federation plane: real-TCP round wall-clock, concurrent-uplink
fold throughput, and server peak memory vs client count (ISSUE 7
tentpole).

Fold rows drive the real :class:`~repro.launch.federation.
FederationServer` over localhost sockets with protocol-speaking raw
clients whose uplink streams are **pre-encoded outside the meter** (the
MemoryMeter is process-global, so client-side encode copies would
otherwise pollute the server-side peak). Only the server's gather phase
runs under the meter: with the default ordered uplink the folds are
grant-serialized, so ``peak_bytes``/``copied`` are deterministic
functions of the wire format — machine-independent gate metrics. The
concurrent-mode row measures scheduler-dependent throughput and is
deliberately named so the nightly compare gate skips it.

``live/round/subprocess`` runs one true multi-process round
(``run_live_federation`` spawning real client subprocesses) and reports
wall seconds ungated — real-deployment latency for the record, not a
regression signal.

``live/degraded/stragglersN`` rows (ISSUE 10) measure quorum-mode folds
with 0%/12%/25% of an 8-client fleet straggling past the grace: the
round closes early over the contributors it has, and the deterministic
``peak_bytes``/``copied`` of the partial fold gate against the
committed ``BENCH_10.json`` — degraded-mode memory/copy behavior is a
regression surface, degraded-mode wall-clock (dominated by the grace
deadline itself) is not.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any

import numpy as np

from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.launch.federation import (
    PROTO,
    FederationServer,
    aggregator_spec,
    build_pipelines_from_spec,
    pipeline_fingerprint,
)
from repro.utils.mem import MemoryMeter

MODEL_ITEMS = 32
ELEMS = 16384  # 32 x 64 KiB fp32 = 2 MiB model
PIPELINE = {"task_result_out": ["quantize:blockwise8", "crc32"]}


def model_dict() -> dict[str, np.ndarray]:
    rng = np.random.default_rng(0)
    return {f"layers.{i}.w": rng.standard_normal(ELEMS).astype(np.float32)
            for i in range(MODEL_ITEMS)}


def _spec(clients: int) -> dict[str, Any]:
    return {"clients": clients, "rounds": 1, "pipeline": dict(PIPELINE),
            "chunk_mb": 1}


def _encode_uplink(spec: dict[str, Any], name: str,
                   sd: dict[str, np.ndarray]) -> bytes:
    """One client's complete uplink chunk stream as raw wire bytes."""
    pipeline = build_pipelines_from_spec(spec)["task_result"]
    msg = Message(MessageKind.TASK_RESULT, dict(sd),
                  {"num_samples": 1, "client": name, "round": 0})
    enc, ctx = pipeline.begin_encode(msg)

    class _Capture:
        def __init__(self) -> None:
            self.bufs: list[bytes] = []

        def send(self, chunk: sm.Chunk) -> None:
            self.bufs.append(chunk.encode())

    cap = _Capture()
    sm.ContainerStreamer(cap, 1 << 20).send_items(
        pipeline.iter_encode_views(enc, ctx), pipeline.n_items(enc)
    )
    return b"".join(cap.bufs)


class _RawClient(threading.Thread):
    """Protocol-speaking fake client: handshake, drain downlinks, replay
    a pre-encoded uplink blob on every grant. No allocations are metered
    client-side — ``sendall`` of prebuilt bytes, no-op chunk drain."""

    def __init__(self, name: str, address: tuple, fingerprint: str,
                 blob: bytes, grant_delay_s: float = 0.0) -> None:
        super().__init__(daemon=True, name=f"bench-{name}")
        self.client = name
        self.address = address
        self.fingerprint = fingerprint
        self.blob = blob
        # straggler knob: sit on the grant past the server's grace, then
        # send anyway — the late stream must be drained and discarded
        self.grant_delay_s = grant_delay_s

    def run(self) -> None:
        conn = None
        try:
            conn = sm.Connection(socket.create_connection(self.address))
            conn.settimeout(120.0)
            conn.send_ctrl({"type": "hello", "client": self.client,
                            "epoch": 0, "proto": PROTO,
                            "fingerprint": self.fingerprint})
            if conn.recv_ctrl().get("type") != "welcome":
                return
            while True:
                ctrl = conn.recv_ctrl()
                kind = ctrl.get("type")
                if kind == "task":
                    conn.recv_stream(lambda c: None)
                elif kind == "grant":
                    if self.grant_delay_s:
                        time.sleep(self.grant_delay_s)
                    conn.send_ctrl({"type": "result",
                                    "round": ctrl["round"],
                                    "client": self.client})
                    conn.sock.sendall(self.blob)
                elif kind == "done":
                    return
        except (OSError, ConnectionError, sm.ProtocolError):
            pass
        finally:
            if conn is not None:
                conn.close()


def _run_fold(clients: int, uplink: str):
    """One metered gather: returns (meter, fold_seconds, items folded)."""
    spec = _spec(clients)
    sd = model_dict()
    server = FederationServer(spec, uplink=uplink, join_timeout_s=60.0,
                              round_timeout_s=120.0).start()
    fp = pipeline_fingerprint(build_pipelines_from_spec(spec),
                              aggregator_spec(spec))
    threads = [
        _RawClient(f"site-{i}", server.address, fp,
                   _encode_uplink(spec, f"site-{i}", sd))
        for i in range(clients)
    ]
    try:
        for t in threads:
            t.start()
        server.wait_for_clients()
        roster = [f"site-{i}" for i in range(clients)]
        # tiny downlink (outside the meter): the fold is what's measured
        active = server._downlink(roster, 0, {"w": np.zeros(8, np.float32)})
        with server._lock:
            server._tasked = set(active)
        meter = MemoryMeter()
        t0 = time.perf_counter()
        with meter.activate():
            server._gather(roster, 0)
        dt = time.perf_counter() - t0
        for name in roster:
            conn = server._conns.get(name)
            if conn is not None:
                try:
                    conn.send_ctrl({"type": "done"})
                except OSError:
                    pass
    finally:
        server.close()
        for t in threads:
            t.join(timeout=10)
    return meter, dt, clients * (MODEL_ITEMS + 1)  # +1: meta item


def _run_degraded(stragglers: int):
    """Quorum fold over 8 clients with the last ``stragglers`` of them
    sitting on the grant past ``straggler_grace_s``: the round closes
    early over the contributors it has, and the late uplinks are drained
    off-meter. Returns (meter, fold_seconds, contributors, faults)."""
    clients = 8
    spec = _spec(clients)
    spec.update({"quorum": 0.75, "straggler_grace_s": 0.25})
    sd = model_dict()
    server = FederationServer(spec, uplink="ordered", join_timeout_s=60.0,
                              round_timeout_s=120.0).start()
    fp = pipeline_fingerprint(build_pipelines_from_spec(spec),
                              aggregator_spec(spec))
    threads = [
        _RawClient(f"site-{i}", server.address, fp,
                   _encode_uplink(spec, f"site-{i}", sd),
                   grant_delay_s=1.0 if i >= clients - stragglers else 0.0)
        for i in range(clients)
    ]
    try:
        for t in threads:
            t.start()
        server.wait_for_clients()
        roster = [f"site-{i}" for i in range(clients)]
        active = server._downlink(roster, 0, {"w": np.zeros(8, np.float32)})
        with server._lock:
            server._tasked = set(active)
        meter = MemoryMeter()
        t0 = time.perf_counter()
        with meter.activate():
            _, contributed = server._gather(roster, 0)
        dt = time.perf_counter() - t0
        # let the late uplinks finish draining before tearing down, so
        # the stragglers end the bench connected, not lost mid-drain
        deadline = time.monotonic() + 15.0
        with server._drain_cv:
            while server._draining and time.monotonic() < deadline:
                server._drain_cv.wait(timeout=0.2)
        for name in roster:
            conn = server._conns.get(name)
            if conn is not None:
                try:
                    conn.send_ctrl({"type": "done"})
                except OSError:
                    pass
        faults = {k: (dict(v) if isinstance(v, dict) else v)
                  for k, v in server.faults.items()}
    finally:
        server.close()
        for t in threads:
            t.join(timeout=10)
    return meter, dt, list(contributed), faults


def _subprocess_round() -> dict[str, Any]:
    from repro.launch.federation import run_live_federation

    result = run_live_federation({
        "arch": "llama3.2-1b",
        "smoke": True,
        "rounds": 1,
        "clients": 2,
        "local_steps": 1,
        "batch": 2,
        "seq": 16,
        "pipeline": dict(PIPELINE),
        "server_streaming_agg": True,
    })
    return result


def run() -> list[str]:
    sd = model_dict()
    model_bytes = sum(v.nbytes for v in sd.values())
    max_item = max(v.nbytes for v in sd.values())
    rows: list[str] = []

    # ordered fold: deterministic peak/copied gate the nightly compare
    meter, dt, items = _run_fold(8, "ordered")
    rows.append(
        f"live/fold/ordered_c8,{dt * 1e6:.0f},peak_bytes={meter.peak};"
        f"copied={meter.copied};model_bytes={model_bytes};"
        f"max_item_bytes={max_item};items={items}"
    )

    # concurrent fold: throughput mode; scheduler-dependent numbers, so
    # the row carries only ungated conc_* fields (us_per_call=0 disarms
    # the wall-clock fallback gate)
    cmeter, cdt, citems = _run_fold(8, "concurrent")
    rows.append(
        f"live/fold/concurrent_c8,0.0,conc_items_per_s={citems / cdt:.0f};"
        f"conc_peak_bytes={cmeter.peak};conc_wall_us={cdt * 1e6:.0f}"
    )

    # O(item) server peak vs client count: ordered folds keep the peak
    # ~flat as the fleet grows — the paper's streaming-aggregation claim
    # measured on real sockets
    peaks = {}
    for n in (2, 8, 16):
        m, _, _ = _run_fold(n, "ordered")
        peaks[n] = m.peak
        rows.append(
            f"live/peak/c{n},0.0,peak_bytes={m.peak};copied={m.copied};"
            f"model_bytes={model_bytes};max_item_bytes={max_item}"
        )
    flat = peaks[16] <= peaks[2] * 1.5
    rows.append(
        f"live/peak/scaling,0.0,flat_2_to_16={int(flat)};"
        f"c16_over_c2={peaks[16] / max(1, peaks[2]):.2f};"
        f"model_over_peak={model_bytes / max(1, peaks[16]):.1f}"
    )

    # degraded-mode quorum folds: 0%/12%/25% of the fleet straggles past
    # the grace; peak/copied of the partial fold are deterministic and
    # gate against BENCH_10.json (us_per_call=0 disarms the wall gate —
    # degraded wall-clock is dominated by the grace deadline itself)
    for k in (0, 1, 2):
        dmeter, ddt, contributed, faults = _run_degraded(k)
        pct = round(100 * k / 8)
        rows.append(
            f"live/degraded/stragglers{pct},0.0,peak_bytes={dmeter.peak};"
            f"copied={dmeter.copied};contributors={len(contributed)};"
            f"stragglers={len(faults['stragglers'])};"
            f"fold_wall_s={ddt:.2f}"
        )

    # one true multi-process round: wall-clock for the record (ungated)
    sub = _subprocess_round()
    rows.append(
        f"live/round/subprocess,0.0,wall_s={sub['wall_s']:.2f};"
        f"round_wall_s={sub['round_log'][0]['wall_s']:.2f};clients=2;"
        f"bytes_up={sub['bytes_up']};bytes_down={sub['bytes_down']};"
        f"exit_ok={int(all(c == 0 for c in sub['client_exit_codes']))}"
    )
    return rows
