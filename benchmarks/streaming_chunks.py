"""Paper §V future-work benchmark: streaming across chunk sizes and

(simulated) network fault conditions — throughput, peak memory and
retransmission overhead per setting.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import streaming as sm
from repro.core.resilience import LossyDriver, ReliableTransfer
from repro.utils.mem import MemoryMeter


def _sd(mb: int = 32):
    rng = np.random.default_rng(0)
    n = mb * 1024 * 1024 // 4 // 8
    return {f"layer.{i}": rng.standard_normal(n).astype(np.float32) for i in range(8)}


def run() -> list[str]:
    rows: list[str] = []
    sd = _sd()
    total = sum(v.nbytes for v in sd.values())

    # chunk-size sweep (clean link)
    for chunk in (64 << 10, 256 << 10, 1 << 20, 4 << 20):
        meter = MemoryMeter()
        t0 = time.perf_counter()
        with meter.activate():
            driver = sm.LoopbackDriver()
            recv = sm.ContainerReceiver(consume=lambda n, v: None)
            driver.connect(recv.on_chunk)
            sm.ContainerStreamer(driver, chunk).send_container(sd)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"streaming_chunks/{chunk >> 10}KiB,{us:.0f},"
            f"GBps={total / (us / 1e6) / 1e9:.2f};peak_bytes={meter.peak}"
        )

    # fault-condition sweep at 1 MiB chunks (reliable transfer)
    for drop in (0.0, 0.05, 0.2):
        driver = LossyDriver(sm.LoopbackDriver(), drop_prob=drop, seed=11)
        recv = sm.ContainerReceiver(consume=lambda n, v: None)
        xfer = ReliableTransfer(driver, chunk_size=1 << 20)
        t0 = time.perf_counter()
        ok = xfer.send_container(sd, recv, max_rounds=100)
        us = (time.perf_counter() - t0) * 1e6
        nchunks = total // (1 << 20) + len(sd)
        rows.append(
            f"streaming_faults/drop{int(drop * 100)}pct,{us:.0f},"
            f"complete={ok};retransmits={xfer.retransmits};"
            f"overhead_pct={100.0 * xfer.retransmits / nchunks:.1f}"
        )
    return rows
