"""Paper Fig. 4 + Fig. 5: SFT convergence parity.

Fig. 4 — centralized training vs single-site FL (loss curves must align
up to training randomness).
Fig. 5 — single-site FL under message quantization (fp16, blockwise8,
fp4, nf4) vs centralized: parity must be preserved.

We train a reduced llama-family model on the synthetic Markov corpus
(learnable; entropy floor = ln(branching)) via the *actual* FL runtime —
filters, serialization, streaming, aggregation — not a shortcut.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.filters import no_filters, two_way_quantization
from repro.data import SyntheticLMDataset
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

STEPS_PER_ROUND = 4
ROUNDS = 8
BATCH, SEQ = 8, 64
LR = 3e-3


def _setup(seed: int = 0):
    cfg = get_smoke_config("llama3.2-1b").with_overrides(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256
    )
    model = create_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    data = SyntheticLMDataset(cfg.vocab_size, SEQ, seed=seed)
    return cfg, model, params, data


def centralized(seed: int = 0) -> list[float]:
    cfg, model, params, data = _setup(seed)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(LR))
        return params, opt, loss

    losses = []
    for _ in range(ROUNDS * STEPS_PER_ROUND):
        batch = {k: jnp.asarray(v) for k, v in data.sample(BATCH).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    return losses


def federated(fmt: Optional[str], seed: int = 0) -> list[float]:
    """Single-site FL (paper's Fig. 4/5 setting) through the full stack."""
    cfg, model, params, data = _setup(seed)
    losses: list[float] = []

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(LR))
        return params, opt, loss

    def train_fn(flat_params, rnd):
        p = unflatten_state_dict(
            {k: jnp.asarray(np.asarray(v)) for k, v in flat_params.items()}
        )
        opt = adamw_init(p)  # paper's SFT restarts optimizer per round
        for _ in range(STEPS_PER_ROUND):
            batch = {k: jnp.asarray(v) for k, v in data.sample(BATCH).items()}
            p, opt, loss = step(p, opt, batch)
            losses.append(float(loss))
        return flatten_state_dict(p), BATCH * STEPS_PER_ROUND, {"loss": losses[-1]}

    filters = two_way_quantization(fmt) if fmt else no_filters()
    sim = FLSimulator(
        [TrainExecutor("site-1", train_fn)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=filters,
        client_filters=filters,
    )
    sim.run(flatten_state_dict(params))
    return losses


def run() -> list[str]:
    rows: list[str] = []
    cen = centralized()
    fl = federated(None)
    # Fig 4: curves align (compare mean of last round)
    tail = STEPS_PER_ROUND * 2
    gap = abs(np.mean(cen[-tail:]) - np.mean(fl[-tail:]))
    rows.append(
        f"fig4/centralized_vs_fl,0,cen_final={np.mean(cen[-tail:]):.4f};"
        f"fl_final={np.mean(fl[-tail:]):.4f};gap={gap:.4f};"
        f"cen_start={cen[0]:.4f};aligned={gap < 0.15}"
    )
    # Fig 5: quantized FL parity
    for fmt in ("fp16", "blockwise8", "fp4", "nf4"):
        flq = federated(fmt)
        gap = abs(np.mean(flq[-tail:]) - np.mean(cen[-tail:]))
        rows.append(
            f"fig5/{fmt},0,final={np.mean(flq[-tail:]):.4f};gap_to_centralized={gap:.4f};"
            f"converged={flq[-1] < flq[0] - 0.5};aligned={gap < 0.25}"
        )
    return rows
