"""Sync-vs-async round throughput under heterogeneous bandwidth.

Eight clients on mixed links (fiber ... 3g) run the same client-task
budget through (a) the round-barrier SyncPolicy and (b) FedBuff-style
buffered async aggregation, for fp32 vs int8 (blockwise8) vs NF4
payloads. Every message crosses the real streaming transport; the
simulated clock converts the *actual* wire bytes into per-link transfer
time, so the table shows both effects the paper's stack is after:
quantization shrinks each transfer, async scheduling stops fast links
from idling behind the 3G straggler.

Emits ``name,us_per_call,derived`` rows (harness contract):
us_per_call = simulated microseconds per global model update.
"""
from __future__ import annotations

from collections.abc import Iterator

import numpy as np

from repro.core.filters import no_filters, two_way_quantization
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import FedBuffPolicy, RuntimeConfig, heterogeneous_network

NUM_CLIENTS = 8
ROUNDS = 4                      # sync rounds; async gets the same task budget
DIM = 32 * 1024                 # 128 KiB of fp32 weights per message


def _executors(w_true: np.ndarray) -> list[TrainExecutor]:
    def make(name: str, seed: int) -> TrainExecutor:
        rng = np.random.default_rng(seed)
        direction = rng.standard_normal(w_true.size).astype(np.float32)
        direction /= np.linalg.norm(direction)

        def train_fn(params, rnd):
            w = np.asarray(params["w"], np.float32)
            # cheap synthetic local step: move toward w_true with a
            # client-specific bias so aggregation has real work to do
            w = w + 0.5 * (w_true - w) + 0.01 * direction
            return {"w": w}, 32, {}

        return TrainExecutor(name, train_fn)

    return [make(f"site-{i}", i) for i in range(NUM_CLIENTS)]


def _run(mode: str, fmt: str | None) -> tuple:
    names = [f"site-{i}" for i in range(NUM_CLIENTS)]
    network = heterogeneous_network(names, seed=7, compute_base_s=0.5, compute_spread=6.0)
    filters = two_way_quantization(fmt) if fmt else no_filters()
    w_true = np.linspace(-1.0, 1.0, DIM).astype(np.float32)
    policy = None
    if mode == "async":
        policy = FedBuffPolicy(total_tasks=ROUNDS * NUM_CLIENTS, buffer_size=NUM_CLIENTS // 2)
    sim = FLSimulator(
        _executors(w_true),
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=filters,
        client_filters=filters,
        runtime=RuntimeConfig(seed=11, max_concurrency=NUM_CLIENTS),
        policy=policy,
        network=network,
    )
    sim.run({"w": np.zeros(DIM, np.float32)})
    updates = max(1, sim.scheduler.stats.model_updates)
    return sim.sim_time_s, updates, sim.stats.bytes_sent


def run() -> Iterator[str]:
    for fmt in (None, "blockwise8", "nf4"):
        label = fmt or "fp32"
        for mode in ("sync", "async"):
            makespan, updates, wire = _run(mode, fmt)
            us_per_update = makespan * 1e6 / updates
            yield (
                f"async_throughput_{mode}_{label},{us_per_update:.0f},"
                f"makespan_s={makespan:.2f};updates={updates};"
                f"updates_per_sim_min={updates / makespan * 60:.2f};"
                f"wire_mb={wire / 1e6:.2f}"
            )


if __name__ == "__main__":
    for row in run():
        print(row)
