"""Wire-plane throughput: GB/s and items/s through the full pipeline
stack, per stage combination, new zero-copy path vs. the pre-refactor
copying path.

Each case pushes an LLM-shaped state dict (many tensors, like a real
transformer checkpoint) through container streaming over loopback —
stage encode, chunk framing, reassembly, stage decode, and a
streaming-fold consume (each decoded item handed downstream and
dropped, the server-side aggregation hot path) — and reports:

* ``items_per_s`` — decoded payload items per second end to end,
* ``gbps`` — payload gigabytes per second end to end,
* ``copied`` / ``alloc`` — MemoryMeter byte-copy volume and cumulative
  buffer allocations per transfer (the zero-copy claim, measured).

The ``legacy`` rows re-enact the pre-refactor hot path faithfully:
per-tensor quantize with eager pad/reshape dispatches and a sync per
item, ``tobytes()`` + ``b"".join`` framing, per-chunk byte slices, and
a parts-list + join receiver. Wire bytes are asserted identical between
the two paths (once, outside the timed region) — this benchmark
measures the cost of copies and dispatch, never a format change. The
``speedup`` rows feed the nightly regression gate
(``benchmarks/compare.py`` against ``BENCH_5.json``).
"""
from __future__ import annotations

import json
import struct
import time

import numpy as np

from repro.core import pipeline as pl
from repro.core import serialization as ser
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.utils import mem
from repro.utils.mem import MemoryMeter

try:
    import zstandard  # noqa: F401
    COMPRESS = "zstd:3"
except ImportError:
    # image without zstd: zlib stored-blocks mode is the closest stand-in
    # for zstd:3's speed class on quantized payloads — on high-entropy
    # nf4 bytes both effectively store (zstd's fast match search finds
    # nothing), whereas deflate's match search at level>=1 runs ~20 MB/s
    # and would make every path compressor-bound, hiding the wire costs
    # this benchmark exists to measure
    COMPRESS = "zlib:0"

CHUNK = 1 << 18

_QSTACK = ["quantize:nf4", COMPRESS, "crc32"]
_QNAME = f"nf4-{COMPRESS.split(':')[0]}-crc32"

#: stage stacks under measurement: (stages, decode_values). The
#: acceptance case is the quantize -> compress -> crc32
#: container-streaming path; its ``wireform`` variant keeps items in
#: wire form on the receiver (``decode_values=False`` — the quantized
#: streaming-aggregation server fold, where the fused
#: dequant-accumulate kernel consumes payloads directly)
STACKS = {
    "plain": ([], True),
    "crc32": (["crc32"], True),
    "nf4": (["quantize:nf4"], True),
    _QNAME: (_QSTACK, True),
    f"{_QNAME}-wireform": (_QSTACK, False),
}


def model_dict(layers: int = 32, d: int = 96):
    """A transformer-shaped dict: many medium tensors (the regime where
    per-item dispatch+copy overhead dominates, as in real LLM
    checkpoints with hundreds of layers)."""
    rng = np.random.default_rng(0)
    sd = {}
    for i in range(layers):
        sd[f"layers.{i}.attn.w"] = rng.standard_normal((d, d)).astype(np.float32)
        sd[f"layers.{i}.mlp.w"] = rng.standard_normal((2 * d, d)).astype(np.float32)
        sd[f"layers.{i}.norm"] = rng.standard_normal((d,)).astype(np.float32)
    return sd


def _message(sd):
    return Message(MessageKind.TASK_RESULT, dict(sd),
                   {"client": "site-0", "num_samples": 1})


class _FoldSink:
    """Streaming-aggregation-shaped consumer: touches each decoded item
    and drops it (the O(item) server fold loop)."""

    def __init__(self):
        self.items = 0

    def __call__(self, name, value):
        self.items += 1


def _wire_tap(driver_cls=sm.LoopbackDriver):
    sent = bytearray()

    class _Tap(driver_cls):
        def send(self, chunk):
            for seg in chunk.segments:
                sent.extend(seg)
            super().send(chunk)

    return _Tap(), sent


# ---------------------------------------------------------------------------
# new path: scatter-gather views end to end
# ---------------------------------------------------------------------------

def run_new(stack, sd, tap: bool = False, decode_values: bool = True):
    """One transfer over the current wire; with ``tap`` the raw wire
    bytes are captured and returned (for the bitwise cross-check)."""
    p = pl.build_pipeline(list(stack), decode_values=decode_values)
    if tap:
        driver, sent = _wire_tap()
    else:
        driver, sent = sm.LoopbackDriver(), None
    decoder = p.decoder()
    sink = _FoldSink()

    def consume(name, value):
        if name != pl.META_ITEM:
            sink(name, value)

    recv = sm.ContainerReceiver(consume=consume, decode_item=decoder.decode_item)
    driver.connect(recv.on_chunk)
    msg, ctx = p.begin_encode(_message(sd))
    sm.ContainerStreamer(driver, CHUNK).send_items(
        p.iter_encode_views(msg, ctx), p.n_items(msg))
    assert sink.items == len(sd)
    return bytes(sent) if tap else None


# ---------------------------------------------------------------------------
# legacy path: the pre-refactor copying pipeline, re-enacted
# ---------------------------------------------------------------------------

def _legacy_quantize(value, fmt):
    """Pre-refactor quantize: eager flatten/astype/pad dispatches
    followed by the 2-D jitted kernel — several dispatches and one sync
    per tensor (the new path fuses these into one async dispatch and
    blocks once per message)."""
    import jax
    import jax.numpy as jnp

    from repro.core.quantization import QuantizedTensor
    from repro.kernels import ops

    arr = np.asarray(value)
    if fmt in ("fp4", "nf4"):
        x2d, _ = ops._pad_to_blocks(
            jnp.asarray(arr).reshape(-1).astype(jnp.float32), ops.BLOCK4)
        payload, absmax = ops._REF_Q4[fmt](x2d)
    elif fmt == "blockwise8":
        x2d, _ = ops._pad_to_blocks(
            jnp.asarray(arr).reshape(-1).astype(jnp.float32), ops.BLOCK8)
        payload, absmax = ops._REF_Q8(x2d)
    else:
        raise ValueError(fmt)
    jax.block_until_ready((payload, absmax))  # the per-item sync
    return QuantizedTensor(payload, absmax, fmt, tuple(arr.shape), arr.dtype)


def _legacy_serialize_item(name, value) -> bytes:
    """Pre-refactor serialize: every buffer exported with ``tobytes``
    (copy), then joined (copy)."""
    views = ser.serialize_item_views(name, value)
    parts = []
    for v in views:
        b = bytes(v)
        mem.record_copy(len(b))
        parts.append(b)
    out = b"".join(parts)
    mem.record_copy(len(out))
    return out


def _legacy_encode_item(p, name, value, ctx) -> bytes:
    from repro.core.quantization import QuantizedTensor

    vmetas = []
    for s in p._vstages:
        ctx.vmeta = {}
        if isinstance(s, pl.QuantizeStage) and s.fmt in ("nf4", "fp4", "blockwise8") \
                and not isinstance(value, QuantizedTensor) \
                and np.issubdtype(np.asarray(value).dtype, np.floating):
            value = _legacy_quantize(value, s.fmt)
        else:
            value = s.encode_item(name, value, ctx)
        vmetas.append(ctx.vmeta)
    inner = _legacy_serialize_item(name, value)
    body = inner
    brecs = []
    for s in p._bstages:
        bmeta = {}
        body = s.encode_item_bytes(name, body, bmeta, ctx)
        brecs.append([s.name, bmeta])
    if not p._vstages and not p._bstages:
        return inner
    header = {"kind": "wire", "name": name, "n": len(body),
              "v": [s.name for s in p._vstages], "b": brecs}
    if vmetas and any(vmetas):
        header["vm"] = vmetas
    hb = json.dumps(header, sort_keys=True).encode()
    out = struct.pack("<I", len(hb)) + hb + body
    mem.record_copy(len(out))
    return out


class _LegacyReceiver:
    """Pre-refactor ContainerReceiver: parts list, join per item."""

    def __init__(self, decode_item, consume):
        self._parts = []
        self._size = 0
        self._decode = decode_item
        self._consume = consume

    def on_chunk(self, chunk):
        b = chunk.payload_bytes()
        self._parts.append(b)
        mem.record_alloc(len(b))
        self._size += len(b)
        if chunk.item_end:
            buf = b"".join(self._parts)
            mem.record_copy(len(buf))
            mem.record_alloc(len(buf))
            name, value, _ = self._decode(bytes(buf))
            mem.record_free(len(buf) + self._size)
            self._parts.clear()
            self._size = 0
            self._consume(name, value)


def run_legacy(stack, sd, tap: bool = False, decode_values: bool = True):
    p = pl.build_pipeline(list(stack), decode_values=decode_values)
    if tap:
        driver, sent = _wire_tap()
    else:
        driver, sent = sm.LoopbackDriver(), None
    decoder = p.decoder()
    sink = _FoldSink()

    def consume(name, value):
        if name != pl.META_ITEM:
            sink(name, value)

    recv = _LegacyReceiver(decoder.decode_item, consume)
    driver.connect(recv.on_chunk)
    msg = _message(sd)
    # no begin_encode batching: the legacy loop encoded item by item
    ctx = pl.WireContext(msg.headers, p.decode_values)
    for s in p.stages:
        if not isinstance(s, pl.QuantizeStage):
            msg = s.begin_encode(msg, ctx)
        else:
            ctx.headers["quantized_fmt"] = s._fmt_label()
    streamer = sm.ContainerStreamer(driver, CHUNK)

    def iter_items():
        yield pl.META_ITEM, ser.join_views(p._encode_meta(msg, ctx))
        for name, value in msg.payload.items():
            blob = _legacy_encode_item(p, name, value, ctx)
            with mem.record_hold(len(blob)):
                # pre-refactor chunking sliced bytes (a copy per chunk)
                parts = [bytes(memoryview(blob)[o:o + CHUNK])
                         for o in range(0, len(blob), CHUNK)]
                for part in parts:
                    mem.record_copy(len(part))
                yield name, parts

    streamer.send_items(iter_items(), p.n_items(msg))
    assert sink.items == len(sd)
    return bytes(sent) if tap else None


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def _time_path(fn, stack, sd, repeats, decode_values):
    meter = MemoryMeter()
    fn(stack, sd, decode_values=decode_values)  # warm jit caches untimed
    best = float("inf")
    with meter.activate():
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(stack, sd, decode_values=decode_values)
            # best-of-N: robust to scheduler noise on shared CI runners,
            # and equally generous to both paths
            best = min(best, time.perf_counter() - t0)
    return best, meter


def _bench_case(sname, stack, sd, repeats, decode_values=True):
    payload = sum(v.nbytes for v in sd.values())
    n_items = len(sd)
    # bitwise cross-check, outside the timed region
    assert run_new(stack, sd, tap=True) == run_legacy(stack, sd, tap=True), \
        f"wire bytes diverged on {sname}"
    per_new, m_new = _time_path(run_new, stack, sd, repeats, decode_values)
    per_old, m_old = _time_path(run_legacy, stack, sd, repeats, decode_values)
    rows = []
    for path, per, meter in (("new", per_new, m_new), ("legacy", per_old, m_old)):
        rows.append(
            f"wire/{sname}/{path},{per * 1e6:.0f},"
            f"items_per_s={n_items / per:.0f};"
            f"gbps={payload / per / 1e9:.3f};"
            f"copied={meter.copied // repeats};"
            f"alloc={meter.total_allocated // repeats}"
        )
    rows.append(
        f"wire/{sname}/speedup,0,"
        f"new_over_legacy={per_old / per_new:.2f};"
        f"copy_reduction={m_old.copied / max(m_new.copied, 1):.2f}"
    )
    return rows


def run(repeats: int = 5) -> list[str]:
    sd = model_dict()
    rows = []
    for sname, (stack, decode_values) in STACKS.items():
        rows.extend(_bench_case(sname, stack, sd, repeats,
                                decode_values=decode_values))
    # framing throughput on embedding-sized tensors: the regime where
    # the joins/copies the refactor removed were memcpy-bound
    big = {f"embed.{i}": np.random.default_rng(i).standard_normal(
        (2048, 2048)).astype(np.float32) for i in range(4)}  # 4 x 16 MiB
    rows.extend(_bench_case("plain-big", [], big, max(repeats // 2, 2)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
