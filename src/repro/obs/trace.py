"""Thread-safe span tracer with dual clocks and Chrome trace export.

One :class:`Tracer` records two kinds of timestamps into a single
bounded ring buffer (the flight recorder):

* **wall-clock spans/instants/counters** — ``perf_counter``-based, one
  Perfetto track per real thread (``pid`` :data:`PID_WALL`). These show
  where host time goes: pipeline stage encode/decode, kernel dispatch,
  socket writes.
* **simulated-clock spans/instants/counters** — explicit timestamps in
  simulated seconds from the event scheduler (``pid`` :data:`PID_SIM`),
  one track per client. These show the federation's *timeline*:
  downlink / compute / uplink segments per round trip, dropouts,
  queue depth.

Exported traces are Chrome trace-event JSON (the ``traceEvents`` array
format): load the file in https://ui.perfetto.dev or ``chrome://tracing``
and the two clocks appear as two processes, "wall clock" and
"simulated time". :func:`validate_chrome_trace` is the schema check the
test suite and CI run over every exported trace.

Activation mirrors :class:`repro.utils.mem.MemoryMeter`: a module-level
:data:`ACTIVE` slot, set by the :func:`activate` context manager. Hot
paths read ``trace.ACTIVE`` directly and branch once — when it is None
(the default) tracing costs one global load and an ``is None`` test,
with no allocation and no call. The :func:`span` helper exists for cool
paths only (round loops, settle waves), where a shared no-op context
manager is cheap enough.

The tracer is write-only during a run (append to a ``deque``, which is
atomic under the GIL; the thread-id map takes a lock on first sight of
a new thread), so worker threads trace concurrently without contention.
Nothing here reads the wall clock into *simulated* event times — tracing
cannot perturb a deterministic timeline.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from collections import deque
from collections.abc import Callable, Iterator
from typing import Any, Optional

#: Perfetto "process" ids for the two clocks
PID_WALL = 1
PID_SIM = 2

#: the active tracer; hot paths read this directly and branch on None
ACTIVE: Optional["Tracer"] = None


def active() -> Optional["Tracer"]:
    return ACTIVE


@contextlib.contextmanager
def activate(tracer: "Tracer") -> Iterator["Tracer"]:
    """Install ``tracer`` as the process-wide active tracer."""
    global ACTIVE
    prev = ACTIVE
    ACTIVE = tracer
    try:
        yield tracer
    finally:
        ACTIVE = prev


_NOOP = contextlib.nullcontext()


def span(name: str, cat: str = "", **args: Any) -> Any:
    """Cool-path helper: a span when tracing is on, a shared no-op
    context manager otherwise. Hot loops should read :data:`ACTIVE`
    once and branch instead (no call, no allocation when off)."""
    tr = ACTIVE
    return _NOOP if tr is None else tr.span(name, cat, **args)


class _Span:
    """One in-flight wall-clock span (context manager).

    ``args`` stays attached to the emitted event by reference, so a
    caller may still fill in late-known fields (byte counts) inside the
    ``with`` block after the traced call returned.
    """

    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_sim_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict[str, Any]) -> None:
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        sim = self._tracer.sim_clock
        self._sim_t0 = sim() if sim is not None else None
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> None:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        if self._sim_t0 is not None:
            self.args["sim_t"] = round(self._sim_t0, 9)
        tr._emit({
            "ph": "X",
            "name": self.name,
            "cat": self.cat or "span",
            "pid": PID_WALL,
            "tid": tr._wall_tid(),
            "ts": (self._t0 - tr._epoch_ns) / 1000.0,
            "dur": (t1 - self._t0) / 1000.0,
            "args": self.args,
        })


class Tracer:
    """Bounded flight recorder emitting Chrome trace events.

    ``capacity`` bounds the ring buffer: the newest events win, and the
    export reports how many older events were dropped. ``sim_clock``
    (bound by the simulator when the async scheduler runs) lets every
    wall-clock span also carry the simulated time at which it ran.
    """

    def __init__(self, capacity: int = 1 << 16,
                 sim_clock: Optional[Callable[[], float]] = None) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.sim_clock = sim_clock
        self._events: deque = deque(maxlen=capacity)
        self._epoch_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._tids: dict[tuple[int, str], int] = {}
        self._total = 0

    # -- bookkeeping --------------------------------------------------------
    def _emit(self, event: dict[str, Any]) -> None:
        self._total += 1          # benign race: a statistic, not an index
        self._events.append(event)

    def _tid(self, pid: int, label: str) -> int:
        key = (pid, label)
        tid = self._tids.get(key)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(key, len(self._tids) + 1)
        return tid

    def _wall_tid(self) -> int:
        return self._tid(PID_WALL, threading.current_thread().name)

    @property
    def total_events(self) -> int:
        return self._total

    @property
    def dropped(self) -> int:
        return self._total - len(self._events)

    def _wall_ts(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1000.0

    # -- wall-clock events --------------------------------------------------
    def span(self, name: str, cat: str = "", **args: Any) -> _Span:
        """A nested wall-clock span (context manager). Spans opened on
        one thread nest by containment on that thread's track."""
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        self._emit({
            "ph": "i", "name": name, "cat": cat or "instant",
            "pid": PID_WALL, "tid": self._wall_tid(),
            "ts": self._wall_ts(), "s": "t", "args": args,
        })

    def counter(self, name: str, value: float, cat: str = "") -> None:
        self._emit({
            "ph": "C", "name": name, "cat": cat or "counter",
            "pid": PID_WALL, "tid": 0,
            "ts": self._wall_ts(), "args": {"value": float(value)},
        })

    # -- simulated-clock events ---------------------------------------------
    def sim_span(self, name: str, t0_s: float, t1_s: float, track: str,
                 cat: str = "sim", **args: Any) -> None:
        """A span on the simulated timeline: ``[t0_s, t1_s]`` in
        simulated seconds on the named track (one track per client)."""
        self._emit({
            "ph": "X", "name": name, "cat": cat,
            "pid": PID_SIM, "tid": self._tid(PID_SIM, track),
            "ts": t0_s * 1e6, "dur": max(0.0, (t1_s - t0_s)) * 1e6,
            "args": args,
        })

    def sim_instant(self, name: str, t_s: float, track: str,
                    cat: str = "sim", **args: Any) -> None:
        self._emit({
            "ph": "i", "name": name, "cat": cat,
            "pid": PID_SIM, "tid": self._tid(PID_SIM, track),
            "ts": t_s * 1e6, "s": "t", "args": args,
        })

    def sim_counter(self, name: str, t_s: float, value: float) -> None:
        self._emit({
            "ph": "C", "name": name, "cat": "sim",
            "pid": PID_SIM, "tid": 0,
            "ts": t_s * 1e6, "args": {"value": float(value)},
        })

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict[str, Any]:
        """The flight recorder as a Chrome trace-event JSON object."""
        meta: list[dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": PID_WALL, "tid": 0,
             "args": {"name": "wall clock"}},
            {"ph": "M", "name": "process_name", "pid": PID_SIM, "tid": 0,
             "args": {"name": "simulated time"}},
        ]
        with self._lock:
            tids = dict(self._tids)
        for (pid, label), tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": label}})
        return {
            "traceEvents": meta + list(self._events),
            "displayTimeUnit": "ms",
            "otherData": {
                "total_events": self._total,
                "dropped_events": self.dropped,
                "capacity": self.capacity,
            },
        }

    def write(self, path: str) -> dict[str, Any]:
        """Serialize the trace to ``path``; returns a small summary."""
        obj = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump(obj, fh)
        return {"path": path, "events": len(obj["traceEvents"]),
                "dropped": self.dropped}


# ---------------------------------------------------------------------------
# Schema validation (tests + CI run this over every exported trace)
# ---------------------------------------------------------------------------

_KNOWN_PH = {"X", "B", "E", "i", "I", "C", "M"}
_TS_REQUIRED = {"X", "B", "E", "i", "I", "C"}


def _fail(i: int, ev: Any, why: str) -> None:
    raise ValueError(f"trace event {i} is not valid Chrome trace JSON: "
                     f"{why} (event: {ev!r})")


def validate_chrome_trace(obj: Any) -> int:
    """Assert ``obj`` is a valid Chrome trace-event JSON object (the
    ``traceEvents``-array form Perfetto ingests). Raises ``ValueError``
    on the first violation; returns the number of events checked."""
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        raise ValueError('a Chrome trace is an object with a "traceEvents" list')
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as exc:
        raise ValueError(f"trace is not JSON-serializable: {exc}") from exc
    for i, ev in enumerate(obj["traceEvents"]):
        if not isinstance(ev, dict):
            _fail(i, ev, "event is not an object")
        ph = ev.get("ph")
        if ph not in _KNOWN_PH:
            _fail(i, ev, f"unknown phase {ph!r}")
        if not isinstance(ev.get("name"), str):
            _fail(i, ev, "missing string name")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            _fail(i, ev, "pid/tid must be integers")
        if ph in _TS_REQUIRED:
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                _fail(i, ev, f"bad timestamp {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                _fail(i, ev, f"complete event needs a non-negative dur, got {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                isinstance(v, (int, float)) for v in args.values()
            ):
                _fail(i, ev, "counter event needs numeric args")
        if ph == "M" and ev["name"] in ("process_name", "thread_name"):
            args = ev.get("args")
            if not isinstance(args, dict) or not isinstance(args.get("name"), str):
                _fail(i, ev, "metadata event needs args.name")
        if "args" in ev and not isinstance(ev["args"], dict):
            _fail(i, ev, "args must be an object")
    return len(obj["traceEvents"])
