"""Labeled metrics registry with JSON-safe snapshots.

A :class:`MetricsRegistry` holds named series of three instrument
kinds — :class:`Counter` (monotone), :class:`Gauge` (last value wins)
and :class:`Histogram` (count/sum/min/max + log2 buckets) — each keyed
by name plus a small label set, Prometheus-style::

    reg = MetricsRegistry()
    reg.counter("wire.items", direction="up").inc()
    reg.gauge("sched.queue_depth").set(7)
    reg.histogram("wire.item_bytes").observe(36864)
    json.dumps(reg.snapshot())      # always JSON-safe

``snapshot()`` is the single export surface: the simulator publishes
``TrafficStats`` / ``MemoryMeter`` / ``RuntimeStats`` ``as_dict()``
exports into its per-run registry (:meth:`MetricsRegistry.publish`), and
``run_job`` / ``benchmarks/run.py --json`` embed the snapshot, so every
counter that used to live on an island travels in one schema.

Instruments are thread-safe (one lock per instrument; the registry lock
only guards series creation), matching the async runtime's concurrent
worker threads.

Activation mirrors :mod:`repro.obs.trace` and
:class:`repro.utils.mem.MemoryMeter`: a module-level :data:`ACTIVE`
slot set by the :func:`activate` context manager. Hot paths (the wire
encode-ahead loop) read ``metrics.ACTIVE`` once and branch on None, so
an inactive registry costs one global load per item.
"""
from __future__ import annotations

import contextlib
import math
import threading
from collections.abc import Iterator, Mapping
from typing import Any, Optional, Union

Number = Union[int, float]

#: the active registry; hot paths read this directly and branch on None
ACTIVE: Optional["MetricsRegistry"] = None


def active() -> Optional["MetricsRegistry"]:
    return ACTIVE


@contextlib.contextmanager
def activate(registry: "MetricsRegistry") -> Iterator["MetricsRegistry"]:
    """Install ``registry`` as the process-wide active registry, so
    instrumented hot paths (wire encode-ahead stalls, queue depths)
    record into the run that is currently executing."""
    global ACTIVE
    prev = ACTIVE
    ACTIVE = registry
    try:
        yield registry
    finally:
        ACTIVE = prev


def _series_key(name: str, labels: Mapping[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value: Number = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        with self._lock:
            self.value += n

    def as_value(self) -> Number:
        return self.value


class Gauge:
    """Last-written value."""

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value: Number = 0
        self._lock = threading.Lock()

    def set(self, v: Number) -> None:
        with self._lock:
            self.value = v

    def max(self, v: Number) -> None:
        """High-watermark update (keeps the larger of old and new)."""
        with self._lock:
            if v > self.value:
                self.value = v

    def as_value(self) -> Number:
        return self.value


class Histogram:
    """Streaming distribution summary: count / sum / min / max plus
    power-of-two buckets (bucket ``k`` counts observations in
    ``[2^(k-1), 2^k)``; zero and negatives land in bucket 0)."""

    __slots__ = ("count", "total", "min", "max", "buckets", "_lock")

    def __init__(self) -> None:
        self.count = 0
        self.total: float = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.buckets: dict[int, int] = {}
        self._lock = threading.Lock()

    def observe(self, v: Number) -> None:
        v = float(v)
        b = max(0, int(math.floor(math.log2(v))) + 1) if v > 0 else 0
        with self._lock:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self.buckets[b] = self.buckets.get(b, 0) + 1

    def as_value(self) -> dict[str, Any]:
        with self._lock:
            return {
                "count": self.count,
                "sum": self.total,
                "min": self.min,
                "max": self.max,
                "mean": (self.total / self.count) if self.count else None,
                "buckets": {str(k): v for k, v in sorted(self.buckets.items())},
            }


class MetricsRegistry:
    """Get-or-create registry of labeled metric series.

    The same ``(kind, name, labels)`` triple always returns the same
    instrument; asking for an existing name with a different kind is an
    error (one series, one meaning).
    """

    def __init__(self) -> None:
        self._series: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, cls: type, name: str, labels: Mapping[str, Any]) -> Any:
        key = _series_key(name, labels)
        inst = self._series.get(key)
        if inst is None:
            with self._lock:
                inst = self._series.setdefault(key, cls())
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {key!r} already registered as {type(inst).__name__}, "
                f"requested as {cls.__name__}"
            )
        return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels)

    def publish(self, prefix: str, values: Mapping[str, Any],
                **labels: Any) -> None:
        """Export a flat stats dict (e.g. ``TrafficStats.as_dict()``)
        as gauges named ``prefix.key``; non-numeric values are skipped."""
        for k, v in values.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.gauge(f"{prefix}.{k}", **labels).set(v)

    def snapshot(self) -> dict[str, Any]:
        """JSON-safe view of every series, grouped by instrument kind."""
        out: dict[str, dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {},
        }
        with self._lock:
            series = dict(self._series)
        for key, inst in sorted(series.items()):
            group = {Counter: "counters", Gauge: "gauges",
                     Histogram: "histograms"}[type(inst)]
            out[group][key] = inst.as_value()
        return out
