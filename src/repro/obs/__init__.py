"""Unified observability plane: span tracing + metrics registry.

Two small, dependency-free modules:

* :mod:`repro.obs.trace` — a thread-safe span tracer with **dual
  clocks** (wall clock and the scheduler's simulated clock), nested
  spans, a bounded ring-buffer flight recorder, and Chrome
  trace-event JSON export viewable in Perfetto / ``chrome://tracing``.
* :mod:`repro.obs.metrics` — a registry of labeled counters / gauges /
  histograms with JSON-safe snapshots; ``TrafficStats``,
  ``RuntimeStats`` and ``MemoryMeter`` publish into it instead of
  remaining islands.

The default-off path is near-zero-cost: hot layers guard every
instrumentation block on a single ``trace.ACTIVE is None`` check, so an
untraced run allocates nothing and pays one global load per guarded
site. Tracing is strictly observational — it never perturbs simulated
timelines or trained weights (a tested invariant).
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, activate, validate_chrome_trace

__all__ = ["MetricsRegistry", "Tracer", "activate", "validate_chrome_trace"]
