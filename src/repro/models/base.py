"""Model zoo foundation: configs, logical sharding axes, and the unified

model API every architecture implements:

* ``init(rng) -> params``                     (pytree of arrays)
* ``train_step_fn``-compatible ``loss(params, batch) -> scalar``
* ``prefill(params, batch) -> (logits, cache)``
* ``decode_step(params, cache, tokens) -> (logits, cache)``  (serve_step)
* ``param_axes() -> pytree of logical-axis tuples`` (same treedef as params)

Logical axis names are mapped to mesh axes by ``repro.launch.sharding``
(MaxText-style rules with divisibility fallback), so the same model code
runs on 1 CPU device (smoke tests) and the 512-chip production mesh
(dry-run) unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

# logical axis vocabulary -----------------------------------------------------
BATCH = "batch"
SEQ = "seq"
VOCAB = "vocab"
EMBED = "embed"          # d_model
Q_FEAT = "q_feat"        # flattened heads*head_dim
KV_FEAT = "kv_feat"      # flattened kv_heads*head_dim
MLP = "mlp"              # d_ff
EXPERT = "expert"        # MoE expert dim
LAYER = "layer"          # stacked-scan layer dim
CONV = "conv"            # conv/frontend feature dims (stubs)
STATE = "state"          # recurrent state feature dims


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True           # False -> sinusoidal absolute positions
    head_dim: Optional[int] = None
    # hybrid / recurrent details
    block_pattern: tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")
    local_window: int = 2048              # local-attention window (hybrid)
    rglru_width: Optional[int] = None     # RG-LRU recurrence width
    # long-context serving variant: replace full attention with
    # sliding-window attention of this size (beyond-paper option)
    sliding_window: Optional[int] = None
    # enc-dec / multimodal frontends (stubs provide embeddings directly)
    encoder_layers: int = 0
    encoder_seq: int = 0
    num_patches: int = 0
    # numerics
    param_dtype: Any = jnp.float32
    activ_dtype: Any = jnp.float32
    # training
    remat: bool = True
    z_loss: float = 1e-4
    aux_loss_coef: float = 0.01
    # citation (source paper / model card for the assigned config)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_feat(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_feat(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def with_overrides(self, **kw) -> ModelConfig:
        return dataclasses.replace(self, **kw)


def param_count(cfg: ModelConfig) -> int:
    """Closed-form parameter estimate (embedding + blocks + head)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    qf, kvf = cfg.q_feat, cfg.kv_feat
    attn = d * qf + 2 * d * kvf + qf * d
    if cfg.family == "moe":
        ffn = cfg.num_experts * 3 * d * f + d * cfg.num_experts  # experts + router
    else:
        ffn = 3 * d * f
    per_layer = attn + ffn + 2 * d
    return v * d * 2 + cfg.num_layers * per_layer + d


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only routed experts) for MODEL_FLOPS."""
    if cfg.family != "moe":
        return param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    qf, kvf = cfg.q_feat, cfg.kv_feat
    attn = d * qf + 2 * d * kvf + qf * d
    ffn = cfg.experts_per_token * 3 * d * f + d * cfg.num_experts
    per_layer = attn + ffn + 2 * d
    return cfg.vocab_size * d * 2 + cfg.num_layers * per_layer + d
