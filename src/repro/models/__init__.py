from typing import Any

from repro.models.base import ModelConfig, active_param_count, param_count
from repro.models.encdec import EncDecModel
from repro.models.rglru import GriffinModel
from repro.models.ssm import XLSTMModel
from repro.models.transformer import DecoderLM


def create_model(cfg: ModelConfig) -> Any:
    """Family dispatch. 'audio' backbones are enc-dec; 'vlm' backbones are

    decoders with a patch-embedding prefix (frontends are stubs per brief)."""
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        return GriffinModel(cfg)
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


__all__ = [
    "ModelConfig",
    "create_model",
    "param_count",
    "active_param_count",
    "DecoderLM",
    "XLSTMModel",
    "GriffinModel",
    "EncDecModel",
]
