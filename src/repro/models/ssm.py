"""xLSTM (sLSTM + mLSTM) blocks [arXiv:2405.04517].

mLSTM — matrix-memory LSTM with exponential gating. Three formulations,
all semantically identical (tests assert pairwise agreement):

* ``mlstm_step``      — O(1)-state recurrent step (decode path).
* ``mlstm_parallel``  — quadratic attention-like form (reference).
* ``mlstm_chunkwise`` — chunked parallel form: intra-chunk quadratic +
  inter-chunk recurrent state, the TPU-native training path (S x S never
  materializes; (Tc x Tc) tiles fit VMEM). This is the standard
  hardware-efficient mLSTM scheme adapted from the paper's CUDA kernels.

sLSTM — scalar-memory LSTM with exponential gating and block-diagonal
(per-head) recurrent weights; inherently sequential (paper §2.2), so both
train and decode use ``lax.scan`` over time.

All exponential gates are stabilized with a running max ``m`` as in the
paper's appendix.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import base as B
from repro.models import layers as L
from repro.models.layers import ParamDef


# ---------------------------------------------------------------------------
# mLSTM core math (per batch x head; feature dim hd)
# ---------------------------------------------------------------------------

def mlstm_step(
    state: tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    logi: jnp.ndarray, logf: jnp.ndarray,
) -> tuple[tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """One decode step. state = (C (...,hd,hd), n (...,hd), m (...,)).

    q,k,v: (..., hd); logi/logf: (...,) per-head scalars.
    """
    C, n, m = state
    m_new = jnp.maximum(logf + m, logi)
    a = jnp.exp(logf + m - m_new)[..., None, None]
    b = jnp.exp(logi - m_new)[..., None, None]
    C_new = a * C + b * (k[..., :, None] * v[..., None, :])
    n_new = a[..., 0] * n + b[..., 0] * k
    num = jnp.einsum("...h,...hv->...v", q, C_new)
    den = jnp.abs(jnp.einsum("...h,...h->...", q, n_new))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return (C_new, n_new, m_new), num / den


def mlstm_parallel(q, k, v, logi, logf):
    """Reference quadratic form. q,k,v: (B,H,S,hd); logi/logf: (B,H,S)."""
    S = q.shape[2]
    F = jnp.cumsum(logf, axis=-1)                          # (B,H,S)
    D = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(tri, D, -jnp.inf)
    m = jnp.max(D, axis=-1)                                # (B,H,S)
    E = jnp.exp(D - m[..., None])
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * E
    den = jnp.maximum(jnp.abs(jnp.sum(scores, axis=-1)), jnp.exp(-m))
    return jnp.einsum("bhst,bhtd->bhsd", scores, v) / den[..., None]


def mlstm_chunkwise(q, k, v, logi, logf, chunk: int = 256,
                    state: Optional[tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None):
    """Chunked parallel mLSTM. q,k,v: (B,H,S,hd); logi/logf: (B,H,S).

    Returns (h (B,H,S,hd), final_state). S must be a multiple of ``chunk``.
    """
    Bsz, H, S, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def to_chunks(x):
        if x.ndim > 3:
            return x.reshape(Bsz, H, nc, chunk, *x.shape[4:])
        return x.reshape(Bsz, H, nc, chunk)

    qc = q.reshape(Bsz, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(Bsz, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(Bsz, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    lic = logi.reshape(Bsz, H, nc, chunk).transpose(2, 0, 1, 3)
    lfc = logf.reshape(Bsz, H, nc, chunk).transpose(2, 0, 1, 3)

    if state is None:
        C0 = jnp.zeros((Bsz, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((Bsz, H, hd), jnp.float32)
        m0 = jnp.full((Bsz, H), -jnp.inf)
        state = (C0, n0, m0)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        C, n, m_prev = carry
        qt, kt, vt, li, lf = xs                            # (B,H,Tc,...)
        Lt = jnp.cumsum(lf, axis=-1)                       # (B,H,Tc) inclusive
        b_tot = Lt[..., -1]                                # (B,H)
        # intra-chunk decay matrix D_tj = L_t - L_j + logi_j  (t >= j)
        D = Lt[..., :, None] - Lt[..., None, :] + li[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                      # (B,H,Tc)
        # inter contribution enters at weight L_t + m_prev
        m_t = jnp.maximum(m_intra, Lt + m_prev[..., None])
        w_inter = jnp.exp(Lt + m_prev[..., None] - m_t)    # (B,H,Tc)
        E = jnp.exp(D - m_t[..., None])                    # (B,H,Tc,Tc)
        scores = jnp.einsum("bhsd,bhtd->bhst", qt, kt) * E
        num = (
            jnp.einsum("bhst,bhtd->bhsd", scores, vt)
            + w_inter[..., None] * jnp.einsum("bhsd,bhdv->bhsv", qt, C)
        )
        den = jnp.sum(scores, axis=-1) + w_inter * jnp.einsum("bhsd,bhd->bhs", qt, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = num / den[..., None]
        # state update to end of chunk
        w_state = b_tot[..., None] - Lt + li               # (B,H,Tc): b - L_j + logi_j
        m_new = jnp.maximum(b_tot + m_prev, jnp.max(w_state, axis=-1))
        decay_C = jnp.exp(b_tot + m_prev - m_new)[..., None, None]
        wk = jnp.exp(w_state - m_new[..., None])           # (B,H,Tc)
        C_new = decay_C * C + jnp.einsum("bhtd,bht,bhtv->bhdv", kt, wk, vt)
        n_new = decay_C[..., 0] * n + jnp.einsum("bhtd,bht->bhd", kt, wk)
        return (C_new, n_new, m_new), h

    final_state, hs = jax.lax.scan(body, state, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(Bsz, H, S, hd)
    return h, final_state


# ---------------------------------------------------------------------------
# mLSTM block (up-proj, causal conv, qkv, gates, out-gate, down-proj)
# ---------------------------------------------------------------------------

CONV_K = 4  # causal depthwise conv kernel width (paper's conv4)


def _mlstm_dims(cfg: B.ModelConfig) -> tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    return d_inner, H, d_inner // H


def mlstm_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    d_inner, H, hd = _mlstm_dims(cfg)
    return {
        "norm": L.norm_spec(d),
        "w_up": ParamDef((d, 2 * d_inner), (B.EMBED, B.MLP)),        # [x_m | z]
        "conv_w": ParamDef((CONV_K, d_inner), (None, B.MLP)),
        "wq": ParamDef((d_inner, d_inner), (B.MLP, B.Q_FEAT)),
        "wk": ParamDef((d_inner, d_inner), (B.MLP, B.Q_FEAT)),
        "wv": ParamDef((d_inner, d_inner), (B.MLP, B.Q_FEAT)),
        "w_i": ParamDef((d_inner, H), (B.MLP, None)),
        "b_i": ParamDef((H,), (None,), init="zeros"),
        "w_f": ParamDef((d_inner, H), (B.MLP, None)),
        "b_f": ParamDef((H,), (None,), init="zeros"),
        "out_norm": ParamDef((d_inner,), (B.MLP,), init="zeros"),
        "w_down": ParamDef((d_inner, d), (B.MLP, B.EMBED)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B,S,D); w: (K,D); prev: (B,K-1,D) state.

    Returns (y, new_prev)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return y, xp[:, -(K - 1) :]


def _mlstm_project(xm, p, cfg):
    """Shared q/k/v/gate projections. xm: (B,S,d_inner) post-conv input."""
    d_inner, H, hd = _mlstm_dims(cfg)
    Bsz, S, _ = xm.shape
    q = jnp.einsum("bsd,de->bse", xm, p["wq"].astype(xm.dtype)) / np.sqrt(hd)
    k = jnp.einsum("bsd,de->bse", xm, p["wk"].astype(xm.dtype)) / np.sqrt(hd)
    v = jnp.einsum("bsd,de->bse", xm, p["wv"].astype(xm.dtype))
    heads = lambda t: t.reshape(Bsz, S, H, hd).transpose(0, 2, 1, 3)
    logi = jnp.einsum("bsd,dh->bsh", xm, p["w_i"].astype(xm.dtype)) + p["b_i"].astype(xm.dtype)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", xm, p["w_f"].astype(xm.dtype)).astype(jnp.float32)
        + p["b_f"].astype(jnp.float32)
    )
    return (
        heads(q).astype(jnp.float32),
        heads(k).astype(jnp.float32),
        heads(v).astype(jnp.float32),
        logi.transpose(0, 2, 1).astype(jnp.float32),
        logf.transpose(0, 2, 1),
    )


def mlstm_block_forward(x: jnp.ndarray, p: dict[str, Any], cfg: B.ModelConfig,
                        chunk: int = 256) -> jnp.ndarray:
    d_inner, H, hd = _mlstm_dims(cfg)
    Bsz, S, _ = x.shape
    xin = L.rms_norm(x, p["norm"])
    up = jnp.einsum("bsd,de->bse", xin, p["w_up"].astype(x.dtype))
    xm_raw, z = jnp.split(up, 2, axis=-1)
    xm, _ = _causal_conv(xm_raw, p["conv_w"])
    xm = jax.nn.silu(xm)
    q, k, v, logi, logf = _mlstm_project(xm, p, cfg)
    c = min(chunk, S)
    if S % c != 0:
        c = S  # tiny smoke shapes: single chunk
    h, _ = mlstm_chunkwise(q, k, v, logi, logf, chunk=c)
    h = h.transpose(0, 2, 1, 3).reshape(Bsz, S, d_inner).astype(x.dtype)
    h = L.rms_norm(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(x.dtype))


def mlstm_init_state(cfg: B.ModelConfig, batch: int) -> dict[str, jnp.ndarray]:
    d_inner, H, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner), cfg.activ_dtype),
    }


def mlstm_block_decode(x, p, state, cfg):
    """x: (B,1,d)."""
    d_inner, H, hd = _mlstm_dims(cfg)
    Bsz = x.shape[0]
    xin = L.rms_norm(x, p["norm"])
    up = jnp.einsum("bsd,de->bse", xin, p["w_up"].astype(x.dtype))
    xm_raw, z = jnp.split(up, 2, axis=-1)
    xm, conv_new = _causal_conv(xm_raw, p["conv_w"], state["conv"])
    xm = jax.nn.silu(xm)
    q, k, v, logi, logf = _mlstm_project(xm, p, cfg)     # (B,H,1,hd)/(B,H,1)
    sq, sk, sv = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    (C, n, m), h = mlstm_step(
        (state["C"], state["n"], state["m"]), sq, sk, sv, logi[:, :, 0], logf[:, :, 0]
    )
    h = h.reshape(Bsz, 1, d_inner).astype(x.dtype)
    h = L.rms_norm(h, p["out_norm"]) * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(x.dtype))
    return out, {"C": C, "n": n, "m": m, "conv": conv_new}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, block-diagonal recurrence, post-FFN)
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: B.ModelConfig) -> tuple[int, int]:
    H = cfg.num_heads
    return H, cfg.d_model // H


def slstm_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    H, hd = _slstm_dims(cfg)
    f_in = int(round(4 * d / 3 / 64)) * 64  # pf 4/3, rounded to lanes
    # recurrent weights are deliberately REPLICATED (axes None): they are
    # tiny (H x hd x hd) and sharding them forces a per-timestep
    # reshard/psum inside the scan (perf iteration 2, EXPERIMENTS.md §Perf)
    gates = {
        name: {
            "w": ParamDef((d, d), (B.EMBED, B.Q_FEAT)),
            "r": ParamDef((H, hd, hd), (None, None, None)),
            "b": ParamDef((d,), (B.Q_FEAT,), init="zeros"),
        }
        for name in ("z", "i", "f", "o")
    }
    return {
        "norm": L.norm_spec(d),
        **gates,
        "out_norm": ParamDef((d,), (B.EMBED,), init="zeros"),
        "ffn_norm": L.norm_spec(d),
        "ffn": {
            "w_gate": ParamDef((d, f_in), (B.EMBED, B.MLP)),
            "w_up": ParamDef((d, f_in), (B.EMBED, B.MLP)),
            "w_down": ParamDef((f_in, d), (B.MLP, B.EMBED)),
        },
    }


def slstm_gate_x(xin: jnp.ndarray, p: dict[str, Any], cfg: B.ModelConfig) -> dict[str, jnp.ndarray]:
    """Hoisted input projections: one GEMM per gate over the WHOLE

    sequence, outside the time scan (cuDNN-LSTM-style; perf iteration 2).
    xin: (B,S,d) -> {name: (B,S,H,hd)}."""
    H, hd = _slstm_dims(cfg)
    Bsz, S, _ = xin.shape
    out = {}
    for name in ("z", "i", "f", "o"):
        g = jnp.einsum("bsd,de->bse", xin, p[name]["w"].astype(xin.dtype))
        g = g + p[name]["b"].astype(xin.dtype)
        out[name] = g.reshape(Bsz, S, H, hd)
    return out


def _slstm_cell(state, gx_t, p, cfg):
    """state: dict(c,n,h,m) each (B,H,hd). gx_t: {name: (B,H,hd)} hoisted

    input-projection slices; only the recurrent (h-dependent) part runs
    inside the scan."""
    h_prev = state["h"]                                   # (B,H,hd)
    dtype = gx_t["z"].dtype

    def gate(name):
        r = p[name]["r"]
        gh = jnp.einsum("bhk,hkl->bhl", h_prev.astype(dtype), r.astype(dtype))
        return (gx_t[name] + gh).astype(jnp.float32)

    z = jnp.tanh(gate("z"))
    o = jax.nn.sigmoid(gate("o"))
    logi = gate("i")
    logf = jax.nn.log_sigmoid(gate("f"))
    m_new = jnp.maximum(logf + state["m"], logi)
    i_s = jnp.exp(logi - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * z
    n = f_s * state["n"] + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    # keep the per-step state (and hence the stacked scan residuals)
    # batch-sharded — without this GSPMD shards (B,H,hd) on heads only and
    # every device carries the FULL batch of residuals (§Perf pair 2)
    cstr = lambda t: L.constrain(t, (B.BATCH, None, None))
    return {"c": cstr(c), "n": cstr(n), "h": cstr(h), "m": cstr(m_new)}


def slstm_init_state(cfg: B.ModelConfig, batch: int) -> dict[str, jnp.ndarray]:
    H, hd = _slstm_dims(cfg)
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, H, hd), -1e30)}


def slstm_block_forward(x: jnp.ndarray, p: dict[str, Any], cfg: B.ModelConfig) -> jnp.ndarray:
    Bsz, S, d = x.shape
    H, hd = _slstm_dims(cfg)
    xin = L.rms_norm(x, p["norm"])
    gx = slstm_gate_x(xin, p, cfg)  # hoisted GEMMs, (B,S,H,hd) per gate
    gx_t = jax.tree_util.tree_map(lambda g: g.transpose(1, 0, 2, 3), gx)

    def step(state, gx_slice):
        new = _slstm_cell(state, gx_slice, p, cfg)
        return new, new["h"]

    _, hs = jax.lax.scan(step, slstm_init_state(cfg, Bsz), gx_t)
    h = hs.transpose(1, 0, 2, 3).reshape(Bsz, S, d).astype(x.dtype)
    x = x + L.rms_norm(h, p["out_norm"])
    h = L.rms_norm(x, p["ffn_norm"])
    g = jnp.einsum("bsd,df->bsf", h, p["ffn"]["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", h, p["ffn"]["w_up"].astype(x.dtype))
    return x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["ffn"]["w_down"].astype(x.dtype))


def slstm_block_decode(x, p, state, cfg):
    Bsz, _, d = x.shape
    xin = L.rms_norm(x, p["norm"])
    gx = slstm_gate_x(xin, p, cfg)
    new = _slstm_cell(state, {k: v[:, 0] for k, v in gx.items()}, p, cfg)
    h = new["h"].reshape(Bsz, 1, d).astype(x.dtype)
    x = x + L.rms_norm(h, p["out_norm"])
    hh = L.rms_norm(x, p["ffn_norm"])
    g = jnp.einsum("bsd,df->bsf", hh, p["ffn"]["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", hh, p["ffn"]["w_up"].astype(x.dtype))
    out = x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["ffn"]["w_down"].astype(x.dtype))
    return out, new


# ---------------------------------------------------------------------------
# xLSTM model: scan over (mLSTM, sLSTM) super-blocks
# ---------------------------------------------------------------------------

class XLSTMModel:
    def __init__(self, cfg: B.ModelConfig) -> None:
        assert cfg.family == "ssm"
        assert cfg.num_layers % 2 == 0, "xLSTM super-block = (mLSTM, sLSTM)"
        self.cfg = cfg
        self.n_super = cfg.num_layers // 2
        super_spec = {"mlstm": mlstm_spec(cfg), "slstm": slstm_spec(cfg)}
        self._spec = {
            "embed": L.embed_spec(cfg),
            "blocks": L.stack_spec(super_spec, self.n_super),
        }

    def init(self, rng: jax.Array) -> dict[str, Any]:
        return L.build_params(rng, self._spec, self.cfg.param_dtype)

    def param_axes(self) -> dict[str, Any]:
        return L.build_axes(self._spec)

    def forward(self, params, tokens, patches=None):
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)

        def body(x, bp):
            x = mlstm_block_forward(x, bp["mlstm"], cfg)
            x = slstm_block_forward(x, bp["slstm"], cfg)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return L.lm_logits(x, params["embed"]), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        lm = L.causal_lm_loss(logits[:, :-1], batch["labels"][:, 1:], self.cfg.z_loss)
        return lm, {"lm_loss": lm, "aux_loss": jnp.float32(0.0)}

    # -- serving (O(1) state; no KV cache — the long_500k native path) ------
    def init_cache(self, batch: int, max_len: int) -> dict[str, Any]:
        del max_len
        cfg = self.cfg
        one = {
            "mlstm": mlstm_init_state(cfg, batch),
            "slstm": slstm_init_state(cfg, batch),
        }
        states = [one for _ in range(self.n_super)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)

    def cache_axes(self) -> dict[str, Any]:
        Lx, Bx, ST, MLP = B.LAYER, B.BATCH, B.STATE, B.MLP
        return {
            "mlstm": {
                "C": (Lx, Bx, None, ST, None),
                "n": (Lx, Bx, None, ST),
                "m": (Lx, Bx, None),
                "conv": (Lx, Bx, None, MLP),
            },
            "slstm": {
                "c": (Lx, Bx, None, ST),
                "n": (Lx, Bx, None, ST),
                "h": (Lx, Bx, None, ST),
                "m": (Lx, Bx, None, ST),
            },
        }

    def prefill(self, params, tokens, patches=None):
        """Recurrent prefill: run the sequence, return last logits + state."""
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        Bsz, S, d = x.shape

        def body(x, bp):
            # chunkwise mLSTM with state capture
            xin = L.rms_norm(x, bp["mlstm"]["norm"])
            up = jnp.einsum("bsd,de->bse", xin, bp["mlstm"]["w_up"].astype(x.dtype))
            xm_raw, z = jnp.split(up, 2, axis=-1)
            xm, conv_state = _causal_conv(xm_raw, bp["mlstm"]["conv_w"])
            xm = jax.nn.silu(xm)
            q, k, v, logi, logf = _mlstm_project(xm, bp["mlstm"], cfg)
            c = 256 if S % 256 == 0 else S
            h, (C, n, m) = mlstm_chunkwise(q, k, v, logi, logf, chunk=c)
            d_inner = 2 * cfg.d_model
            h = h.transpose(0, 2, 1, 3).reshape(Bsz, S, d_inner).astype(x.dtype)
            h = L.rms_norm(h, bp["mlstm"]["out_norm"]) * jax.nn.silu(z)
            x = x + jnp.einsum("bse,ed->bsd", h, bp["mlstm"]["w_down"].astype(x.dtype))
            mlstm_state = {"C": C, "n": n, "m": m, "conv": conv_state}
            # sLSTM scan with final state capture (hoisted input GEMMs)
            xin = L.rms_norm(x, bp["slstm"]["norm"])
            gx = slstm_gate_x(xin, bp["slstm"], cfg)
            gx_t = jax.tree_util.tree_map(lambda g: g.transpose(1, 0, 2, 3), gx)

            def step(state, gx_slice):
                new = _slstm_cell(state, gx_slice, bp["slstm"], cfg)
                return new, new["h"]

            sfinal, hs = jax.lax.scan(step, slstm_init_state(cfg, Bsz), gx_t)
            h = hs.transpose(1, 0, 2, 3).reshape(Bsz, S, d).astype(x.dtype)
            x = x + L.rms_norm(h, bp["slstm"]["out_norm"])
            hh = L.rms_norm(x, bp["slstm"]["ffn_norm"])
            g = jnp.einsum("bsd,df->bsf", hh, bp["slstm"]["ffn"]["w_gate"].astype(x.dtype))
            u = jnp.einsum("bsd,df->bsf", hh, bp["slstm"]["ffn"]["w_up"].astype(x.dtype))
            x = x + jnp.einsum(
                "bsf,fd->bsd", jax.nn.silu(g) * u, bp["slstm"]["ffn"]["w_down"].astype(x.dtype)
            )
            return x, {"mlstm": mlstm_state, "slstm": sfinal}

        if cfg.remat:
            body = jax.checkpoint(body)
        x, states = jax.lax.scan(body, x, params["blocks"])
        logits = L.lm_logits(x[:, -1:], params["embed"])
        return logits, states

    def decode_step(self, params, cache, tokens, pos):
        del pos  # recurrent state is position-free
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)

        def body(x, inp):
            bp, st = inp
            x, m_new = mlstm_block_decode(x, bp["mlstm"], st["mlstm"], cfg)
            x, s_new = slstm_block_decode(x, bp["slstm"], st["slstm"], cfg)
            return x, {"mlstm": m_new, "slstm": s_new}

        x, new_states = jax.lax.scan(body, x, (params["blocks"], cache))
        return L.lm_logits(x, params["embed"]), new_states
