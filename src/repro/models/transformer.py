"""Decoder-only transformer assembly (dense, MoE, VLM-backbone).

One scanned super-layer = attention + (MLP | MoE). All per-layer params
are stacked on a leading ``layer`` axis and the forward is a
``jax.lax.scan``, keeping HLO size O(1) in depth — essential for the
40-pair dry-run sweep (DESIGN.md §4). The VLM family is this same decoder
consuming stub patch embeddings as a prefix (the carve-out in the brief).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import base as B
from repro.models import layers as L
from repro.models import moe as M


def _block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    spec: dict[str, Any] = {
        "attn_norm": L.norm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.norm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        spec["moe"] = M.moe_spec(cfg)
    else:
        spec["mlp"] = L.mlp_spec(cfg)
    return spec


def _block_forward(
    x: jnp.ndarray, bp: dict[str, Any], cfg: B.ModelConfig, *, window: Optional[int]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = L.attn_forward(L.rms_norm(x, bp["attn_norm"]), bp["attn"], cfg, causal=True, window=window)
    x = x + h
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        h, aux = M.moe_forward(L.rms_norm(x, bp["mlp_norm"]), bp["moe"], cfg)
    else:
        h = L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
    return x + h, aux


def _block_decode(
    x: jnp.ndarray,
    bp: dict[str, Any],
    cache: dict[str, jnp.ndarray],
    pos: jnp.ndarray,
    cfg: B.ModelConfig,
    *,
    window: Optional[int],
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    h, new_cache = L.attn_decode(
        L.rms_norm(x, bp["attn_norm"]), bp["attn"], cache, pos, cfg, window=window
    )
    x = x + h
    if cfg.family == "moe":
        h, _ = M.moe_forward(L.rms_norm(x, bp["mlp_norm"]), bp["moe"], cfg)
    else:
        h = L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
    return x + h, new_cache


class DecoderLM:
    """dense | moe | vlm families."""

    def __init__(self, cfg: B.ModelConfig) -> None:
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        self.cfg = cfg
        self._spec = {
            "embed": L.embed_spec(cfg),
            "blocks": L.stack_spec(_block_spec(cfg), cfg.num_layers),
        }

    # -- params ------------------------------------------------------------
    def init(self, rng: jax.Array) -> dict[str, Any]:
        return L.build_params(rng, self._spec, self.cfg.param_dtype)

    def param_axes(self) -> dict[str, Any]:
        return L.build_axes(self._spec)

    # -- forward / loss ------------------------------------------------------
    def _backbone(self, params: dict[str, Any], x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        window = cfg.sliding_window

        def body(carry, bp):
            x, aux = carry
            x, a = _block_forward(x, bp, cfg, window=window)
            return (x, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
        return x, aux

    def forward(
        self,
        params: dict[str, Any],
        tokens: jnp.ndarray,
        patches: Optional[jnp.ndarray] = None,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        n_prefix = 0
        if patches is not None:
            x = jnp.concatenate([patches.astype(cfg.activ_dtype), x], axis=1)
            n_prefix = patches.shape[1]
        x, aux = self._backbone(params, x)
        logits = L.lm_logits(x[:, n_prefix:], params["embed"])
        return logits, aux

    def loss(
        self, params: dict[str, Any], batch: dict[str, jnp.ndarray]
    ) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch["tokens"], batch.get("patches"))
        lm = L.causal_lm_loss(logits[:, :-1], batch["labels"][:, 1:], cfg.z_loss)
        total = lm + cfg.aux_loss_coef * aux
        return total, {"lm_loss": lm, "aux_loss": aux}

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict[str, Any]:
        cfg = self.cfg
        window = cfg.sliding_window

        def one_layer(_):
            if window is not None:
                return L.init_window_cache(cfg, batch, min(window, max_len), cfg.activ_dtype)
            return L.init_full_cache(cfg, batch, max_len, cfg.activ_dtype)

        # stacked over layers
        caches = [one_layer(i) for i in range(cfg.num_layers)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)

    def cache_axes(self) -> dict[str, Any]:
        """Logical axes for the decode cache (mirrors init_cache)."""
        base = {
            "k": (B.LAYER, B.BATCH, B.SEQ, B.KV_FEAT),
            "v": (B.LAYER, B.BATCH, B.SEQ, B.KV_FEAT),
        }
        if self.cfg.sliding_window is not None:
            base["pos"] = (B.LAYER, B.BATCH, B.SEQ)
        return base

    def prefill(
        self,
        params: dict[str, Any],
        tokens: jnp.ndarray,
        patches: Optional[jnp.ndarray] = None,
    ) -> tuple[jnp.ndarray, dict[str, Any]]:
        """Run the full prompt, returning last-position logits and a cache

        sized to the prompt (decode continues from pos = S)."""
        cfg = self.cfg
        window = cfg.sliding_window
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        n_prefix = 0
        if patches is not None:
            x = jnp.concatenate([patches.astype(cfg.activ_dtype), x], axis=1)
            n_prefix = patches.shape[1]
        bsz, s, _ = x.shape

        def body(x, bp):
            xin = L.rms_norm(x, bp["attn_norm"])
            positions = jnp.arange(s)[None, :]
            q, k, v = L._project_qkv(xin, bp["attn"], cfg, positions)
            out = L.sdpa_or_flash(q, k, v, cfg, causal=True, window=window)
            h = jnp.einsum("bsf,fd->bsd", out, bp["attn"]["wo"].astype(x.dtype))
            x = x + h
            if cfg.family == "moe":
                h, _ = M.moe_forward(L.rms_norm(x, bp["mlp_norm"]), bp["moe"], cfg)
            else:
                h = L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
            x = x + h
            kvf = cfg.kv_feat
            k_flat = k.reshape(bsz, s, kvf).astype(cfg.activ_dtype)
            v_flat = v.reshape(bsz, s, kvf).astype(cfg.activ_dtype)
            if window is not None:
                w = min(window, s)
                cache = {
                    "k": k_flat[:, -w:],
                    "v": v_flat[:, -w:],
                    "pos": jnp.broadcast_to(jnp.arange(s - w, s, dtype=jnp.int32)[None], (bsz, w)),
                }
            else:
                cache = {"k": k_flat, "v": v_flat}
            return x, cache

        if cfg.remat:
            body = jax.checkpoint(body)
        x, caches = jax.lax.scan(body, x, params["blocks"])
        logits = L.lm_logits(x[:, -1:], params["embed"])
        return logits, caches

    def decode_step(
        self,
        params: dict[str, Any],
        cache: dict[str, Any],
        tokens: jnp.ndarray,
        pos: jnp.ndarray,
    ) -> tuple[jnp.ndarray, dict[str, Any]]:
        """serve_step: one new token for the whole batch. tokens: (B, 1)."""
        cfg = self.cfg
        window = cfg.sliding_window
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)

        def body(x, inp):
            bp, cache_l = inp
            x, new_cache = _block_decode(x, bp, cache_l, pos, cfg, window=window)
            return x, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], cache))
        logits = L.lm_logits(x, params["embed"])
        return logits, new_caches
