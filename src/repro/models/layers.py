"""Shared neural building blocks: parameter specs, norms, RoPE, GQA

attention (train / prefill / decode, full- and sliding-window), and gated
MLPs. Everything is functional (params are plain dicts) and every
parameter's logical sharding axes come from the same spec that built it —
a single source of truth consumed by ``repro.launch.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import base as B


# ---------------------------------------------------------------------------
# parameter specs: one definition -> params + logical axes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def build_params(rng: jax.Array, spec: dict[str, Any], dtype) -> dict[str, Any]:
    flat: dict[str, ParamDef] = {}

    def collect(node, path):
        if isinstance(node, ParamDef):
            flat[path] = node
        else:
            for k, v in node.items():
                collect(v, f"{path}/{k}" if path else k)

    collect(spec, "")
    keys = jax.random.split(rng, max(len(flat), 1))
    arrays: dict[str, jnp.ndarray] = {}
    for (path, pd), key in zip(sorted(flat.items()), keys):
        if pd.init == "zeros":
            arr = jnp.zeros(pd.shape, dtype)
        elif pd.init == "ones":
            arr = jnp.ones(pd.shape, dtype)
        else:
            arr = (jax.random.normal(key, pd.shape, jnp.float32) * pd.scale).astype(dtype)
        arrays[path] = arr

    def rebuild(node, path):
        if isinstance(node, ParamDef):
            return arrays[path]
        return {k: rebuild(v, f"{path}/{k}" if path else k) for k, v in node.items()}

    return rebuild(spec, "")


def build_axes(spec: dict[str, Any]) -> dict[str, Any]:
    if isinstance(spec, ParamDef):
        return spec.axes
    return {k: build_axes(v) for k, v in spec.items()}


def stacked(pd: ParamDef, num: int) -> ParamDef:
    """Prepend a scanned-layer dim."""
    return ParamDef((num,) + pd.shape, (B.LAYER,) + pd.axes, pd.init, pd.scale)


def stack_spec(spec: dict[str, Any], num: int) -> dict[str, Any]:
    if isinstance(spec, ParamDef):
        return stacked(spec, num)
    return {k: stack_spec(v, num) for k, v in spec.items()}


# ---------------------------------------------------------------------------
# activation-sharding context (set by the launcher; no-op in smoke tests)
# ---------------------------------------------------------------------------

_SHARD_CTX: Optional[tuple[Any, dict[str, tuple[str, ...]]]] = None


def set_sharding_context(mesh, rules) -> None:
    """Install (mesh, logical->mesh rules) so model code can constrain

    activations. Called by launch.dryrun/train around lowering; smoke
    tests leave it unset and every constraint is a no-op."""
    global _SHARD_CTX
    _SHARD_CTX = None if mesh is None else (mesh, rules)


def _mesh_axis_size(axis: str) -> int:
    if _SHARD_CTX is None:
        return 1
    mesh, rules = _SHARD_CTX
    size = 1
    for m in rules.get(axis, ()):
        if m in mesh.axis_names:
            size *= mesh.shape[m]
    return size


def constrain(x: jnp.ndarray, axes: tuple[Optional[str], ...]) -> jnp.ndarray:
    """with_sharding_constraint by logical axes (divisibility-safe).

    REPRO_DISABLE_ACT_CONSTRAINTS=1 disables all activation constraints —
    used to re-measure pre-optimization baselines (§Perf)."""
    import os as _os

    if _SHARD_CTX is None or _os.environ.get("REPRO_DISABLE_ACT_CONSTRAINTS"):
        return x
    from jax.sharding import NamedSharding

    from repro.launch.sharding import spec_for

    mesh, rules = _SHARD_CTX
    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_heads_qkv(q, k, v, cfg) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pick the attention parallelism by divisibility (perf iteration 1,

    EXPERIMENTS.md §Perf): shard heads over `model` when the head count
    divides; otherwise fall back to **context parallelism** — q sharded
    over seq, k/v replicated over `model` — which keeps 40-head archs
    (qwen2.5-32b, llama4-scout) from GSPMD's replicate-and-repartition
    path. Decode (s == 1) uses heads-or-nothing.
    """
    model_sz = _mesh_axis_size(B.Q_FEAT)
    s = q.shape[1]
    if model_sz <= 1:
        return q, k, v
    if cfg.num_heads % model_sz == 0 and cfg.num_kv_heads % model_sz == 0:
        q = constrain(q, (B.BATCH, None, B.Q_FEAT, None))
        k = constrain(k, (B.BATCH, None, B.KV_FEAT, None))
        v = constrain(v, (B.BATCH, None, B.KV_FEAT, None))
    elif s > 1 and s % model_sz == 0:
        q = constrain(q, (B.BATCH, B.Q_FEAT, None, None))  # seq-sharded
        k = constrain(k, (B.BATCH, None, None, None))
        v = constrain(v, (B.BATCH, None, None, None))
    else:
        q = constrain(q, (B.BATCH, None, None, None))
        k = constrain(k, (B.BATCH, None, None, None))
        v = constrain(v, (B.BATCH, None, None, None))
    return q, k, v


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dtype)


def norm_spec(d: int) -> ParamDef:
    return ParamDef((d,), (B.EMBED,), init="zeros")


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(
    positions: jnp.ndarray, head_dim: int, theta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int -> cos/sin of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, n, head_dim); cos/sin: (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d, qf, kvf = cfg.d_model, cfg.q_feat, cfg.kv_feat
    spec: dict[str, Any] = {
        "wq": ParamDef((d, qf), (B.EMBED, B.Q_FEAT)),
        "wk": ParamDef((d, kvf), (B.EMBED, B.KV_FEAT)),
        "wv": ParamDef((d, kvf), (B.EMBED, B.KV_FEAT)),
        "wo": ParamDef((qf, d), (B.Q_FEAT, B.EMBED)),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamDef((qf,), (B.Q_FEAT,), init="zeros")
        spec["bk"] = ParamDef((kvf,), (B.KV_FEAT,), init="zeros")
        spec["bv"] = ParamDef((kvf,), (B.KV_FEAT,), init="zeros")
    return spec


def _project_qkv(x, p, cfg: B.ModelConfig, positions):
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,df->bsf", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,df->bsf", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(bsz, s, cfg.num_heads, hd)
    k = k.reshape(bsz, s, cfg.num_kv_heads, hd)
    v = v.reshape(bsz, s, cfg.num_kv_heads, hd)
    if cfg.use_rope:
        cos, sin = rope_table(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return constrain_heads_qkv(q, k, v, cfg)


def sinusoidal_positions(s: int, d: int, dtype) -> jnp.ndarray:
    """Classic transformer sinusoidal table (whisper-style encoders)."""
    pos = np.arange(s)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


def _sdpa(q, k, v, mask, cfg: B.ModelConfig):
    """q: (b,s,H,hd); k,v: (b,t,KV,hd); mask: (b,1,1,s,t) or broadcastable."""
    bsz, s, H, hd = q.shape
    t = k.shape[1]
    KV = cfg.num_kv_heads
    G = H // KV
    qg = q.reshape(bsz, s, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(bsz, s, H * hd)


def sdpa_or_flash(q, k, v, cfg: B.ModelConfig, *, causal: bool, window: Optional[int]):
    """Full-sequence attention; routes to the flash Pallas kernel on TPU

    (O(S) HBM traffic — §Perf pair 1 iteration 2), masked jnp softmax
    elsewhere. Shapes: q (b,s,H,hd); k,v (b,t,KV,hd)."""
    from repro.kernels import ops as kops
    from repro.kernels.flash_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q

    bsz, s, H, hd = q.shape
    t = k.shape[1]
    if (
        kops.get_backend() == "pallas"
        and s % DEFAULT_BLOCK_Q == 0
        and t % DEFAULT_BLOCK_K == 0
    ):
        from repro.kernels.flash_attention import flash_attention_pallas

        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            window=window,
        )
        return out.transpose(0, 2, 1, 3).reshape(bsz, s, H * hd)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool) if not causal else (j <= i)
    if window is not None:
        mask = mask & (i - j < window)
    return _sdpa(q, k, v, mask[None, None, None], cfg)


def attn_forward(
    x: jnp.ndarray,
    p: dict[str, jnp.ndarray],
    cfg: B.ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Training / prefill attention over a full sequence."""
    bsz, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(x, p, cfg, positions)
    out = sdpa_or_flash(q, k, v, cfg, causal=causal, window=window)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(x.dtype))


# -- decode caches -----------------------------------------------------------

def init_full_cache(cfg: B.ModelConfig, batch: int, max_len: int, dtype) -> dict[str, jnp.ndarray]:
    kvf = cfg.kv_feat
    return {
        "k": jnp.zeros((batch, max_len, kvf), dtype),
        "v": jnp.zeros((batch, max_len, kvf), dtype),
    }


def init_window_cache(cfg: B.ModelConfig, batch: int, window: int, dtype) -> dict[str, jnp.ndarray]:
    kvf = cfg.kv_feat
    return {
        "k": jnp.zeros((batch, window, kvf), dtype),
        "v": jnp.zeros((batch, window, kvf), dtype),
        "pos": jnp.full((batch, window), -1, jnp.int32),  # absolute positions stored
    }


def attn_decode(
    x: jnp.ndarray,
    p: dict[str, jnp.ndarray],
    cache: dict[str, jnp.ndarray],
    pos: jnp.ndarray,
    cfg: B.ModelConfig,
    *,
    window: Optional[int] = None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """One-token decode step. x: (b, 1, d); pos: scalar int32 (current index).

    Full cache: writes k/v at ``pos`` and attends over [0, pos].
    Window cache: writes at ``pos % window`` (rolling) and attends over the
    stored absolute positions — O(window) memory for any context length.
    """
    bsz, one, _ = x.shape
    assert one == 1
    hd = cfg.resolved_head_dim
    positions = jnp.full((bsz, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    kvf = cfg.kv_feat
    k_flat = k_new.reshape(bsz, 1, kvf)
    v_flat = v_new.reshape(bsz, 1, kvf)
    if window is None:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_flat.astype(cache["k"].dtype), (0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_flat.astype(cache["v"].dtype), (0, pos, 0))
        t = k_cache.shape[1]
        mask = (jnp.arange(t) <= pos)[None, None, None, None, :]  # (1,1,1,1,t)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        slot = pos % window
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_flat.astype(cache["k"].dtype), (0, slot, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_flat.astype(cache["v"].dtype), (0, slot, 0))
        pos_cache = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((bsz, 1), pos, jnp.int32), (0, slot)
        )
        valid = (pos_cache >= 0) & (pos_cache <= pos) & (pos - pos_cache < window)
        mask = valid[:, None, None, None, :]  # (b,1,1,1,w)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    t = new_cache["k"].shape[1]
    k_all = new_cache["k"].reshape(bsz, t, cfg.num_kv_heads, hd).astype(x.dtype)
    v_all = new_cache["v"].reshape(bsz, t, cfg.num_kv_heads, hd).astype(x.dtype)
    out = _sdpa(q, k_all, v_all, mask, cfg)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(x.dtype)), new_cache


# -- cross attention (enc-dec) ------------------------------------------------

def cross_attn_forward(
    x: jnp.ndarray,
    memory: jnp.ndarray,
    p: dict[str, jnp.ndarray],
    cfg: B.ModelConfig,
    kv: Optional[tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Decoder cross-attention. q from ``x`` (b,s,d); k/v from ``memory``

    (b,t,d) — or from precomputed ``kv`` (decode path). No mask, no rope.
    Returns (out, (k, v)) so prefill can cache the projected memory.
    """
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(x.dtype)).reshape(bsz, s, cfg.num_heads, hd)
    if kv is None:
        t = memory.shape[1]
        k = jnp.einsum("btd,df->btf", memory, p["wk"].astype(x.dtype)).reshape(
            bsz, t, cfg.num_kv_heads, hd
        )
        v = jnp.einsum("btd,df->btf", memory, p["wv"].astype(x.dtype)).reshape(
            bsz, t, cfg.num_kv_heads, hd
        )
    else:
        k, v = kv
    mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), mask, cfg)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(x.dtype)), (k, v)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), (B.EMBED, B.MLP)),
        "w_up": ParamDef((d, f), (B.EMBED, B.MLP)),
        "w_down": ParamDef((f, d), (B.MLP, B.EMBED)),
    }


def mlp_forward(x: jnp.ndarray, p: dict[str, jnp.ndarray]) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    return {
        "embedding": ParamDef((cfg.vocab_size, cfg.d_model), (B.VOCAB, B.EMBED), scale=1.0),
        "lm_head": ParamDef((cfg.d_model, cfg.vocab_size), (B.EMBED, B.VOCAB)),
        "final_norm": norm_spec(cfg.d_model),
    }


def embed_tokens(tokens: jnp.ndarray, p: dict[str, jnp.ndarray], dtype) -> jnp.ndarray:
    return p["embedding"].astype(dtype)[tokens]


def lm_logits(x: jnp.ndarray, p: dict[str, jnp.ndarray]) -> jnp.ndarray:
    x = rms_norm(x, p["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, p["lm_head"].astype(x.dtype))


# ---------------------------------------------------------------------------
# LoRA adapters (parameter-efficient payloads)
# ---------------------------------------------------------------------------

def _lora_eligible(pd: ParamDef, rank: int) -> bool:
    if len(pd.shape) != 2:
        return False
    m, n = pd.shape
    return rank <= min(m, n) and rank * (m + n) < m * n


def lora_adapter_spec(spec: dict[str, Any], rank: int) -> dict[str, Any]:
    """The adapter ParamDef tree for a base parameter spec: every
    eligible 2-D matrix (rank fits, factors beat the dense form) maps to
    an ``{"a", "b"}`` factor pair carrying the base spec's sharding axes
    on its outer dims. ``b`` is zero-initialized, so a freshly built
    adapter contributes an exactly-zero delta — standard LoRA init.
    Norms, biases, and stacked (3-D) tensors are left out: those ship
    dense (the ``lora`` wire stage skips them for the same reason)."""
    out: dict[str, Any] = {}
    for k, v in spec.items():
        if isinstance(v, ParamDef):
            if _lora_eligible(v, rank):
                m, n = v.shape
                out[k] = {
                    "a": ParamDef((m, rank), (v.axes[0], None)),
                    "b": ParamDef((rank, n), (None, v.axes[1]), init="zeros"),
                }
        else:
            sub = lora_adapter_spec(v, rank)
            if sub:
                out[k] = sub
    return out


def lora_adapter_params(
    rng: jax.Array, spec: dict[str, Any], rank: int,
    dtype=jnp.float32, alpha: Optional[float] = None,
) -> dict[str, Any]:
    """Native-adapter mode: trainable LoRA pairs as a **flat** dict of
    :class:`~repro.peft.lowrank.LowRankDelta`, keyed by the base
    parameter's flat path. Clients training adapters put these straight
    into the Task Result payload — the ``lowrank`` wire kind, byte
    stages, and :class:`~repro.fl.aggregator.LoRAFedAvgAggregator`
    handle them identically to stage-decomposed deltas, and the uplink
    carries ``rank * (m + n)`` floats per matrix instead of ``m * n``."""
    from repro.peft.lowrank import LowRankDelta

    adapter_spec = lora_adapter_spec(spec, rank)
    arrays = build_params(rng, adapter_spec, dtype)
    alpha_f = float(alpha) if alpha is not None else float(rank)
    out: dict[str, Any] = {}

    def walk(base_node: dict[str, Any], pair_node: dict[str, Any], path: str) -> None:
        for k, pair in pair_node.items():
            p = f"{path}/{k}" if path else k
            base = base_node[k]
            if isinstance(base, ParamDef):
                a = np.asarray(pair["a"])
                out[p] = LowRankDelta(
                    a, np.asarray(pair["b"]), alpha_f, rank,
                    tuple(base.shape), a.dtype,
                )
            else:
                walk(base, pair, p)

    walk(spec, arrays, "")
    return out


def merge_lora(params: dict[str, Any], adapters: dict[str, Any]) -> dict[str, Any]:
    """Fold adapter deltas into a flat base state dict:
    ``params[name] + (alpha/rank) * a @ b`` per adapter entry, other
    entries untouched. The result dtype follows the base parameters."""
    out = dict(params)
    for name, delta in adapters.items():
        base = out[name]
        out[name] = (base + delta.to_dense().astype(base.dtype)).astype(base.dtype)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def causal_lm_loss(logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float = 0.0) -> jnp.ndarray:
    """Cross-entropy with optional z-loss; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
