"""Griffin-style hybrid blocks: RG-LRU recurrence + local attention, 1:2

attention:recurrent ratio [arXiv:2402.19427] (RecurrentGemma).

RG-LRU (Real-Gated Linear Recurrent Unit):

    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence runs as a ``jax.lax.associative_scan`` —
O(log S) depth, fully parallel across (batch, width), the TPU-native
替代 of the paper's fused GPU scan kernel.

Block layout per layer (Griffin):
  temporal block (RG-LRU *or* local MQA) with residual, then gated-GeLU
  MLP with residual. The layer pattern (e.g. rec,rec,attn repeating) comes
  from ``cfg.block_pattern``; the repeating super-block is scanned and any
  remainder layers run as an explicit tail scan.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import base as B
from repro.models import layers as L
from repro.models.layers import ParamDef

CONV_K = 4
RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_scan(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray, lam: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x, r, i: (B,S,W); lam: (W,). Returns (h (B,S,W), h_last (B,W))."""
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    if h0 is not None:
        # fold the carried state in as a virtual step: h_1 = a_1 h0 + b_1
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        # note: a_1 multiplies h0 once; the scan below then treats step 1's
        # element as (a_1, a_1 h0 + b_1) with a_1 reset to preserve later
        # products — achieved by zeroing a at t=0 contribution via combine.

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        a_eff = a.at[:, 0].set(0.0)
    else:
        a_eff = a
    _, h = jax.lax.associative_scan(combine, (a_eff, gated), axis=1)
    return h, h[:, -1]


def rglru_step(h_prev: jnp.ndarray, x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
               lam: jnp.ndarray) -> jnp.ndarray:
    """One decode step. h_prev, x, r, i: (B,W)."""
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    return a * h_prev.astype(jnp.float32) + b


# ---------------------------------------------------------------------------
# recurrent temporal block
# ---------------------------------------------------------------------------

def rec_block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    w = cfg.rglru_width or cfg.d_model
    return {
        "norm": L.norm_spec(d),
        "w_in": ParamDef((d, w), (B.EMBED, B.STATE)),
        "w_gate_branch": ParamDef((d, w), (B.EMBED, B.STATE)),
        "conv_w": ParamDef((CONV_K, w), (None, B.STATE)),
        "w_r": ParamDef((w, w), (B.STATE, B.STATE)),
        "b_r": ParamDef((w,), (B.STATE,), init="zeros"),
        "w_i": ParamDef((w, w), (B.STATE, B.STATE)),
        "b_i": ParamDef((w,), (B.STATE,), init="zeros"),
        "lam": ParamDef((w,), (B.STATE,), init="ones", scale=1.0),
        "w_out": ParamDef((w, d), (B.STATE, B.EMBED)),
    }


def _rec_gates(u, p, dtype):
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_r"].astype(dtype)) + p["b_r"].astype(dtype))
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_i"].astype(dtype)) + p["b_i"].astype(dtype))
    return r, i


def rec_block_forward(x, p, cfg, state=None):
    """state: None (train) or dict(conv (B,K-1,W), h (B,W)) for streaming.

    Returns (out, new_state)."""
    dtype = x.dtype
    xin = L.rms_norm(x, p["norm"])
    u = jnp.einsum("bsd,dw->bsw", xin, p["w_in"].astype(dtype))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin, p["w_gate_branch"].astype(dtype)))
    conv_prev = state["conv"] if state is not None else None
    u, conv_new = L_causal_conv(u, p["conv_w"], conv_prev)
    r, i = _rec_gates(u, p, dtype)
    h0 = state["h"] if state is not None else None
    h, h_last = rglru_scan(u, r, i, p["lam"], h0)
    y = (h.astype(dtype) * gate)
    out = x + jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dtype))
    return out, {"conv": conv_new, "h": h_last}


def L_causal_conv(x, w, prev=None):
    from repro.models.ssm import _causal_conv

    return _causal_conv(x, w, prev)


def rec_block_decode(x, p, state, cfg):
    dtype = x.dtype
    xin = L.rms_norm(x, p["norm"])
    u = jnp.einsum("bsd,dw->bsw", xin, p["w_in"].astype(dtype))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin, p["w_gate_branch"].astype(dtype)))
    u, conv_new = L_causal_conv(u, p["conv_w"], state["conv"])
    r, i = _rec_gates(u, p, dtype)
    h = rglru_step(state["h"], u[:, 0], r[:, 0], i[:, 0], p["lam"])
    y = h[:, None].astype(dtype) * gate
    out = x + jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dtype))
    return out, {"conv": conv_new, "h": h}


def rec_init_state(cfg: B.ModelConfig, batch: int) -> dict[str, jnp.ndarray]:
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, w), cfg.activ_dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


# ---------------------------------------------------------------------------
# MLP block (gated GeLU) and attention temporal block reuse
# ---------------------------------------------------------------------------

def mlp_block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    return {"norm": L.norm_spec(cfg.d_model), "mlp": L.mlp_spec(cfg)}


def mlp_block_forward(x, p, cfg):
    h = L.rms_norm(x, p["norm"])
    g = jnp.einsum("bsd,df->bsf", h, p["mlp"]["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", h, p["mlp"]["w_up"].astype(x.dtype))
    return x + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, p["mlp"]["w_down"].astype(x.dtype))


def attn_block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    return {"norm": L.norm_spec(cfg.d_model), "attn": L.attention_spec(cfg)}


# ---------------------------------------------------------------------------
# Griffin model (pattern-scanned hybrid)
# ---------------------------------------------------------------------------

class GriffinModel:
    def __init__(self, cfg: B.ModelConfig) -> None:
        assert cfg.family == "hybrid"
        assert cfg.block_pattern, "hybrid needs cfg.block_pattern"
        self.cfg = cfg
        pat = cfg.block_pattern
        self.n_super = cfg.num_layers // len(pat)
        self.tail_pattern = pat[: cfg.num_layers % len(pat)]

        def layer_spec(kind: str) -> dict[str, Any]:
            temporal = rec_block_spec(cfg) if kind == "rglru" else attn_block_spec(cfg)
            return {"temporal": temporal, "mlp_block": mlp_block_spec(cfg)}

        super_spec = {f"{i}_{k}": layer_spec(k) for i, k in enumerate(pat)}
        self._spec: dict[str, Any] = {
            "embed": L.embed_spec(cfg),
            "blocks": L.stack_spec(super_spec, self.n_super),
        }
        if self.tail_pattern:
            self._spec["tail"] = {
                f"{i}_{k}": layer_spec(k) for i, k in enumerate(self.tail_pattern)
            }

    def init(self, rng: jax.Array) -> dict[str, Any]:
        return L.build_params(rng, self._spec, self.cfg.param_dtype)

    def param_axes(self) -> dict[str, Any]:
        return L.build_axes(self._spec)

    # -- layer application helpers ------------------------------------------
    def _apply_layer(self, x, kind, lp, *, collect_state: bool):
        cfg = self.cfg
        state = None
        if kind == "rglru":
            x, st = rec_block_forward(x, lp["temporal"], cfg)
            if collect_state:
                state = st
        else:
            xin = L.rms_norm(x, lp["temporal"]["norm"])
            bsz, s, _ = xin.shape
            positions = jnp.arange(s)[None, :]
            q, k, v = L._project_qkv(xin, lp["temporal"]["attn"], cfg, positions)
            out = L.sdpa_or_flash(q, k, v, cfg, causal=True, window=cfg.local_window)
            h = jnp.einsum("bsf,fd->bsd", out, lp["temporal"]["attn"]["wo"].astype(x.dtype))
            x = x + h
            if collect_state:
                w = min(cfg.local_window, s)
                kvf = cfg.kv_feat
                state = {
                    "k": k.reshape(bsz, s, kvf)[:, -w:].astype(cfg.activ_dtype),
                    "v": v.reshape(bsz, s, kvf)[:, -w:].astype(cfg.activ_dtype),
                    "pos": jnp.broadcast_to(
                        jnp.arange(s - w, s, dtype=jnp.int32)[None], (bsz, w)
                    ),
                }
        x = mlp_block_forward(x, lp["mlp_block"], cfg)
        return x, state

    def _apply_layer_decode(self, x, kind, lp, st, pos):
        cfg = self.cfg
        if kind == "rglru":
            x, new = rec_block_decode(x, lp["temporal"], st, cfg)
        else:
            h, new = L.attn_decode(
                L.rms_norm(x, lp["temporal"]["norm"]),
                lp["temporal"]["attn"],
                st,
                pos,
                cfg,
                window=cfg.local_window,
            )
            x = x + h
        x = mlp_block_forward(x, lp["mlp_block"], cfg)
        return x, new

    # -- training -------------------------------------------------------------
    def forward(self, params, tokens, patches=None):
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        pat = cfg.block_pattern

        def body(x, bp):
            for i, kind in enumerate(pat):
                x, _ = self._apply_layer(x, kind, bp[f"{i}_{kind}"], collect_state=False)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        for i, kind in enumerate(self.tail_pattern):
            x, _ = self._apply_layer(x, kind, params["tail"][f"{i}_{kind}"], collect_state=False)
        return L.lm_logits(x, params["embed"]), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        lm = L.causal_lm_loss(logits[:, :-1], batch["labels"][:, 1:], self.cfg.z_loss)
        return lm, {"lm_loss": lm, "aux_loss": jnp.float32(0.0)}

    # -- serving ---------------------------------------------------------------
    def _layer_state(self, kind: str, batch: int, max_len: int):
        cfg = self.cfg
        if kind == "rglru":
            return rec_init_state(cfg, batch)
        return L.init_window_cache(cfg, batch, min(cfg.local_window, max_len), cfg.activ_dtype)

    def init_cache(self, batch: int, max_len: int) -> dict[str, Any]:
        pat = self.cfg.block_pattern
        one = {f"{i}_{k}": self._layer_state(k, batch, max_len) for i, k in enumerate(pat)}
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[one for _ in range(self.n_super)]
        )
        cache: dict[str, Any] = {"blocks": stacked}
        if self.tail_pattern:
            cache["tail"] = {
                f"{i}_{k}": self._layer_state(k, batch, max_len)
                for i, k in enumerate(self.tail_pattern)
            }
        return cache

    def cache_axes(self) -> dict[str, Any]:
        def layer_axes(kind: str, with_layer: bool):
            pre = (B.LAYER,) if with_layer else ()
            if kind == "rglru":
                return {
                    "conv": pre + (B.BATCH, None, B.STATE),
                    "h": pre + (B.BATCH, B.STATE),
                }
            return {
                "k": pre + (B.BATCH, B.SEQ, B.KV_FEAT),
                "v": pre + (B.BATCH, B.SEQ, B.KV_FEAT),
                "pos": pre + (B.BATCH, B.SEQ),
            }

        pat = self.cfg.block_pattern
        axes: dict[str, Any] = {
            "blocks": {f"{i}_{k}": layer_axes(k, True) for i, k in enumerate(pat)}
        }
        if self.tail_pattern:
            axes["tail"] = {
                f"{i}_{k}": layer_axes(k, False) for i, k in enumerate(self.tail_pattern)
            }
        return axes

    def prefill(self, params, tokens, patches=None):
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        pat = cfg.block_pattern

        def body(x, bp):
            states = {}
            for i, kind in enumerate(pat):
                x, st = self._apply_layer(x, kind, bp[f"{i}_{kind}"], collect_state=True)
                states[f"{i}_{kind}"] = st
            return x, states

        if cfg.remat:
            body = jax.checkpoint(body)
        x, stacked = jax.lax.scan(body, x, params["blocks"])
        cache: dict[str, Any] = {"blocks": stacked}
        if self.tail_pattern:
            cache["tail"] = {}
            for i, kind in enumerate(self.tail_pattern):
                x, st = self._apply_layer(
                    x, kind, params["tail"][f"{i}_{kind}"], collect_state=True
                )
                cache["tail"][f"{i}_{kind}"] = st
        logits = L.lm_logits(x[:, -1:], params["embed"])
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        pat = cfg.block_pattern

        def body(x, inp):
            bp, st = inp
            new_states = {}
            for i, kind in enumerate(pat):
                key = f"{i}_{kind}"
                x, new = self._apply_layer_decode(x, kind, bp[key], st[key], pos)
                new_states[key] = new
            return x, new_states

        x, new_stacked = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache: dict[str, Any] = {"blocks": new_stacked}
        if self.tail_pattern:
            new_cache["tail"] = {}
            for i, kind in enumerate(self.tail_pattern):
                key = f"{i}_{kind}"
                x, new = self._apply_layer_decode(
                    x, kind, params["tail"][key], cache["tail"][key], pos
                )
                new_cache["tail"][key] = new
        return L.lm_logits(x, params["embed"]), new_cache
