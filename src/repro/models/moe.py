"""Mixture-of-Experts layer: top-k router with capacity-based einsum

dispatch (GSPMD-native, shards experts over the ``model`` mesh axis so
dispatch/combine lower to all-to-alls) and a load-balance auxiliary loss.

Token groups are sequence chunks of ``GROUP_T`` tokens; capacity per group
is ``ceil(GROUP_T * k / E * capacity_factor)``. Tokens over capacity are
dropped (their residual passes through) — the classic Switch/GShard
formulation, chosen over sort/ragged dispatch because it lowers robustly
under pjit on every mesh (DESIGN.md §4); the §Perf loop revisits the
dispatch tensor cost.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import base as B
from repro.models.layers import ParamDef

# tokens per routing group; capacity (and with it the (T,E,C) dispatch
# tensor and its einsum flops) scales linearly with this, so smaller groups
# bound the dispatch overhead — 256 keeps the dbrx-132b train_4k dispatch
# temp ~10 GB/device on the production mesh
GROUP_T = 256


def moe_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, e), (B.EMBED, B.EXPERT)),
        "w_gate": ParamDef((e, d, f), (B.EXPERT, B.EMBED, B.MLP)),
        "w_up": ParamDef((e, d, f), (B.EXPERT, B.EMBED, B.MLP)),
        "w_down": ParamDef((e, f, d), (B.EXPERT, B.MLP, B.EMBED)),
    }


def _dispatch_tensors(
    gates: jnp.ndarray, k: int, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """gates: (G, T, E) softmax probs -> (combine (G,T,E,C), aux per-group).

    Iterative top-k (k is 1..4 for every assigned arch): slot j picks the
    best remaining expert per token, positions within an expert's buffer
    come from a cumulative count over the flattened (slot, token) order.
    """
    G, T, E = gates.shape
    remaining = gates
    combine = jnp.zeros((G, T, E, capacity), gates.dtype)
    # running per-expert fill count across slots
    fill = jnp.zeros((G, E), jnp.int32)
    for _ in range(k):
        gate_j, idx_j = jax.lax.top_k(remaining, 1)          # (G,T,1)
        gate_j, idx_j = gate_j[..., 0], idx_j[..., 0]        # (G,T)
        onehot = jax.nn.one_hot(idx_j, E, dtype=jnp.int32)   # (G,T,E)
        pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot + fill[:, None, :]
        pos = jnp.sum(pos_in_expert * onehot, axis=-1)       # (G,T)
        keep = pos < capacity
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=gates.dtype)  # (G,T,C)
        combine = combine + (
            gate_j[..., None, None]
            * onehot.astype(gates.dtype)[..., None]
            * pos_oh[:, :, None, :]
            * keep[..., None, None].astype(gates.dtype)
        )
        fill = fill + jnp.sum(onehot, axis=1)
        remaining = remaining * (1 - onehot.astype(gates.dtype))
    return combine


def load_balance_loss(gates: jnp.ndarray, k: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e mean_prob_e * mean_topk_frac_e."""
    G, T, E = gates.shape
    mean_prob = jnp.mean(gates, axis=1)                      # (G,E)
    _, topk_idx = jax.lax.top_k(gates, k)                    # (G,T,k)
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx, E, dtype=gates.dtype), axis=2), axis=1
    ) / k                                                    # (G,E)
    return E * jnp.mean(jnp.sum(mean_prob * frac, axis=-1))


def moe_forward(
    x: jnp.ndarray, p: dict[str, jnp.ndarray], cfg: B.ModelConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (batch, seq, d) -> (output, aux_loss). Routing is per GROUP_T-token

    sequence chunk (decode: one group of the live tokens)."""
    bsz, s, d = x.shape
    k = cfg.experts_per_token
    E = cfg.num_experts
    group_t = min(GROUP_T, s)
    assert (bsz * s) % group_t == 0, (bsz, s, group_t)
    G = bsz * s // group_t
    xg = x.reshape(G, group_t, d)
    capacity = int(np.ceil(group_t * k / E * cfg.moe_capacity_factor))

    # router in input dtype with fp32 ACCUMULATION: keeps the router's
    # numerics fp32 while the cross-shard all-gather of x stays bf16
    # (§Perf pair 4: the f32 cast before this einsum made GSPMD gather
    # fp32 activations — 26% of dbrx train wire bytes)
    router_logits = jnp.einsum(
        "gtd,de->gte", xg, p["router"].astype(xg.dtype),
        preferred_element_type=jnp.float32,
    )
    gates = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    combine = _dispatch_tensors(gates.astype(jnp.float32), k, capacity)
    combine = combine.astype(x.dtype)
    dispatch = (combine > 0).astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)          # all-to-all
    g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)            # all-to-all back
    aux = load_balance_loss(gates, k)
    return y.reshape(bsz, s, d), aux
