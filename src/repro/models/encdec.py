"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the brief, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: ``input_specs`` provides precomputed frame
embeddings of shape (batch, encoder_seq, d_model). We implement the
transformer itself: a bidirectional encoder and a causal decoder with
cross-attention. Positions are sinusoidal (deviation from Whisper's
learned decoder positions — noted in DESIGN.md §8 — so that arbitrary
assigned input shapes don't require giant learned tables).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import base as B
from repro.models import layers as L


def _enc_block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    return {
        "attn_norm": L.norm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def _dec_block_spec(cfg: B.ModelConfig) -> dict[str, Any]:
    return {
        "self_norm": L.norm_spec(cfg.d_model),
        "self_attn": L.attention_spec(cfg),
        "cross_norm": L.norm_spec(cfg.d_model),
        "cross_attn": L.attention_spec(cfg),
        "mlp_norm": L.norm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


class EncDecModel:
    def __init__(self, cfg: B.ModelConfig) -> None:
        assert cfg.family == "encdec"
        assert cfg.encoder_layers > 0 and cfg.encoder_seq > 0
        self.cfg = cfg
        self._spec = {
            "embed": L.embed_spec(cfg),
            "enc_blocks": L.stack_spec(_enc_block_spec(cfg), cfg.encoder_layers),
            "enc_norm": L.norm_spec(cfg.d_model),
            "dec_blocks": L.stack_spec(_dec_block_spec(cfg), cfg.num_layers),
        }

    def init(self, rng: jax.Array) -> dict[str, Any]:
        return L.build_params(rng, self._spec, self.cfg.param_dtype)

    def param_axes(self) -> dict[str, Any]:
        return L.build_axes(self._spec)

    # -- encoder ---------------------------------------------------------------
    def encode(self, params: dict[str, Any], frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, S_enc, d) stub embeddings -> encoder memory."""
        cfg = self.cfg
        x = frames.astype(cfg.activ_dtype)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        def body(x, bp):
            h = L.attn_forward(L.rms_norm(x, bp["attn_norm"]), bp["attn"], cfg, causal=False)
            x = x + h
            x = x + L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"])

    # -- decoder ---------------------------------------------------------------
    def _dec_block(self, x, bp, memory, *, collect_cache: bool):
        cfg = self.cfg
        bsz, s, _ = x.shape
        xin = L.rms_norm(x, bp["self_norm"])
        positions = jnp.arange(s)[None, :]
        q, k, v = L._project_qkv(xin, bp["self_attn"], cfg, positions)
        out = L.sdpa_or_flash(q, k, v, cfg, causal=True, window=None)
        x = x + jnp.einsum("bsf,fd->bsd", out, bp["self_attn"]["wo"].astype(x.dtype))
        h, cross_kv = L.cross_attn_forward(
            L.rms_norm(x, bp["cross_norm"]), memory, bp["cross_attn"], cfg
        )
        x = x + h
        x = x + L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
        cache = None
        if collect_cache:
            kvf = cfg.kv_feat
            cache = {
                "self_k": k.reshape(bsz, s, kvf).astype(cfg.activ_dtype),
                "self_v": v.reshape(bsz, s, kvf).astype(cfg.activ_dtype),
                "cross_k": cross_kv[0].astype(cfg.activ_dtype),
                "cross_v": cross_kv[1].astype(cfg.activ_dtype),
            }
        return x, cache

    def forward(self, params, tokens, frames):
        cfg = self.cfg
        memory = self.encode(params, frames)
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        def body(x, bp):
            x, _ = self._dec_block(x, bp, memory, collect_cache=False)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return L.lm_logits(x, params["embed"]), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"], batch["frames"])
        lm = L.causal_lm_loss(logits[:, :-1], batch["labels"][:, 1:], self.cfg.z_loss)
        return lm, {"lm_loss": lm, "aux_loss": jnp.float32(0.0)}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict[str, Any]:
        cfg = self.cfg
        kvf = cfg.kv_feat
        hd = cfg.resolved_head_dim
        one = {
            "self_k": jnp.zeros((batch, max_len, kvf), cfg.activ_dtype),
            "self_v": jnp.zeros((batch, max_len, kvf), cfg.activ_dtype),
            "cross_k": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, hd), cfg.activ_dtype),
            "cross_v": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, hd), cfg.activ_dtype),
        }
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[one for _ in range(cfg.num_layers)]
        )

    def cache_axes(self) -> dict[str, Any]:
        Lx, Bx = B.LAYER, B.BATCH
        return {
            "self_k": (Lx, Bx, B.SEQ, B.KV_FEAT),
            "self_v": (Lx, Bx, B.SEQ, B.KV_FEAT),
            "cross_k": (Lx, Bx, B.SEQ, None, None),
            "cross_v": (Lx, Bx, B.SEQ, None, None),
        }

    def prefill(self, params, tokens, frames):
        """Encode + run the decoder prompt, returning (logits, cache)."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        def body(x, bp):
            x, cache = self._dec_block(x, bp, memory, collect_cache=True)
            # reshape cross kv to cache layout
            return x, cache

        if cfg.remat:
            body = jax.checkpoint(body)
        x, caches = jax.lax.scan(body, x, params["dec_blocks"])
        logits = L.lm_logits(x[:, -1:], params["embed"])
        return logits, caches

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,1); cache from init_cache/prefill; pos: scalar."""
        cfg = self.cfg
        x = L.embed_tokens(tokens, params["embed"], cfg.activ_dtype)
        pos_enc = L.sinusoidal_positions(2, cfg.d_model, x.dtype)  # table lookup
        # sinusoidal at absolute pos: compute directly
        x = x + _sinusoid_at(pos, cfg.d_model, x.dtype)

        def body(x, inp):
            bp, cl = inp
            self_cache = {"k": cl["self_k"], "v": cl["self_v"]}
            h, new_self = L.attn_decode(
                L.rms_norm(x, bp["self_norm"]), bp["self_attn"], self_cache, pos, cfg
            )
            x = x + h
            h, _ = L.cross_attn_forward(
                L.rms_norm(x, bp["cross_norm"]),
                memory=None,
                p=bp["cross_attn"],
                cfg=cfg,
                kv=(cl["cross_k"], cl["cross_v"]),
            )
            x = x + h
            x = x + L.mlp_forward(L.rms_norm(x, bp["mlp_norm"]), bp["mlp"])
            new_cache = {
                "self_k": new_self["k"],
                "self_v": new_self["v"],
                "cross_k": cl["cross_k"],
                "cross_v": cl["cross_v"],
            }
            return x, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        return L.lm_logits(x, params["embed"]), new_caches


def _sinusoid_at(pos: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    half = d // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :].astype(dtype)
