"""Event-driven async federated runtime.

Layers (each its own module):

* :mod:`repro.runtime.events` — deterministic simulated-clock event loop
  and client availability traces (arrival/departure schedules).
* :mod:`repro.runtime.network` — per-client link/compute models that turn
  actual wire bytes into simulated time.
* :mod:`repro.runtime.async_agg` — aggregation policies: round-barrier
  :class:`SyncPolicy` (bitwise-equal to ``ScatterAndGather``),
  staleness-weighted :class:`FedBuffPolicy`, per-update
  :class:`FedAsyncPolicy`, and latency-tiered :class:`TieredPolicy`.
* :mod:`repro.runtime.scheduler` — the orchestrator: concurrent
  real-transport execution on a thread pool, fault injection,
  availability deferral/interrupts, timeline.
"""
from repro.runtime.async_agg import (
    AggregationPolicy,
    Dispatch,
    FedAsyncPolicy,
    FedBuffPolicy,
    SyncPolicy,
    TieredPolicy,
    polynomial_staleness,
)
from repro.runtime.events import (
    AvailabilityTrace,
    Event,
    EventKind,
    EventLoop,
    availability_from_spec,
    periodic_availability,
    random_availability,
)
from repro.runtime.network import (
    PROFILES,
    ComputeProfile,
    LinkProfile,
    NetworkModel,
    heterogeneous_network,
    network_from_spec,
)
from repro.runtime.scheduler import AsyncFLScheduler, RuntimeConfig, RuntimeStats

__all__ = [
    "AggregationPolicy",
    "Dispatch",
    "FedAsyncPolicy",
    "FedBuffPolicy",
    "SyncPolicy",
    "TieredPolicy",
    "polynomial_staleness",
    "AvailabilityTrace",
    "Event",
    "EventKind",
    "EventLoop",
    "availability_from_spec",
    "periodic_availability",
    "random_availability",
    "PROFILES",
    "ComputeProfile",
    "LinkProfile",
    "NetworkModel",
    "heterogeneous_network",
    "network_from_spec",
    "AsyncFLScheduler",
    "RuntimeConfig",
    "RuntimeStats",
]
