"""Event-driven async federated runtime.

Layers (each its own module):

* :mod:`repro.runtime.events` — deterministic simulated-clock event loop.
* :mod:`repro.runtime.network` — per-client link/compute models that turn
  actual wire bytes into simulated time.
* :mod:`repro.runtime.async_agg` — aggregation policies: round-barrier
  :class:`SyncPolicy` (bitwise-equal to ``ScatterAndGather``) and
  staleness-weighted :class:`FedBuffPolicy`.
* :mod:`repro.runtime.scheduler` — the orchestrator: concurrent
  real-transport execution on a thread pool, fault injection, timeline.
"""
from repro.runtime.async_agg import (
    AggregationPolicy,
    Dispatch,
    FedBuffPolicy,
    SyncPolicy,
    polynomial_staleness,
)
from repro.runtime.events import Event, EventKind, EventLoop
from repro.runtime.network import (
    PROFILES,
    ComputeProfile,
    LinkProfile,
    NetworkModel,
    heterogeneous_network,
)
from repro.runtime.scheduler import AsyncFLScheduler, RuntimeConfig, RuntimeStats

__all__ = [
    "AggregationPolicy",
    "Dispatch",
    "FedBuffPolicy",
    "SyncPolicy",
    "polynomial_staleness",
    "Event",
    "EventKind",
    "EventLoop",
    "PROFILES",
    "ComputeProfile",
    "LinkProfile",
    "NetworkModel",
    "heterogeneous_network",
    "AsyncFLScheduler",
    "RuntimeConfig",
    "RuntimeStats",
]
