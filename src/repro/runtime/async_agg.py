"""Aggregation policies for the event-driven scheduler.

Four policies make synchronous FedAvg "one policy among several":

* :class:`SyncPolicy` — a barrier per round. It buffers each round's
  Task Results as they complete (in any simulated order) and feeds the
  aggregator **in client-list order**, exactly the order the sequential
  :class:`~repro.fl.controller.ScatterAndGather` loop uses; tasks are
  built by the same :func:`~repro.fl.controller.make_task`. With the
  same seeds the final weights are therefore *bitwise equal* to the
  synchronous controller's — the staleness-0 fixed point.

* :class:`FedBuffPolicy` — buffered asynchronous aggregation (FedBuff,
  Nguyen et al. 2022): no barrier; every completed client immediately
  gets a fresh task built from the *current* global model. Client deltas
  (w_client - w_dispatched) accumulate in a size-K buffer weighted by
  ``num_samples * (1 + staleness)^-alpha``; each buffer flush applies the
  weighted-mean delta at ``server_lr`` and bumps the model version. Fast
  clients contribute many low-staleness updates instead of idling behind
  stragglers — the throughput win the async benchmark quantifies.

* :class:`FedAsyncPolicy` — fully asynchronous per-update mixing
  (FedAsync, Xie et al. 2019): every single client result is immediately
  folded into the global model, ``w <- (1 - a_t) w + a_t w_client`` with
  ``a_t = mixing_rate * (1 + staleness)^-alpha`` — the K=1 extreme of
  the buffered family, maximum freshness, one model version per update.

* :class:`TieredPolicy` — TiFL-style tiered selection (Chai et al.
  2020): clients are bucketed into tiers by *profiled round latency* and
  each round runs over one tier only, so a round is never dragged out by
  a straggler from a slower tier. Selection is seeded (deterministic)
  with optional per-tier credits to bound how often any tier is drawn.

Policies are transport-ignorant: they see completed
:class:`~repro.core.messages.Message` results (already through all four
filter points) and emit :class:`Dispatch` records; the scheduler owns
time, links, threads and faults.

Streaming aggregation (``server_streaming_agg``) swaps the result path
to :meth:`AggregationPolicy.on_result_stream`: instead of a decoded
Message, the policy receives a ``deliver(sink)`` callable that runs the
uplink fold transfer at the completion instant, pushing one decoded item
at a time into the sink the policy chooses. Every built-in policy folds
into *per-item running state* (the aggregator's sums, the FedBuff delta
buffer, the FedAsync global model) rather than buffering payload dicts;
third-party policies inherit a collect-and-call-``on_result`` fallback.
"""
from __future__ import annotations

import dataclasses
from random import Random
from collections.abc import Callable, Mapping, Sequence
from typing import Any, Optional

import numpy as np

from repro.core.messages import Message, MessageKind
from repro.fl.aggregator import CollectingSink
from repro.fl.controller import make_task


@dataclasses.dataclass
class Dispatch:
    """One task handed to one client: what the scheduler launches."""

    client: str
    task: Message
    version: int          # global model version the task was built from
    attempt: int = 0      # dropout retry counter (scheduler-managed)


class AggregationPolicy:
    """What the scheduler asks of an aggregation/workflow policy."""

    name = "policy"

    def begin(self, initial_weights: Mapping[str, Any], clients: Sequence[str]) -> list[Dispatch]:
        raise NotImplementedError

    def on_result(self, dispatch: Dispatch, result: Message) -> list[Dispatch]:
        raise NotImplementedError

    def on_result_stream(
        self,
        dispatch: Dispatch,
        headers: Mapping[str, Any],
        deliver: Callable[[Any], Message],
    ) -> list[Dispatch]:
        """Streaming-aggregation result path: called at the simulated
        completion instant (event order, scheduler thread) *instead of*
        :meth:`on_result`. ``headers`` are the result's headers (sample
        counts, wire bytes); ``deliver(sink)`` runs the uplink fold
        transfer, pushing each decoded item through ``sink.begin``/
        ``sink.accept_item`` and freeing it — call it at most once, with
        a sink that folds items into per-item running state.

        The default adapts any policy that only implements
        :meth:`on_result`: items are collected back into a payload dict
        (no memory win, full compatibility).
        """
        sink = CollectingSink()
        msg = deliver(sink)
        # finish() dequantizes any wire-form items in one fused dispatch
        # per format group (no-op on already-decoded payloads)
        return self.on_result(dispatch, Message(msg.kind, sink.finish(), dict(msg.headers)))

    def on_client_failed(self, dispatch: Dispatch) -> list[Dispatch]:
        """Called when a client exhausted its dropout retries."""
        return []

    @property
    def complete(self) -> bool:
        raise NotImplementedError

    @property
    def model_version(self) -> int:
        raise NotImplementedError

    def finish(self) -> dict[str, Any]:
        raise NotImplementedError


class SyncPolicy(AggregationPolicy):
    """Round-barrier FedAvg over the async scheduler.

    Results may *complete* in any simulated order, but aggregation per
    round runs in client-list order once the barrier closes, so the float
    summation order — and hence the output bits — match the sequential
    controller. (Under streaming aggregation the fold instead runs at
    each completion instant, in completion order — see
    :meth:`on_result_stream`.) Clients that permanently dropped out are
    skipped (the sample-weighted average renormalizes over survivors).

    Subclasses may narrow each round to a cohort by overriding
    :meth:`_select_round_clients` (see :class:`TieredPolicy`).
    """

    name = "sync"

    def __init__(
        self,
        aggregator: Any,
        num_rounds: int,
        on_round_end: Optional[Callable[[int, dict[str, Any], list[Message]], None]] = None,
    ) -> None:
        self.aggregator = aggregator
        self.num_rounds = num_rounds
        self.on_round_end = on_round_end
        self._clients: list[str] = []
        self._round_clients: list[str] = []
        self._round = 0
        self._weights: dict[str, Any] = {}
        self._results: dict[str, Message] = {}
        self._failed: set = set()
        self._streamed: set = set()  # clients already folded via streaming

    def begin(self, initial_weights, clients):
        self._clients = list(clients)
        self._weights = dict(initial_weights)
        self._round = 0
        if self.num_rounds <= 0:  # match ScatterAndGather: no rounds, no work
            return []
        return self._dispatch_round()

    def _select_round_clients(self) -> list[str]:
        """The cohort for the round about to start (default: everyone)."""
        return list(self._clients)

    def _dispatch_round(self) -> list[Dispatch]:
        self._results = {}
        self._failed = set()
        self._streamed = set()
        self._round_clients = self._select_round_clients()
        return [
            Dispatch(c, make_task(self._round, self._weights), version=self._round)
            for c in self._round_clients
        ]

    def _round_done(self) -> bool:
        return len(self._results) + len(self._failed) >= len(self._round_clients)

    def _close_round(self) -> list[Dispatch]:
        ordered = [self._results[c] for c in self._round_clients if c in self._results]
        # batch contributions were buffered whole and fold now, in
        # client-list order at the barrier (the sequential controller's
        # exact order); streamed clients already folded at completion
        for c in self._round_clients:
            if c in self._results and c not in self._streamed:
                self.aggregator.accept(self._results[c])
        self._weights = self.aggregator.finish()
        if self.on_round_end is not None:
            self.on_round_end(self._round, self._weights, ordered)
        self._round += 1
        if self._round < self.num_rounds:
            return self._dispatch_round()
        return []

    def on_result(self, dispatch, result):
        if dispatch.version != self._round:
            return []  # stale straggler from an already-closed round
        self._results[dispatch.client] = result
        return self._close_round() if self._round_done() else []

    def on_result_stream(self, dispatch, headers, deliver):
        """Streaming barrier: each completing client folds straight into
        the aggregator's per-item running sums at its completion instant
        — the policy buffers header-only records, never payload dicts, so
        round memory is one running aggregate instead of one model per
        cohort client. Folds run in completion order (deterministic in
        simulated time); bitwise-equal to the batch barrier whenever
        completion order matches client-list order (uniform jitter-free
        links — tested), numerically equivalent otherwise.
        """
        if dispatch.version != self._round:
            return []  # stale straggler from an already-closed round
        self._streamed.add(dispatch.client)
        deliver(self.aggregator)
        self._results[dispatch.client] = Message(
            MessageKind.TASK_RESULT, {}, dict(headers)
        )
        return self._close_round() if self._round_done() else []

    def on_client_failed(self, dispatch):
        if dispatch.version != self._round:
            return []
        self._failed.add(dispatch.client)
        if not self._results and self._round_done():
            raise RuntimeError(f"round {self._round}: every client dropped out")
        return self._close_round() if self._round_done() else []

    @property
    def complete(self) -> bool:
        return self._round >= self.num_rounds

    @property
    def model_version(self) -> int:
        return self._round

    def finish(self):
        return dict(self._weights)


def polynomial_staleness(alpha: float = 0.5) -> Callable[[int], float]:
    """FedBuff's polynomial staleness discount: (1 + s)^-alpha."""

    def weight(staleness: int) -> float:
        return float((1.0 + max(0, staleness)) ** (-alpha))

    return weight


class _BudgetedAsyncPolicy(AggregationPolicy):
    """Shared machinery for barrier-free policies with a client-task
    budget (:class:`FedBuffPolicy`, :class:`FedAsyncPolicy`): dispatch
    bookkeeping, float32 weight coercion, and the completion criterion
    (all dispatched tasks either processed or permanently lost)."""

    def __init__(self, total_tasks: int) -> None:
        self.total_tasks = total_tasks
        self._weights: dict[str, np.ndarray] = {}
        self._version = 0
        self._dispatched = 0
        self._done = 0          # results processed
        self._lost = 0          # permanently failed clients' tasks
        self.staleness_seen: list[int] = []

    # -- dispatch helpers ---------------------------------------------------
    def _next_task(self, client: str) -> list[Dispatch]:
        if self._dispatched >= self.total_tasks:
            return []
        self._dispatched += 1
        return [Dispatch(client, make_task(self._version, self._weights), version=self._version)]

    def begin(self, initial_weights, clients):
        self._weights = {
            n: np.asarray(v, np.float32) if np.issubdtype(np.asarray(v).dtype, np.floating)
            else v
            for n, v in initial_weights.items()
        }
        out: list[Dispatch] = []
        for c in clients:
            out.extend(self._next_task(c))
        return out

    def on_client_failed(self, dispatch):
        self._lost += 1
        return []

    @property
    def complete(self) -> bool:
        return self._done + self._lost >= self._dispatched and self._dispatched >= self.total_tasks

    @property
    def model_version(self) -> int:
        return self._version

    def finish(self):
        return dict(self._weights)


class _FedBuffFoldSink:
    """Per-dispatch streaming sink for FedBuff: folds ``(value - base) *
    w`` into the policy's shared per-item delta sums the moment each item
    decodes — identical arithmetic and item order to the batch
    ``on_result`` loop, so streaming and batch aggregation are
    bitwise-equal. ``base`` is the dispatched task payload the policy
    already holds (the arrays are shared with the global model snapshot,
    not copies)."""

    def __init__(self, policy: FedBuffPolicy, dispatch: Dispatch, w: float) -> None:
        self._policy = policy
        self._base = dispatch.task.payload
        self._w = w

    def begin(self, meta: Mapping[str, Any]) -> float:
        return self._w  # staleness weight fixed at the completion instant

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        base = self._base.get(name)
        if base is None or not np.issubdtype(np.asarray(value).dtype, np.floating):
            return
        delta = (np.asarray(value, np.float32) - np.asarray(base, np.float32)) * self._w
        sums = self._policy._delta_sum
        if name in sums:
            sums[name] += delta
        else:
            sums[name] = delta


class _FedAsyncFoldSink:
    """Per-dispatch streaming sink for FedAsync: applies the per-item mix
    ``w <- (1 - a) w + a w_client`` as each item decodes — the same op,
    in the same item order, as the batch ``on_result`` loop."""

    def __init__(self, policy: FedAsyncPolicy, a: float) -> None:
        self._policy = policy
        self._a = a

    def begin(self, meta: Mapping[str, Any]) -> float:
        return self._a

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        weights = self._policy._weights
        cur = weights.get(name)
        if cur is None or not np.issubdtype(np.asarray(value).dtype, np.floating):
            return
        a = self._a
        weights[name] = (
            (1.0 - a) * np.asarray(cur, np.float32) + a * np.asarray(value, np.float32)
        ).astype(np.float32)


class FedBuffPolicy(_BudgetedAsyncPolicy):
    """Staleness-weighted buffered async aggregation.

    ``total_tasks`` is the client-task budget (compare against a sync run
    of ``num_rounds * num_clients``); ``buffer_size`` is K, the number of
    client updates folded into one server step.
    """

    name = "fedbuff"

    def __init__(
        self,
        total_tasks: int,
        buffer_size: int = 4,
        server_lr: float = 1.0,
        staleness_weight: Optional[Callable[[int], float]] = None,
        on_update: Optional[Callable[[int, dict[str, Any]], None]] = None,
    ) -> None:
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        super().__init__(total_tasks)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.staleness_weight = staleness_weight or polynomial_staleness()
        self.on_update = on_update
        self._delta_sum: dict[str, np.ndarray] = {}
        self._wsum = 0.0
        self._buffered = 0

    # -- aggregation --------------------------------------------------------
    def _flush(self) -> None:
        if self._buffered == 0 or self._wsum <= 0:
            return
        for name, dsum in self._delta_sum.items():
            self._weights[name] = (
                np.asarray(self._weights[name], np.float32)
                + self.server_lr * dsum / self._wsum
            ).astype(np.float32)
        self._version += 1
        self._delta_sum = {}
        self._wsum = 0.0
        self._buffered = 0
        if self.on_update is not None:
            self.on_update(self._version, self._weights)

    def on_result(self, dispatch, result):
        staleness = self._version - dispatch.version
        self.staleness_seen.append(staleness)
        w = float(result.headers.get("num_samples", 1)) * self.staleness_weight(staleness)
        if w > 0:
            for name, value in result.payload.items():
                base = dispatch.task.payload.get(name)
                if base is None or not np.issubdtype(np.asarray(value).dtype, np.floating):
                    continue
                delta = (np.asarray(value, np.float32) - np.asarray(base, np.float32)) * w
                if name in self._delta_sum:
                    self._delta_sum[name] += delta
                else:
                    self._delta_sum[name] = delta
            self._wsum += w
            self._buffered += 1
        self._done += 1
        if self._buffered >= self.buffer_size:
            self._flush()
        return self._next_task(dispatch.client)

    def on_result_stream(self, dispatch, headers, deliver):
        """Streaming FedBuff: the delta buffer *is* the per-item running
        state — each arriving item's weighted delta folds into it during
        the uplink transfer, and the full client payload is never held.
        Runs at the completion instant with completion-time staleness,
        exactly like :meth:`on_result` — bitwise-equal results."""
        staleness = self._version - dispatch.version
        self.staleness_seen.append(staleness)
        w = float(headers.get("num_samples", 1)) * self.staleness_weight(staleness)
        if w > 0:
            deliver(_FedBuffFoldSink(self, dispatch, w))
            self._wsum += w
            self._buffered += 1
        self._done += 1
        if self._buffered >= self.buffer_size:
            self._flush()
        return self._next_task(dispatch.client)

    def finish(self):
        self._flush()  # partial buffer still carries information
        return dict(self._weights)


class FedAsyncPolicy(_BudgetedAsyncPolicy):
    """FedAsync (Xie et al. 2019): per-update server mixing.

    Every completed client result is immediately mixed into the global
    model — no buffer, no barrier:

        a_t = mixing_rate * (1 + staleness)^-alpha
        w  <- (1 - a_t) * w + a_t * w_client

    One server step (and model version bump) per client update: maximum
    freshness at the cost of more server steps than FedBuff. Stale
    updates are geometrically discounted by the polynomial staleness
    weight, FedAsync's convergence knob.
    """

    name = "fedasync"

    def __init__(
        self,
        total_tasks: int,
        mixing_rate: float = 0.6,
        staleness_weight: Optional[Callable[[int], float]] = None,
        on_update: Optional[Callable[[int, dict[str, Any]], None]] = None,
    ) -> None:
        if not 0.0 < mixing_rate <= 1.0:
            raise ValueError("mixing_rate must be in (0, 1]")
        super().__init__(total_tasks)
        self.mixing_rate = mixing_rate
        self.staleness_weight = staleness_weight or polynomial_staleness()
        self.on_update = on_update

    def on_result(self, dispatch, result):
        staleness = self._version - dispatch.version
        self.staleness_seen.append(staleness)
        a = self.mixing_rate * self.staleness_weight(staleness)
        for name, value in result.payload.items():
            cur = self._weights.get(name)
            if cur is None or not np.issubdtype(np.asarray(value).dtype, np.floating):
                continue
            self._weights[name] = (
                (1.0 - a) * np.asarray(cur, np.float32) + a * np.asarray(value, np.float32)
            ).astype(np.float32)
        self._version += 1
        self._done += 1
        if self.on_update is not None:
            self.on_update(self._version, self._weights)
        return self._next_task(dispatch.client)

    def on_result_stream(self, dispatch, headers, deliver):
        """Streaming FedAsync: the global model *is* the per-item running
        state — each arriving item is mixed in place during the uplink
        transfer. Same per-item op and order as :meth:`on_result` at the
        same completion instant — bitwise-equal results."""
        staleness = self._version - dispatch.version
        self.staleness_seen.append(staleness)
        a = self.mixing_rate * self.staleness_weight(staleness)
        deliver(_FedAsyncFoldSink(self, a))
        self._version += 1
        self._done += 1
        if self.on_update is not None:
            self.on_update(self._version, self._weights)
        return self._next_task(dispatch.client)


class TieredPolicy(SyncPolicy):
    """TiFL-style tiered client selection (Chai et al. 2020).

    Clients are profiled for expected round latency, sorted, and split
    into ``num_tiers`` equal-size buckets; every round draws **one** tier
    (seeded uniform over eligible tiers) and runs a sync FedAvg round
    over that tier only. Intra-round wait is bounded by the tier's own
    stragglers — a fiber client never idles behind a 3G one.

    Profiling: ``latency_fn(client) -> seconds`` if given; else, with a
    :class:`~repro.runtime.network.NetworkModel`, the jitter-free
    estimate ``2 * link.base_seconds(probe_bytes) + compute`` per client;
    else clients are bucketed in client-list order.

    ``credits`` (optional, per tier) bounds how many rounds any tier may
    serve, TiFL's guard against over-training on one latency class; when
    every tier's credits are spent the guard lifts and all tiers become
    eligible again.
    """

    name = "tiered"

    def __init__(
        self,
        aggregator: Any,
        num_rounds: int,
        num_tiers: int = 3,
        latency_fn: Optional[Callable[[str], float]] = None,
        network: Optional[Any] = None,   # repro.runtime.network.NetworkModel
        probe_bytes: int = 1 << 20,
        credits: Optional[int] = None,
        seed: int = 0,
        on_round_end: Optional[Callable[[int, dict[str, Any], list[Message]], None]] = None,
    ) -> None:
        if num_tiers < 1:
            raise ValueError("num_tiers must be >= 1")
        super().__init__(aggregator, num_rounds, on_round_end)
        self.num_tiers = num_tiers
        self.latency_fn = latency_fn
        self.network = network
        self.probe_bytes = probe_bytes
        self.credits = credits
        self._rng = Random(f"tiered:{seed}")
        self.tiers: list[list[str]] = []
        self.tier_of: dict[str, int] = {}
        self.profiled_latency: dict[str, float] = {}
        self.selected_tiers: list[int] = []
        self._credits_left: list[int] = []

    def _estimate_latency(self, client: str) -> float:
        if self.latency_fn is not None:
            return float(self.latency_fn(client))
        if self.network is not None:
            link = self.network.link(client)
            _, compute = self.network.floor_seconds(client)
            return 2.0 * link.base_seconds(self.probe_bytes) + compute
        return 0.0  # no profile: stable sort keeps client-list order

    def begin(self, initial_weights, clients):
        clients = list(clients)
        self.profiled_latency = {c: self._estimate_latency(c) for c in clients}
        by_latency = sorted(clients, key=lambda c: self.profiled_latency[c])
        k = min(self.num_tiers, len(clients))
        bounds = [round(i * len(by_latency) / k) for i in range(k + 1)]
        self.tiers = [by_latency[bounds[i]:bounds[i + 1]] for i in range(k)]
        self.tier_of = {c: i for i, tier in enumerate(self.tiers) for c in tier}
        self._credits_left = [self.credits or 0] * len(self.tiers)
        self.selected_tiers = []
        return super().begin(initial_weights, clients)

    def _select_round_clients(self) -> list[str]:
        eligible = [i for i, left in enumerate(self._credits_left) if left > 0]
        if not eligible:  # no credit scheme, or all spent: every tier eligible
            eligible = list(range(len(self.tiers)))
        idx = eligible[self._rng.randrange(len(eligible))]
        if self._credits_left[idx] > 0:
            self._credits_left[idx] -= 1
        self.selected_tiers.append(idx)
        return list(self.tiers[idx])


# ---------------------------------------------------------------------------
# Policy registry (the job system resolves "runtime.policy" names here)
# ---------------------------------------------------------------------------

#: name -> builder(r, ctx) -> Optional[AggregationPolicy]. ``r`` is the raw
#: job-spec ``"runtime"`` dict; ``ctx`` carries what the job system already
#: built (aggregator, rounds, client_names, network, seed, total_tasks,
#: staleness). Returning None selects the scheduler's default SyncPolicy.
_POLICIES: dict[str, Callable[[Mapping[str, Any], Mapping[str, Any]],
                              Optional[AggregationPolicy]]] = {}


def register_policy(name: str):
    """Decorator binding a spec name to a policy builder — the same
    registry pattern as ``repro.core.pipeline.register_stage``; third-
    party policies become addressable from job specs without touching
    :mod:`repro.fl.job`."""

    def deco(builder):
        if name in _POLICIES:
            raise ValueError(f"policy name {name!r} already registered ({_POLICIES[name]})")
        _POLICIES[name] = builder
        return builder

    return deco


def registered_policies() -> tuple:
    return tuple(sorted(_POLICIES))


def build_policy(name: str, r: Mapping[str, Any],
                 ctx: Mapping[str, Any]) -> Optional[AggregationPolicy]:
    try:
        builder = _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown runtime policy {name!r}; pick from {registered_policies()}"
        ) from None
    return builder(r, ctx)


@register_policy("sync")
def _build_sync(r, ctx):
    # None -> FLSimulator installs its default SyncPolicy (which carries
    # the simulator's on_round_end callback)
    return None


@register_policy("fedbuff")
def _build_fedbuff(r, ctx):
    return FedBuffPolicy(
        ctx["total_tasks"],
        buffer_size=int(r.get("buffer_size", 4)),
        server_lr=float(r.get("server_lr", 1.0)),
        staleness_weight=ctx["staleness"],
    )


@register_policy("fedasync")
def _build_fedasync(r, ctx):
    return FedAsyncPolicy(
        ctx["total_tasks"],
        mixing_rate=float(r.get("mixing_rate", 0.6)),
        staleness_weight=ctx["staleness"],
    )


@register_policy("tiered")
def _build_tiered(r, ctx):
    return TieredPolicy(
        ctx["aggregator"],
        ctx["rounds"],
        num_tiers=int(r.get("num_tiers", 3)),
        network=ctx["network"],
        credits=r.get("credits"),
        seed=ctx["seed"],
    )
