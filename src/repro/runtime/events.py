"""Deterministic simulated-clock event loop for the async FL runtime.

The scheduler never reads the wall clock: every latency in the system —
link transmission time derived from *actual* wire bytes, client compute
time, dropout instants — is expressed in **simulated seconds** and pushed
onto one priority queue. Two runs with the same seeds pop the exact same
event sequence, which is what makes the async federation reproducible
and lets tests assert bitwise equality against the synchronous
controller.

Ordering is (time, seq): ``seq`` is a monotonically increasing insertion
counter, so simultaneous events resolve in schedule order rather than by
heap internals. The loop itself is randomness-free; jitter draws live in
the network model's per-client RNG streams and dropout draws in the
scheduler's seeded stream. Nothing here touches ``time.time()``.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Any, Dict, Iterator, List, Optional


class EventKind(enum.Enum):
    DISPATCH = "dispatch"        # server hands a task to a client link
    ARRIVAL = "arrival"          # task data fully received by the client
    COMPLETION = "completion"    # task result fully received by the server
    DROPOUT = "dropout"          # client failed mid-round (injected fault)
    RETRY = "retry"              # re-dispatch after a dropout
    MODEL_UPDATE = "model_update"  # aggregation produced a new global version


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int
    kind: EventKind
    client: Optional[str] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def sort_key(self):
        return (self.time, self.seq)


class EventLoop:
    """Min-heap of :class:`Event` with a monotone simulated clock."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[tuple] = []
        self._seq = 0
        self.history: List[Event] = []

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def empty(self) -> bool:
        return not self._heap

    def schedule(
        self,
        delay: float,
        kind: EventKind,
        client: Optional[str] = None,
        **data: Any,
    ) -> Event:
        """Schedule ``kind`` at ``now + delay`` (delay in simulated seconds)."""
        return self.schedule_at(self.now + max(0.0, float(delay)), kind, client, **data)

    def schedule_at(
        self,
        time: float,
        kind: EventKind,
        client: Optional[str] = None,
        **data: Any,
    ) -> Event:
        if time < self.now:
            raise ValueError(f"cannot schedule into the past ({time} < {self.now})")
        ev = Event(float(time), self._seq, kind, client, data)
        self._seq += 1
        heapq.heappush(self._heap, (ev.sort_key(), ev))
        return ev

    def peek(self) -> Event:
        """The earliest queued event, without popping or advancing time."""
        if not self._heap:
            raise IndexError("peek into empty event loop")
        return self._heap[0][1]

    def pop(self) -> Event:
        """Pop the earliest event and advance the clock to it."""
        if not self._heap:
            raise IndexError("pop from empty event loop")
        _, ev = heapq.heappop(self._heap)
        self.now = ev.time
        self.history.append(ev)
        return ev

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()
