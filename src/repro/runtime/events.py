"""Deterministic simulated-clock event loop for the async FL runtime.

The scheduler never reads the wall clock: every latency in the system —
link transmission time derived from *actual* wire bytes, client compute
time, dropout instants — is expressed in **simulated seconds** and pushed
onto one priority queue. Two runs with the same seeds pop the exact same
event sequence, which is what makes the async federation reproducible
and lets tests assert bitwise equality against the synchronous
controller.

Ordering is (time, seq): ``seq`` is a monotonically increasing insertion
counter, so simultaneous events resolve in schedule order rather than by
heap internals. The loop itself is randomness-free; jitter draws live in
the network model's per-client RNG streams and dropout draws in the
scheduler's seeded stream. Nothing here touches ``time.time()``.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
import heapq
import json
import math
from random import Random
from collections.abc import Iterator, Mapping, Sequence
from typing import Any, Optional


class EventKind(enum.Enum):
    DISPATCH = "dispatch"        # server hands a task to a client link
    ARRIVAL = "arrival"          # task data fully received by the client
    COMPLETION = "completion"    # task result fully received by the server
    DROPOUT = "dropout"          # client failed mid-round (injected fault)
    RETRY = "retry"              # re-dispatch after a dropout
    MODEL_UPDATE = "model_update"  # aggregation produced a new global version
    DEFERRED = "deferred"        # dispatch parked until the client's next arrival
    INTERRUPT = "interrupt"      # client departed mid round trip (availability)


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int
    kind: EventKind
    client: Optional[str] = None
    data: dict[str, Any] = dataclasses.field(default_factory=dict)

    def sort_key(self):
        return (self.time, self.seq)


class EventLoop:
    """Min-heap of :class:`Event` with a monotone simulated clock."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: list[tuple] = []
        self._seq = 0
        self.history: list[Event] = []

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def empty(self) -> bool:
        return not self._heap

    def schedule(
        self,
        delay: float,
        kind: EventKind,
        client: Optional[str] = None,
        **data: Any,
    ) -> Event:
        """Schedule ``kind`` at ``now + delay`` (delay in simulated seconds)."""
        return self.schedule_at(self.now + max(0.0, float(delay)), kind, client, **data)

    def schedule_at(
        self,
        time: float,
        kind: EventKind,
        client: Optional[str] = None,
        **data: Any,
    ) -> Event:
        if time < self.now:
            raise ValueError(f"cannot schedule into the past ({time} < {self.now})")
        ev = Event(float(time), self._seq, kind, client, data)
        self._seq += 1
        heapq.heappush(self._heap, (ev.sort_key(), ev))
        return ev

    def peek(self) -> Event:
        """The earliest queued event, without popping or advancing time."""
        if not self._heap:
            raise IndexError("peek into empty event loop")
        return self._heap[0][1]

    def pop(self) -> Event:
        """Pop the earliest event and advance the clock to it."""
        if not self._heap:
            raise IndexError("pop from empty event loop")
        _, ev = heapq.heappop(self._heap)
        self.now = ev.time
        self.history.append(ev)
        return ev

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


# ---------------------------------------------------------------------------
# Client availability: arrival/departure schedules
# ---------------------------------------------------------------------------

class AvailabilityTrace:
    """Per-client online windows (arrival/departure schedule).

    ``intervals`` maps a client name to half-open ``[start, end)`` windows
    in simulated seconds during which the client is reachable; ``end`` may
    be ``inf`` for an open-ended final window. Clients absent from the
    mapping are **always online** — an empty trace is the idealized fleet.

    This replaces Bernoulli-only dropout with the trace-driven churn of
    real cross-device fleets: the scheduler defers dispatches to offline
    clients until their next arrival, and a departure mid round trip
    interrupts the trip (the task is re-dispatched on return). Traces are
    plain data — load them from a file (:meth:`from_file`) or synthesize
    them (:func:`periodic_availability`, :func:`random_availability`).
    """

    def __init__(self, intervals: Mapping[str, Sequence[tuple[float, float]]]) -> None:
        self._starts: dict[str, list[float]] = {}
        self._ends: dict[str, list[float]] = {}
        for client, wins in intervals.items():
            merged = _merge_windows(wins)
            self._starts[client] = [s for s, _ in merged]
            self._ends[client] = [e for _, e in merged]

    # -- queries -----------------------------------------------------------
    def _window_index(self, client: str, t: float) -> int:
        """Index of the last window starting at or before ``t`` (-1: none)."""
        return bisect.bisect_right(self._starts[client], t) - 1

    def is_online(self, client: str, t: float) -> bool:
        if client not in self._starts:
            return True
        i = self._window_index(client, t)
        return i >= 0 and t < self._ends[client][i]

    def next_arrival(self, client: str, t: float) -> float:
        """Earliest time >= ``t`` at which ``client`` is online (``t`` itself
        if already online; ``inf`` if the client never returns)."""
        if self.is_online(client, t):
            return t
        starts = self._starts[client]
        i = bisect.bisect_left(starts, t)
        return starts[i] if i < len(starts) else math.inf

    def online_until(self, client: str, t: float) -> float:
        """End of the online window containing ``t`` (``t`` if offline,
        ``inf`` if the client is always online / in an open-ended window)."""
        if client not in self._starts:
            return math.inf
        i = self._window_index(client, t)
        if i < 0 or t >= self._ends[client][i]:
            return t
        return self._ends[client][i]

    def clients(self) -> list[str]:
        return list(self._starts)

    def windows(self, client: str) -> list[tuple[float, float]]:
        if client not in self._starts:
            return [(0.0, math.inf)]
        return list(zip(self._starts[client], self._ends[client]))

    # -- (de)serialization -------------------------------------------------
    @classmethod
    def from_file(cls, path: str) -> AvailabilityTrace:
        """Load a trace: JSON ``{"client": [[start, end], ...]}`` or CSV
        lines ``client,start,end`` (``end`` may be ``inf``); ``#`` comments
        and blank lines are skipped in CSV."""
        with open(path) as fh:
            text = fh.read()
        if text.lstrip().startswith("{"):
            raw = json.loads(text)
            return cls({c: [(float(s), float(e)) for s, e in wins] for c, wins in raw.items()})
        intervals: dict[str, list[tuple[float, float]]] = {}
        for line in text.splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            client, start, end = (f.strip() for f in line.split(","))
            intervals.setdefault(client, []).append((float(start), float(end)))
        return cls(intervals)

    def to_file(self, path: str) -> None:
        payload = {
            c: [[s, "inf" if math.isinf(e) else e] for s, e in self.windows(c)]
            for c in self._starts
        }
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1)


def _merge_windows(wins: Sequence[tuple[float, float]]) -> list[tuple[float, float]]:
    """Sort, validate, and merge overlapping/adjacent online windows."""
    out: list[tuple[float, float]] = []
    for start, end in sorted((float(s), float(e)) for s, e in wins):
        if end <= start:
            raise ValueError(f"empty availability window [{start}, {end})")
        if out and start <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], end))
        else:
            out.append((start, end))
    return out


def periodic_availability(
    clients: Sequence[str],
    period_s: float,
    horizon_s: float,
    duty_cycle: float = 0.5,
    stagger: bool = True,
) -> AvailabilityTrace:
    """Diurnal-style availability: each client is online for the first
    ``duty_cycle`` fraction of every ``period_s`` window, phase-shifted
    per client when ``stagger`` so the fleet never goes dark at once.
    After ``horizon_s`` every client comes (and stays) online, so jobs
    always terminate."""
    if not 0.0 < duty_cycle <= 1.0:
        raise ValueError("duty_cycle must be in (0, 1]")
    if not math.isfinite(horizon_s) or horizon_s <= 0:
        raise ValueError("horizon_s must be finite and positive")
    intervals: dict[str, list[tuple[float, float]]] = {}
    for i, client in enumerate(clients):
        offset = (i / max(1, len(clients))) * period_s if stagger else 0.0
        wins: list[tuple[float, float]] = []
        # the tail of the previous (phase-shifted) on-window may cover t=0
        head_end = offset - (1.0 - duty_cycle) * period_s
        if offset > 0.0 and head_end > 0.0:
            wins.append((0.0, min(head_end, horizon_s)))
        start = offset
        while start < horizon_s:
            wins.append((start, min(start + duty_cycle * period_s, horizon_s)))
            start += period_s
        wins.append((horizon_s, math.inf))
        intervals[client] = wins
    return AvailabilityTrace(intervals)


def availability_from_spec(spec: Mapping, clients: Sequence[str]) -> AvailabilityTrace:
    """Build an AvailabilityTrace from a declarative job-spec dict.

    Shapes (``kind`` selects the source)::

        {"kind": "file", "path": "traces/fleet.json"}
        {"kind": "windows", "windows": {"site-0": [[0, 10], [20, "inf"]]}}
        {"kind": "periodic", "period_s": 60, "duty_cycle": 0.5,
         "horizon_s": 600, "stagger": true}
        {"kind": "random", "mean_online_s": 120, "mean_offline_s": 60,
         "horizon_s": 600, "seed": 0}
    """
    spec = dict(spec)
    kind = spec.get("kind", "windows" if "windows" in spec else None)
    if kind == "file":
        return AvailabilityTrace.from_file(spec["path"])
    if kind == "windows":
        return AvailabilityTrace(
            {c: [(float(s), float(e)) for s, e in wins]
             for c, wins in spec["windows"].items()}
        )
    if kind == "periodic":
        return periodic_availability(
            clients,
            period_s=float(spec["period_s"]),
            horizon_s=float(spec["horizon_s"]),
            duty_cycle=float(spec.get("duty_cycle", 0.5)),
            stagger=bool(spec.get("stagger", True)),
        )
    if kind == "random":
        return random_availability(
            clients,
            mean_online_s=float(spec["mean_online_s"]),
            mean_offline_s=float(spec["mean_offline_s"]),
            horizon_s=float(spec["horizon_s"]),
            seed=int(spec.get("seed", 0)),
        )
    raise ValueError(f"unknown availability spec kind: {kind!r}")


def random_availability(
    clients: Sequence[str],
    mean_online_s: float,
    mean_offline_s: float,
    horizon_s: float,
    seed: int = 0,
) -> AvailabilityTrace:
    """Churn model: each client alternates exponentially-distributed
    online/offline stretches (its own seeded stream, so traces are
    deterministic and independent across clients). After ``horizon_s``
    everyone stays online so the federation can always finish."""
    if mean_online_s <= 0 or mean_offline_s <= 0:
        raise ValueError("mean_online_s and mean_offline_s must be positive "
                         "(for an always-online fleet, omit the trace)")
    if not math.isfinite(horizon_s) or horizon_s <= 0:
        raise ValueError("horizon_s must be finite and positive")
    intervals: dict[str, list[tuple[float, float]]] = {}
    for client in clients:
        rng = Random(f"avail:{seed}:{client}")
        wins: list[tuple[float, float]] = []
        duty = mean_online_s / (mean_online_s + mean_offline_s)
        t = 0.0 if rng.random() < duty else rng.expovariate(1.0 / mean_offline_s)
        while t < horizon_s:
            end = t + rng.expovariate(1.0 / mean_online_s)
            wins.append((t, min(end, horizon_s)))
            t = end + rng.expovariate(1.0 / mean_offline_s)
        wins.append((horizon_s, math.inf))
        intervals[client] = wins
    return AvailabilityTrace(intervals)
