"""Per-client link + compute models for the async runtime.

The simulator's ``_Wire`` counts the *actual* bytes each message puts on
the wire (post-filter, so an int8 or NF4 payload is ~4x / ~8x smaller
than fp32). This module converts those byte counts into **simulated
transmission time** per client link, which is how quantization shortens
simulated rounds by a measurable, paper-faithful amount instead of by
assertion.

A :class:`LinkProfile` is (bandwidth, latency, jitter); :data:`PROFILES`
names a few canonical WAN classes (fiber ... satellite) used by the
heterogeneous-federation benchmark. All jitter draws come from a
per-client ``random.Random`` seeded with a string key — CPython seeds
string inputs via SHA-512, so the model is deterministic across
processes without touching ``PYTHONHASHSEED``.
"""
from __future__ import annotations

import dataclasses
from random import Random
from collections.abc import Mapping, Sequence
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """One client's uplink/downlink characteristics."""

    name: str
    bandwidth_mbps: float     # symmetric link rate, megabits per second
    latency_ms: float         # one-way propagation delay
    jitter: float = 0.0       # fractional stddev on transfer time (>= 0)

    def base_seconds(self, nbytes: int) -> float:
        return self.latency_ms / 1e3 + (nbytes * 8.0) / (self.bandwidth_mbps * 1e6)


PROFILES: dict[str, LinkProfile] = {
    "fiber": LinkProfile("fiber", bandwidth_mbps=1000.0, latency_ms=2.0, jitter=0.01),
    "cable": LinkProfile("cable", bandwidth_mbps=200.0, latency_ms=10.0, jitter=0.05),
    "wifi": LinkProfile("wifi", bandwidth_mbps=80.0, latency_ms=5.0, jitter=0.10),
    "lte": LinkProfile("lte", bandwidth_mbps=30.0, latency_ms=40.0, jitter=0.20),
    "dsl": LinkProfile("dsl", bandwidth_mbps=10.0, latency_ms=25.0, jitter=0.08),
    "3g": LinkProfile("3g", bandwidth_mbps=2.0, latency_ms=100.0, jitter=0.30),
    "satellite": LinkProfile("satellite", bandwidth_mbps=25.0, latency_ms=600.0, jitter=0.15),
}


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """How long one local-training task takes on a client device."""

    base_seconds: float = 1.0
    jitter: float = 0.0


class NetworkModel:
    """Maps (client, nbytes) -> simulated transfer seconds, deterministically.

    ``profiles`` assigns each client a :class:`LinkProfile`; clients not in
    the mapping use ``default``. Each client owns a seeded RNG stream so
    jitter sequences are independent of scheduling order on *other* links.
    """

    def __init__(
        self,
        profiles: Optional[Mapping[str, LinkProfile]] = None,
        default: Optional[LinkProfile] = None,
        compute: Optional[Mapping[str, ComputeProfile]] = None,
        default_compute: Optional[ComputeProfile] = None,
        seed: int = 0,
    ) -> None:
        self.profiles = dict(profiles or {})
        self.default = default or PROFILES["wifi"]
        self.compute = dict(compute or {})
        self.default_compute = default_compute or ComputeProfile()
        self.seed = seed
        self._rngs: dict[str, Random] = {}

    def _rng(self, client: str) -> Random:
        rng = self._rngs.get(client)
        if rng is None:
            rng = self._rngs[client] = Random(f"link:{self.seed}:{client}")
        return rng

    def link(self, client: str) -> LinkProfile:
        return self.profiles.get(client, self.default)

    def bandwidth_bps(self, client: str) -> float:
        """The client's link rate in bits/s (AdaptiveQuantizeFilter's unit)."""
        return self.link(client).bandwidth_mbps * 1e6

    def _jittered(self, client: str, base: float, jitter: float) -> float:
        if jitter <= 0.0:
            return base
        # 1 + |N(0, jitter)|: transfers only ever slow down, never go
        # faster than the deterministic lower bound — keeps sim times
        # physical and monotone in bytes.
        return base * (1.0 + abs(self._rng(client).gauss(0.0, jitter)))

    def transfer_seconds(self, client: str, nbytes: int) -> float:
        link = self.link(client)
        return self._jittered(client, link.base_seconds(nbytes), link.jitter)

    def compute_seconds(self, client: str) -> float:
        prof = self.compute.get(client, self.default_compute)
        return self._jittered(client, prof.base_seconds, prof.jitter)

    def floor_seconds(self, client: str) -> tuple[float, float]:
        """(min transfer time, min compute time) for ``client`` — hard
        lower bounds regardless of payload size or jitter draw (jitter
        only ever slows transfers down). The scheduler uses these to
        decide whether an in-flight round trip could still produce an
        event earlier than the next queued one."""
        link = self.link(client)
        prof = self.compute.get(client, self.default_compute)
        return link.latency_ms / 1e3, prof.base_seconds


def heterogeneous_network(
    clients: Sequence[str],
    seed: int = 0,
    tiers: Sequence[str] = ("fiber", "cable", "wifi", "lte", "dsl", "3g"),
    compute_base_s: float = 1.0,
    compute_spread: float = 4.0,
) -> NetworkModel:
    """A mixed federation: link tiers round-robin over ``tiers`` and
    compute speeds spread log-uniformly over [base, base*spread] — the
    straggler-heavy regime where async scheduling pays off.
    """
    rng = Random(f"hetero:{seed}")
    profiles = {c: PROFILES[tiers[i % len(tiers)]] for i, c in enumerate(clients)}
    compute = {
        c: ComputeProfile(compute_base_s * compute_spread ** rng.random(), jitter=0.1)
        for c in clients
    }
    return NetworkModel(profiles, compute=compute, seed=seed)


def _link_from_spec(value) -> LinkProfile:
    """A named WAN class ("fiber") or an inline profile dict."""
    if isinstance(value, str):
        return PROFILES[value]
    return LinkProfile(
        name=value.get("name", "custom"),
        bandwidth_mbps=float(value["bandwidth_mbps"]),
        latency_ms=float(value.get("latency_ms", 10.0)),
        jitter=float(value.get("jitter", 0.0)),
    )


def network_from_spec(spec: Mapping, clients: Sequence[str]) -> NetworkModel:
    """Build a NetworkModel from a declarative job-spec dict.

    Two shapes::

        {"kind": "hetero", "tiers": ["fiber", "3g"], "compute_base_s": 1.0,
         "compute_spread": 4.0, "seed": 0}

        {"default": "wifi",
         "profiles": {"site-0": "fiber",
                      "site-1": {"bandwidth_mbps": 5, "latency_ms": 80}},
         "compute": {"site-0": 0.5}, "compute_base_s": 1.0,
         "compute_jitter": 0.0, "seed": 0}

    Link values are canonical :data:`PROFILES` names or inline dicts.
    """
    spec = dict(spec)
    seed = int(spec.get("seed", 0))
    if spec.get("kind") == "hetero":
        kwargs = {
            k: spec[k]
            for k in ("tiers", "compute_base_s", "compute_spread")
            if k in spec
        }
        if "tiers" in kwargs:
            kwargs["tiers"] = tuple(kwargs["tiers"])
        return heterogeneous_network(clients, seed=seed, **kwargs)
    jitter = float(spec.get("compute_jitter", 0.0))
    return NetworkModel(
        profiles={c: _link_from_spec(v) for c, v in spec.get("profiles", {}).items()},
        default=_link_from_spec(spec.get("default", "wifi")),
        compute={
            c: ComputeProfile(float(v), jitter=jitter)
            for c, v in spec.get("compute", {}).items()
        },
        default_compute=ComputeProfile(float(spec.get("compute_base_s", 1.0)), jitter=jitter),
        seed=seed,
    )
