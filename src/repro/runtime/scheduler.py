"""Event-driven federated scheduler: concurrent clients on a simulated clock.

The seed controller (`ScatterAndGather`) drives clients strictly
sequentially, so round time is the *sum* of client times. This scheduler
runs the same filtered, streamed round trips **concurrently** (a thread
pool executes the real transport — Loopback/TCP/spool drivers, real
serialization, real byte counts) while a deterministic
:class:`~repro.runtime.events.EventLoop` orders everything in simulated
time:

    dispatch --downlink--> arrival --compute--> ... --uplink--> completion

Link and compute durations come from the :class:`NetworkModel`, driven by
the *actual* wire bytes each hop produced — so a quantized federation's
simulated rounds are measurably shorter, not assumed shorter.

Determinism: real executions run on worker threads in any wall-clock
order, but their results are folded into the policy strictly in
(simulated time, schedule seq) order, and every random draw (jitter,
dropout) comes from seeded streams keyed by stable strings. Two runs
with the same seeds produce identical timelines and identical weights.
Stateful filters (error feedback, DP noise) are serialized under
``filter_lock`` for thread-safety, but their state consumption follows
completion order — use stateless filters where bit-reproducibility
across runtimes matters.

Fault injection: each dispatch attempt may drop out (seeded Bernoulli,
``dropout_prob``) partway through its round trip; the scheduler
re-dispatches up to ``max_retries`` times, then reports the client as
failed to the policy (`SyncPolicy` renormalizes over survivors,
`FedBuffPolicy` simply loses the contribution). Chunk-level faults
compose underneath: set ``chunk_drop_prob``/``chunk_dup_prob``/
``chunk_reorder_window`` on the simulator's ``SimulationConfig`` and
every hop runs through :class:`~repro.core.resilience.LossyDriver` +
``ReliableTransfer``. The wire counts retransmitted chunks into the
``wire_bytes_down``/``wire_bytes_up`` headers this scheduler feeds to
the network model, so a lossy link's repairs lengthen simulated
transfer time — measured, not assumed.

Client availability: an optional :class:`AvailabilityTrace` gives each
client arrival/departure windows. A dispatch to an offline client is
**deferred** (parked as a ``DEFERRED`` event at the client's next
arrival, not launched); a departure mid round trip **interrupts** the
trip at the departure instant and re-dispatches on return. Unlike
dropouts, availability churn is scheduled — it never consumes retry
budget. A client that never returns is reported failed to the policy.
"""
from __future__ import annotations

import dataclasses
import math
import time
from concurrent.futures import Future, ThreadPoolExecutor
from random import Random
from collections.abc import Sequence
from typing import Any, Optional

from repro.fl.controller import ClientProxy
from repro.obs import trace as obs_trace
from repro.runtime.async_agg import AggregationPolicy, Dispatch
from repro.runtime.events import AvailabilityTrace, Event, EventKind, EventLoop
from repro.runtime.network import NetworkModel


@dataclasses.dataclass
class RuntimeConfig:
    """Knobs for the async runtime (transport knobs stay in SimulationConfig)."""

    seed: int = 0
    max_concurrency: int = 8
    dropout_prob: float = 0.0
    max_retries: int = 2
    drop_after_frac: float = 0.5   # dropout strikes this far through the round trip


@dataclasses.dataclass
class RuntimeStats:
    dispatches: int = 0
    completions: int = 0
    dropouts: int = 0
    retries: int = 0
    failed_clients: int = 0
    model_updates: int = 0
    deferrals: int = 0      # dispatches parked until a client's arrival
    interruptions: int = 0  # round trips cut short by a client departure
    settle_waves: int = 0   # calls into the settle loop
    settled_futures: int = 0  # round trips timestamped (== dispatches at end)
    partial_settles: int = 0  # settles that stopped early, leaving trips in flight
    sim_time_s: float = 0.0
    events_processed: int = 0  # events popped off the simulated-time queue
    queue_depth_peak: int = 0  # high-water mark of the event queue

    # every field is deterministic across identical-seed runs (wall-clock
    # elapsed deliberately lives on the scheduler, not here — run results
    # embed this dict and tests compare them across runs)
    def as_dict(self) -> dict[str, Any]:
        """JSON-safe export (the metrics-snapshot schema)."""
        return dataclasses.asdict(self)


class AsyncFLScheduler:
    """Runs an :class:`AggregationPolicy` over real client proxies.

    ``streaming_agg=True`` switches the result path to streaming
    aggregation: worker threads run downlink + compute + a byte-pricing
    pass over the uplink (so simulated times are fed the same true wire
    bytes as ever), and the *fold* transfer — decode one item, fold it
    into the policy's per-item running state, free it — runs at the
    COMPLETION instant on the scheduler thread, in simulated-time order.
    One fold stream is live at a time, so server-side
    transmission+aggregation memory peaks at ~one item regardless of how
    many clients are in flight, and fold order is fully deterministic.
    Requires proxies exposing ``stream_task`` (the simulator's proxies
    do) and a stateless task_result pipeline; proxies without it fall
    back to the batch path transparently.
    """

    def __init__(
        self,
        proxies: Sequence[ClientProxy],
        policy: AggregationPolicy,
        network: Optional[NetworkModel] = None,
        config: Optional[RuntimeConfig] = None,
        availability: Optional[AvailabilityTrace] = None,
        streaming_agg: bool = False,
    ) -> None:
        if not proxies:
            raise ValueError("need at least one client proxy")
        self.proxies: dict[str, ClientProxy] = {p.name: p for p in proxies}
        if len(self.proxies) != len(proxies):
            raise ValueError("client proxy names must be unique")
        self.policy = policy
        self.config = config or RuntimeConfig()
        self.network = network or NetworkModel(seed=self.config.seed)
        self.availability = availability
        self.streaming_agg = streaming_agg
        self.loop = EventLoop()
        self.stats = RuntimeStats()
        self.wall_elapsed_s = 0.0  # host time of the last run() (not in stats)
        self._drop_rng = Random(f"dropout:{self.config.seed}")
        # (dispatch, dispatch_sim_time, future) in launch order
        self._inflight: list[tuple[Dispatch, float, Future]] = []

    # -- real execution (worker threads) ------------------------------------
    def _execute(self, dispatch: Dispatch) -> Any:
        proxy = self.proxies[dispatch.client]
        if self.streaming_agg and hasattr(proxy, "stream_task"):
            return proxy.stream_task(dispatch.task)  # deferred-uplink handle
        return proxy.submit_task(dispatch.task)

    def _fail_client(self, dispatch: Dispatch, pool: ThreadPoolExecutor) -> None:
        self.stats.failed_clients += 1
        for d in self.policy.on_client_failed(dispatch):
            self._launch(d, pool)

    def _launch(self, dispatch: Dispatch, pool: ThreadPoolExecutor) -> None:
        if self.availability is not None and not self.availability.is_online(
            dispatch.client, self.loop.now
        ):
            arrival = self.availability.next_arrival(dispatch.client, self.loop.now)
            if math.isinf(arrival):  # departed for good: permanent failure
                self._fail_client(dispatch, pool)
                return
            self.stats.deferrals += 1
            self.loop.schedule_at(arrival, EventKind.DEFERRED, dispatch.client,
                                  dispatch=dispatch)
            return
        self.stats.dispatches += 1
        self.loop.schedule(0.0, EventKind.DISPATCH, dispatch.client,
                           version=dispatch.version, attempt=dispatch.attempt)
        self._inflight.append((dispatch, self.loop.now, pool.submit(self._execute, dispatch)))

    # -- folding real results into simulated time ---------------------------
    def _earliest_possible(self, dispatch: Dispatch, t0: float) -> float:
        """Hard lower bound on the simulated time of any event this
        in-flight round trip can produce (its ARRIVAL, a DROPOUT that
        strikes partway through the minimum-duration trip, or an
        INTERRUPT at the client's scheduled departure)."""
        lat, comp = self.network.floor_seconds(dispatch.client)
        bound = t0 + min(lat, self.config.drop_after_frac * (2.0 * lat + comp))
        if self.availability is not None:
            bound = min(bound, self.availability.online_until(dispatch.client, t0))
        return bound

    def _must_settle(self) -> bool:
        """True when an in-flight trip could still beat the next queued
        event in simulated time — only then does the loop block on real
        results. Otherwise queued events are processed first, leaving
        in-flight transports running in parallel on the pool."""
        if not self._inflight:
            return False
        if self.loop.empty:
            return True
        next_t = self.loop.peek().time
        return any(
            self._earliest_possible(d, t0) < next_t for d, t0, _ in self._inflight
        )

    def _settle(self) -> None:
        """Timestamp in-flight round trips, in launch order, stopping as
        soon as no remaining trip can beat the queue's head.

        Event *times* depend only on bytes + seeds, never on which
        worker thread finished first, and futures are settled in launch
        order, so the timeline is deterministic. The early stop is the
        settle-wave relaxation (profiled at 200 clients): the old
        full-wave barrier blocked on *every* in-flight future before
        processing the next event, so one wall-clock straggler stalled
        the whole loop even when its earliest possible event lay far in
        the simulated future. Settling only the launch-order prefix that
        can still affect the next event lets queued completions process
        — and their follow-up dispatches launch — while stragglers keep
        running on the pool. The dropout RNG is consumed in launch order
        either way, so timelines are unchanged.
        """
        self.stats.settle_waves += 1
        pending = self._inflight
        self._inflight = []
        while pending:
            if not self.loop.empty:
                next_t = self.loop.peek().time
                if all(self._earliest_possible(d, t0) >= next_t
                       for d, t0, _ in pending):
                    self._inflight = pending
                    self.stats.partial_settles += 1
                    return
            dispatch, t0, future = pending.pop(0)
            self._settle_one(dispatch, t0, future)
            self.stats.settled_futures += 1

    def _settle_one(self, dispatch: Dispatch, t0: float, future: Future) -> None:
        """Wait for one round trip and schedule its timeline events."""
        result = future.result()
        # true bytes-on-wire (frames + envelopes + retransmissions) as
        # stamped by the simulator wire; payload size is the fallback
        # for proxies that don't measure their transport
        down = int(result.headers.get("wire_bytes_down", dispatch.task.payload_bytes()))
        up = int(result.headers.get("wire_bytes_up", result.payload_bytes()))
        t_down = self.network.transfer_seconds(dispatch.client, down)
        t_compute = self.network.compute_seconds(dispatch.client)
        t_up = self.network.transfer_seconds(dispatch.client, up)
        total = t_down + t_compute + t_up
        departs = (
            self.availability.online_until(dispatch.client, t0)
            if self.availability is not None else math.inf
        )
        dropped = self._drop_rng.random() < self.config.dropout_prob
        drop_t = t0 + self.config.drop_after_frac * total
        tr = obs_trace.ACTIVE
        if dropped and drop_t < departs:
            if tr is not None:
                tr.sim_span("trip.dropped", t0, drop_t, track=dispatch.client,
                            cat="trip", version=dispatch.version,
                            attempt=dispatch.attempt)
            self.loop.schedule_at(drop_t, EventKind.DROPOUT, dispatch.client,
                                  dispatch=dispatch)
        elif t0 + total > departs:
            # client leaves mid round trip: the trip dies at the
            # departure instant and re-dispatches on the next arrival
            if tr is not None:
                tr.sim_span("trip.interrupted", t0, departs, track=dispatch.client,
                            cat="trip", version=dispatch.version,
                            attempt=dispatch.attempt)
            if t0 + t_down < departs:
                self.loop.schedule_at(t0 + t_down, EventKind.ARRIVAL, dispatch.client,
                                      version=dispatch.version)
            self.loop.schedule_at(departs, EventKind.INTERRUPT, dispatch.client,
                                  dispatch=dispatch)
        else:
            if tr is not None:
                # the round trip's simulated anatomy, one track per client
                c = dispatch.client
                tr.sim_span("downlink", t0, t0 + t_down, track=c, cat="trip",
                            version=dispatch.version, attempt=dispatch.attempt,
                            wire_bytes=down)
                tr.sim_span("compute", t0 + t_down, t0 + t_down + t_compute,
                            track=c, cat="trip", version=dispatch.version)
                tr.sim_span("uplink", t0 + t_down + t_compute, t0 + total,
                            track=c, cat="trip", version=dispatch.version,
                            wire_bytes=up)
            self.loop.schedule_at(t0 + t_down, EventKind.ARRIVAL, dispatch.client,
                                  version=dispatch.version)
            self.loop.schedule_at(
                t0 + total,
                EventKind.COMPLETION,
                dispatch.client,
                dispatch=dispatch,
                result=result,
            )

    # -- event handlers (scheduler thread, simulated-time order) ------------
    def _handle(self, event: Event, pool: ThreadPoolExecutor) -> None:
        if event.kind is EventKind.COMPLETION:
            self.stats.completions += 1
            dispatch: Dispatch = event.data["dispatch"]
            result = event.data["result"]
            before = self.policy.model_version
            if hasattr(result, "deliver"):
                # streaming aggregation: the uplink fold transfer runs
                # NOW, on this thread, in simulated-time order — one
                # decoded item live at a time, straight into the
                # policy's per-item running state
                follow_ups = self.policy.on_result_stream(
                    dispatch, result.headers, result.deliver
                )
            else:
                follow_ups = self.policy.on_result(dispatch, result)
            if self.policy.model_version != before:
                self.stats.model_updates += 1
                self.loop.schedule(0.0, EventKind.MODEL_UPDATE,
                                   version=self.policy.model_version)
            for d in follow_ups:
                self._launch(d, pool)
        elif event.kind is EventKind.DROPOUT:
            self.stats.dropouts += 1
            dispatch = event.data["dispatch"]
            if dispatch.attempt < self.config.max_retries:
                self.stats.retries += 1
                retry = Dispatch(dispatch.client, dispatch.task,
                                 dispatch.version, dispatch.attempt + 1)
                self.loop.schedule(0.0, EventKind.RETRY, dispatch.client,
                                   attempt=retry.attempt)
                self._launch(retry, pool)
            else:
                self._fail_client(dispatch, pool)
        elif event.kind is EventKind.DEFERRED:
            # the client just arrived: launch the parked dispatch for real
            self._launch(event.data["dispatch"], pool)
        elif event.kind is EventKind.INTERRUPT:
            # departure killed the trip; re-dispatch (defers to next
            # arrival). Availability churn never consumes retry budget.
            self.stats.interruptions += 1
            self._launch(event.data["dispatch"], pool)
        # DISPATCH / ARRIVAL / RETRY / MODEL_UPDATE are timeline markers

    # -- main loop -----------------------------------------------------------
    def run(self, initial_weights: dict[str, Any]) -> dict[str, Any]:
        t_start = time.perf_counter()
        with ThreadPoolExecutor(max_workers=self.config.max_concurrency) as pool:
            for d in self.policy.begin(dict(initial_weights), list(self.proxies)):
                self._launch(d, pool)
            while self._inflight or not self.loop.empty:
                if self._must_settle():
                    tr = obs_trace.ACTIVE
                    if tr is None:
                        self._settle()
                    else:
                        with tr.span("sched.settle", "sched",
                                     inflight=len(self._inflight)):
                            self._settle()
                if self.loop.empty:
                    break
                depth = len(self.loop)
                if depth > self.stats.queue_depth_peak:
                    self.stats.queue_depth_peak = depth
                event = self.loop.pop()
                self.stats.events_processed += 1
                tr = obs_trace.ACTIVE
                if tr is not None:
                    # timeline markers on the simulated clock: one track
                    # per client plus the queue-depth counter series
                    tr.sim_instant(event.kind.value, event.time,
                                   track=event.client or "scheduler",
                                   cat="event", seq=event.seq)
                    tr.sim_counter("queue_depth", event.time, depth - 1)
                self._handle(event, pool)
        self.wall_elapsed_s = time.perf_counter() - t_start
        self.stats.sim_time_s = self.loop.now
        if not self.policy.complete:
            raise RuntimeError(
                f"{self.policy.name}: federation ended before the policy "
                "completed its budget (did every client drop out?)"
            )
        return self.policy.finish()

    @property
    def timeline(self) -> list[Event]:
        """Processed events in simulated-time order (the run's trace)."""
        return list(self.loop.history)
