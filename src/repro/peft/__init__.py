"""Parameter-efficient payload plane (LoRA-style low-rank wire kinds).

:mod:`repro.peft.lowrank` defines :class:`LowRankDelta`, the factor-pair
wire container; :mod:`repro.peft.stage` registers the ``lora[:rank]``
pipeline stage. The stage module is deliberately NOT imported here —
``repro.core.serialization`` imports this package for the wire kind, and
the stage imports ``repro.core.pipeline``; importing it at package level
would close that cycle. ``repro.core.pipeline`` imports the stage module
itself (bottom of the file, once the registry exists), so the ``lora``
stage is always registered wherever the pipeline registry is in use.
"""
from repro.peft.lowrank import LowRankDelta

__all__ = ["LowRankDelta"]
