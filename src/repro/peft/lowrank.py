"""Low-rank factor-pair wire type (LoRA adapters / truncated deltas).

:class:`LowRankDelta` is the wire form of a parameter-efficient payload
item: instead of a dense ``(m, n)`` tensor the message carries the factor
pair ``a (m, r)`` / ``b (r, n)`` plus the LoRA scaling metadata, so the
item costs ``r * (m + n)`` floats on the wire instead of ``m * n`` —
orders of magnitude below even nf4 at LLM shapes. It crosses the wire
through :mod:`repro.core.serialization` exactly like
:class:`~repro.core.sparse.SparseTensor` (its own ``"lowrank"`` item
kind, scatter-gather views over the factor buffers), and the ``lora``
pipeline stage (:mod:`repro.peft.stage`) produces/consumes it per item
inside the streaming loop. Byte stages (``zstd``, ``crc32``) see the
factors as opaque item bytes; value stages (``quantize``, ``delta``)
pass the container through untouched, like they do sparse items.

The dense form is ``(alpha / rank) * (a @ b)`` — the standard LoRA
scaling convention, so natively-trained adapter pairs (see
``repro.models.layers.lora_adapter_spec``) ship on the wire without any
decomposition step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class LowRankDelta:
    """Wire format for one low-rank factored tensor."""

    a: np.ndarray                        # (m, rank) left factor
    b: np.ndarray                        # (rank, n) right factor
    alpha: float                         # LoRA scale numerator
    rank: int
    orig_shape: tuple[int, ...]          # dense shape ((m, n) or higher-rank)
    orig_dtype: Any

    @property
    def total_bytes(self) -> int:
        return int(np.asarray(self.a).nbytes) + int(np.asarray(self.b).nbytes)

    @property
    def scale(self) -> float:
        """The LoRA merge scale ``alpha / rank``."""
        return float(self.alpha) / float(self.rank)

    @property
    def dense_bytes(self) -> int:
        """What the dense form would cost at original dtype."""
        n = int(np.prod(self.orig_shape)) if self.orig_shape else 1
        return n * np.dtype(self.orig_dtype).itemsize

    def to_dense(self) -> np.ndarray:
        """Merge the factors: ``(alpha / rank) * (a @ b)`` reshaped and
        cast back to the original dtype (one fused jitted dispatch —
        :func:`repro.kernels.ops.low_rank_merge`)."""
        from repro.kernels import ops  # lazy: keep the wire type import-light

        dense = np.asarray(ops.low_rank_merge(self.a, self.b, self.scale))
        return dense.reshape(self.orig_shape).astype(
            np.dtype(self.orig_dtype), copy=False
        )
