"""The ``lora[:rank]`` pipeline stage: dense deltas -> low-rank factors.

Encode decomposes each eligible float matrix into a truncated-SVD factor
pair (:func:`repro.kernels.ops.low_rank_decompose`, one fused jitted
dispatch per tensor) and ships a
:class:`~repro.peft.lowrank.LowRankDelta`; decode merges the factors
back to a dense array. Spec forms::

    "lora"                     # rank 8
    "lora:16"                  # rank 16
    {"stage": "lora", "rank": 8, "alpha": 16, "min_params": 4096}

Eligibility mirrors the other lossy stages (``quantize``/``topk``):
plain float tensors only, with at least 2 dims, ``min_params`` or more
elements, and small enough rank that the factors actually beat the
dense form (``rank * (m + n) < m * n``); everything else passes through
untouched — so a stacked ``lora:8 -> quantize:nf4`` pipeline low-ranks
the big matrices and nf4-quantizes the norms/biases the lora stage
skipped. Higher-rank tensors flatten their leading dims (``orig_shape``
restores them on merge).

Decomposition is deterministic (jitted SVD + sign canonicalization), so
the stage is stateless and re-encoding the same payload yields identical
wire bytes — the contract both the async scheduler's double-encode path
and the live federation's re-grant path rely on.

Native-adapter mode needs no stage at all: clients that train LoRA
pairs directly (``repro.models.layers.lora_adapter_params``) put
:class:`LowRankDelta` items straight into the payload, and the wire
kind, byte stages, and :class:`~repro.fl.aggregator.LoRAFedAvgAggregator`
treat them identically to decomposed deltas.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.core.pipeline import Stage, WireContext, register_stage
from repro.kernels import ops
from repro.peft.lowrank import LowRankDelta


def _matrix_dims(shape: tuple[int, ...]) -> tuple[int, int]:
    """Collapse leading dims: the decomposed matrix is (prod(lead), last)."""
    return int(np.prod(shape[:-1])), int(shape[-1])


@register_stage("lora")
class LoRAStage(Stage):
    """Per-item low-rank decomposition (parameter-efficient payloads)."""

    def __init__(self, rank: int = 8, alpha: Optional[float] = None,
                 min_params: int = 1024) -> None:
        if rank < 1:
            raise ValueError(f"lora stage needs rank >= 1, got {rank}")
        self.rank = int(rank)
        # alpha defaults to rank: merge scale alpha/rank == 1, so a
        # decomposed delta round-trips to its best rank-r approximation
        self.alpha = float(alpha) if alpha is not None else float(rank)
        self.min_params = int(min_params)

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> LoRAStage:
        if arg is not None:
            kwargs.setdefault("rank", int(arg))
        return cls(**kwargs)

    def _eligible(self, value: Any) -> bool:
        if isinstance(value, LowRankDelta):  # already factored (native adapters)
            return False
        arr = np.asarray(value) if not hasattr(value, "dtype") else value
        try:
            dtype = np.dtype(arr.dtype)
            shape = tuple(arr.shape)
        except (TypeError, AttributeError):
            return False
        if not np.issubdtype(dtype, np.floating) or len(shape) < 2:
            return False
        m, n = _matrix_dims(shape)
        if m * n < self.min_params or self.rank > min(m, n):
            return False
        # factors must actually be smaller than the dense tensor
        return self.rank * (m + n) < m * n

    def begin_encode(self, message, ctx: WireContext):
        ctx.headers["lora_rank"] = self.rank
        return message

    def end_decode(self, message, ctx: WireContext):
        if ctx.decode_values:
            message.headers.pop("lora_rank", None)
        return message

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if not self._eligible(value):
            return value
        arr = np.asarray(value)
        m, n = _matrix_dims(arr.shape)
        a, b = ops.low_rank_decompose(arr.reshape(m, n), self.rank)
        ctx.vmeta["r"] = self.rank
        ctx.vmeta["n"] = int(arr.size)
        return LowRankDelta(np.asarray(a), np.asarray(b), self.alpha,
                            self.rank, tuple(arr.shape), arr.dtype)

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return value.to_dense() if isinstance(value, LowRankDelta) else value
