"""Streaming checkpointing — the paper's file/container streaming applied

to persistence: the checkpoint is written **one state-dict item at a
time** using the same framed item format as the wire (so a checkpoint
file can be served directly by ``FileStreamer`` and consumed incrementally
by ``ContainerReceiver`` — checkpoint transfer and message transfer are
the same code path). Peak writer memory = one serialized item, never the
whole model.

Layout:  item_count (u32) | serialized items (see repro.core.serialization)
Optionally each item is quantized on disk (4-bit checkpoints = the wire
format at rest).
"""
from __future__ import annotations

import os
import struct
from collections.abc import Callable, Iterator
from typing import Any, Optional

import numpy as np

from repro.core import serialization as ser
from repro.core.quantization import QuantizedTensor, dequantize, quantize
from repro.utils import mem
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

_U32 = struct.Struct("<I")


def save_checkpoint(path: str, tree: Any, *, fmt: Optional[str] = None) -> int:
    """Write ``tree`` (nested pytree of arrays) item-by-item. Returns bytes.

    ``fmt``: optional quantization format for at-rest compression.
    """
    flat = flatten_state_dict(tree)
    total = 0
    with open(path, "wb") as fh:
        fh.write(_U32.pack(len(flat)))
        for name, arr in flat.items():
            value: Any = np.asarray(arr)
            if fmt is not None and np.issubdtype(value.dtype, np.floating):
                value = quantize(value, fmt)
            item = ser.serialize_item(name, value)
            with mem.record_hold(len(item)):
                fh.write(item)
            total += len(item)
    return total + 4


def iter_checkpoint(path: str) -> Iterator[tuple[str, Any]]:
    """Stream items off disk one at a time (peak memory = one item)."""
    size = os.path.getsize(path)
    with open(path, "rb") as fh:
        (n,) = _U32.unpack(fh.read(4))
        for _ in range(n):
            (hlen,) = _U32.unpack(fh.read(4))
            header = fh.read(hlen)
            # re-parse via deserialize_item on a reconstructed buffer; body
            # length is derivable from the header
            import json

            h = json.loads(header.decode())
            if h["kind"] == "qtensor":
                pshape = tuple(h["payload_shape"])
                pdtype = np.dtype(h["payload_dtype"])
                body_len = int(np.prod(pshape)) * pdtype.itemsize + h["absmax_len"]
            else:
                shape = tuple(h["shape"])
                body_len = int(np.prod(shape)) * np.dtype(h["dtype"]).itemsize
            body = fh.read(body_len)
            buf = _U32.pack(hlen) + header + body
            with mem.record_hold(len(buf)):
                name, value, _ = ser.deserialize_item(buf)
            if isinstance(value, QuantizedTensor):
                value = np.asarray(dequantize(value))
            yield name, value


def load_checkpoint(path: str) -> dict[str, Any]:
    return unflatten_state_dict(dict(iter_checkpoint(path)))


def load_checkpoint_streaming(
    path: str, consume: Callable[[str, Any], None]
) -> int:
    """Incremental load: hand each item to ``consume`` without ever

    materializing the whole dict (e.g. assigning into a pre-allocated
    sharded param tree)."""
    count = 0
    for name, value in iter_checkpoint(path):
        consume(name, value)
        count += 1
    return count


# ---------------------------------------------------------------------------
# Live-server round state (crash/resume for the federation plane)
# ---------------------------------------------------------------------------

def save_server_state(ckpt_dir: str, rnd: int, weights: Any,
                      meta: Optional[dict[str, Any]] = None,
                      keep: int = 3) -> str:
    """Atomically persist one completed federation round.

    Two files per round: ``round_NNNNNN.ckpt`` — the flat global weights
    in the wire item format (unquantized, so a resume is bitwise) — and
    ``round_NNNNNN.json`` — round number + caller metadata (roster,
    round log). Both are written to a temp name, fsynced, and renamed
    into place, weights first: the meta JSON is the **commit point**, so
    a crash at any instant leaves either a complete older checkpoint or
    a complete newer one, never a half-valid state. Keeps the newest
    ``keep`` rounds and prunes older pairs. Returns the meta path.
    """
    import json

    os.makedirs(ckpt_dir, exist_ok=True)
    wname = f"round_{rnd:06d}.ckpt"
    wtmp = os.path.join(ckpt_dir, wname + ".tmp")
    save_checkpoint(wtmp, dict(weights))
    with open(wtmp, "rb") as fh:
        os.fsync(fh.fileno())
    os.replace(wtmp, os.path.join(ckpt_dir, wname))
    doc = {"round": int(rnd), "weights": wname, **dict(meta or {})}
    mname = f"round_{rnd:06d}.json"
    mtmp = os.path.join(ckpt_dir, mname + ".tmp")
    with open(mtmp, "w") as fh:
        json.dump(doc, fh)
        fh.flush()
        os.fsync(fh.fileno())
    mpath = os.path.join(ckpt_dir, mname)
    os.replace(mtmp, mpath)
    rounds = sorted(
        f[:-5] for f in os.listdir(ckpt_dir)
        if f.startswith("round_") and f.endswith(".json"))
    for stem in rounds[:-keep] if keep > 0 else []:
        for suffix in (".json", ".ckpt"):
            try:
                os.unlink(os.path.join(ckpt_dir, stem + suffix))
            except OSError:
                pass
    return mpath


def latest_server_state(ckpt_dir: str) -> Optional[dict[str, Any]]:
    """Newest complete round checkpoint in ``ckpt_dir``, or ``None``.

    Scans meta files newest-first and returns the first whose weights
    file exists, as ``{"round", "weights" (flat state dict), "meta"}``.
    The weights load **flat** (``dict(iter_checkpoint(...))``), never
    through ``load_checkpoint`` — unflattening dotted wire names into
    nested dicts would change the state-dict shape the server folds and
    downlinks.
    """
    import json

    if not os.path.isdir(ckpt_dir):
        return None
    metas = sorted(
        (f for f in os.listdir(ckpt_dir)
         if f.startswith("round_") and f.endswith(".json")),
        reverse=True)
    for mname in metas:
        try:
            with open(os.path.join(ckpt_dir, mname)) as fh:
                doc = json.load(fh)
            wpath = os.path.join(ckpt_dir, doc["weights"])
            weights = dict(iter_checkpoint(wpath))
        except (OSError, ValueError, KeyError, struct.error):
            continue  # torn leftovers from a crash mid-write: skip
        return {"round": int(doc["round"]), "weights": weights, "meta": doc}
    return None
