from repro.checkpoint.streaming_ckpt import (
    load_checkpoint,
    load_checkpoint_streaming,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "load_checkpoint_streaming"]
