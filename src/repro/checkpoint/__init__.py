from repro.checkpoint.streaming_ckpt import (
    latest_server_state,
    load_checkpoint,
    load_checkpoint_streaming,
    save_checkpoint,
    save_server_state,
)

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_checkpoint_streaming",
    "save_server_state",
    "latest_server_state",
]
