"""In-process FL simulator: wires Controller, Executors, the four filter

points and the streaming transport into one runnable federation —
NVFlare's simulator analogue. Every message physically crosses the
streaming layer (serialized, framed, chunked, reassembled), so byte
counts and peak transmission memory are real, not estimated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import streaming as sm
from repro.core.filters import FilterChain, FilterPoint, no_filters
from repro.core.messages import Message, MessageKind
from repro.fl.controller import ClientProxy, ScatterAndGather
from repro.fl.executor import Executor
from repro.utils.mem import MemoryMeter


@dataclasses.dataclass
class SimulationConfig:
    num_rounds: int = 1
    transmission: str = "container"     # regular | container | file
    chunk_size: int = sm.DEFAULT_CHUNK_SIZE
    driver: str = "loopback"            # loopback | tcp | spool
    spool_dir: Optional[str] = None


@dataclasses.dataclass
class TrafficStats:
    messages: int = 0
    bytes_sent: int = 0

    def add(self, nbytes: int) -> None:
        self.messages += 1
        self.bytes_sent += nbytes


class _Wire:
    """One filtered, streamed hop: serialize -> frames -> reassemble."""

    def __init__(self, cfg: SimulationConfig, stats: TrafficStats) -> None:
        self.cfg = cfg
        self.stats = stats

    def _driver(self) -> sm.Driver:
        if self.cfg.driver == "tcp":
            return sm.TCPDriver()
        if self.cfg.driver == "spool":
            assert self.cfg.spool_dir is not None
            return sm.FileSpoolDriver(self.cfg.spool_dir)
        return sm.LoopbackDriver()

    def transmit(self, message: Message) -> Message:
        self.stats.add(message.payload_bytes())
        driver = self._driver()
        if self.cfg.transmission == "regular":
            recv: Any = sm.BlobReceiver()
            driver.connect(recv.on_chunk)
            sm.ObjectStreamer(driver, self.cfg.chunk_size).send_container(message.payload)
        else:
            # container streaming is also the carrier for "file" payloads in
            # the simulator; true file transfer is exercised by FileStreamer
            # paths in the streaming demo / Table III benchmark.
            recv = sm.ContainerReceiver()
            driver.connect(recv.on_chunk)
            sm.ContainerStreamer(driver, self.cfg.chunk_size).send_container(message.payload)
        if isinstance(driver, sm.FileSpoolDriver):
            driver.flush()
        driver.close()
        payload = recv.result
        return Message(message.kind, payload, dict(message.headers))


class _SimClientProxy(ClientProxy):
    """Server-side handle for one simulated client; runs the full filtered

    round trip (the four filter points of paper §II-B) over the wire."""

    def __init__(
        self,
        executor: Executor,
        server_filters: Dict[FilterPoint, FilterChain],
        client_filters: Dict[FilterPoint, FilterChain],
        wire: _Wire,
    ) -> None:
        self.name = executor.name
        self.executor = executor
        self.server_filters = server_filters
        self.client_filters = client_filters
        self.wire = wire

    def submit_task(self, task: Message) -> Message:
        # 1. before Task Data leaves server
        task = self.server_filters[FilterPoint.TASK_DATA_OUT].process(task)
        task = self.wire.transmit(task)
        # 2. before client accepts Task Data
        task = self.client_filters[FilterPoint.TASK_DATA_IN].process(task)
        result = self.executor.execute(task)
        # 3. before Task Result leaves client
        result = self.client_filters[FilterPoint.TASK_RESULT_OUT].process(result)
        result = self.wire.transmit(result)
        # 4. before server accepts Task Result
        result = self.server_filters[FilterPoint.TASK_RESULT_IN].process(result)
        return result


class FLSimulator:
    def __init__(
        self,
        executors: Sequence[Executor],
        aggregator: Any,
        config: Optional[SimulationConfig] = None,
        server_filters: Optional[Dict[FilterPoint, FilterChain]] = None,
        client_filters: Optional[Dict[FilterPoint, FilterChain]] = None,
        on_round_end: Optional[Callable[[int, Dict[str, Any], List[Message]], None]] = None,
    ) -> None:
        self.config = config or SimulationConfig()
        self.server_filters = server_filters or no_filters()
        self.client_filters = client_filters or no_filters()
        self.stats = TrafficStats()
        self.meter = MemoryMeter()
        wire = _Wire(self.config, self.stats)
        proxies = [
            _SimClientProxy(ex, self.server_filters, self.client_filters, wire)
            for ex in executors
        ]
        self.controller = ScatterAndGather(
            proxies, aggregator, self.config.num_rounds, on_round_end=on_round_end
        )

    def run(self, initial_weights: Dict[str, Any]) -> Dict[str, Any]:
        with self.meter.activate():
            return self.controller.run(initial_weights)
