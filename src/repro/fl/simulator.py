"""In-process FL simulator: wires Controller, Executors, the four filter

points and the streaming transport into one runnable federation —
NVFlare's simulator analogue. Every message physically crosses the
streaming layer (serialized, framed, chunked, reassembled), so byte
counts and peak transmission memory are real, not estimated.

Two runtimes drive the same proxies:

* the classic sequential :class:`~repro.fl.controller.ScatterAndGather`
  controller (default — one client at a time), or
* the event-driven :class:`~repro.runtime.scheduler.AsyncFLScheduler`
  (pass ``runtime=``/``policy=``/``network=``): clients run concurrently
  on a thread pool over the real transport, ordered by a deterministic
  simulated clock fed by actual wire bytes.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import streaming as sm
from repro.core.filters import FilterChain, FilterPoint, no_filters
from repro.core.messages import Message
from repro.fl.controller import ClientProxy, ScatterAndGather
from repro.fl.executor import Executor
from repro.utils.mem import MemoryMeter


@dataclasses.dataclass
class SimulationConfig:
    num_rounds: int = 1
    transmission: str = "container"     # regular | container | file
    chunk_size: int = sm.DEFAULT_CHUNK_SIZE
    driver: str = "loopback"            # loopback | tcp | spool
    spool_dir: Optional[str] = None


@dataclasses.dataclass
class TrafficStats:
    """Wire-level message/byte counters.

    Thread-safe: the async runtime transmits from a pool of worker
    threads, so ``add`` must be atomic (a bare ``+=`` on two fields loses
    counts under contention).
    """

    messages: int = 0
    bytes_sent: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, nbytes: int) -> None:
        with self._lock:
            self.messages += 1
            self.bytes_sent += nbytes


class _Wire:
    """One filtered, streamed hop: serialize -> frames -> reassemble.

    Stateless per transmit (a fresh driver/receiver pair each call), so
    concurrent transmits from different scheduler threads don't share
    buffers.
    """

    def __init__(self, cfg: SimulationConfig, stats: TrafficStats) -> None:
        self.cfg = cfg
        self.stats = stats

    def _driver(self) -> sm.Driver:
        if self.cfg.driver == "tcp":
            return sm.TCPDriver()
        if self.cfg.driver == "spool":
            assert self.cfg.spool_dir is not None
            return sm.FileSpoolDriver(self.cfg.spool_dir)
        return sm.LoopbackDriver()

    def transmit(self, message: Message) -> Message:
        self.stats.add(message.payload_bytes())
        driver = self._driver()
        if self.cfg.transmission == "regular":
            recv: Any = sm.BlobReceiver()
            driver.connect(recv.on_chunk)
            sm.ObjectStreamer(driver, self.cfg.chunk_size).send_container(message.payload)
        else:
            # container streaming is also the carrier for "file" payloads in
            # the simulator; true file transfer is exercised by FileStreamer
            # paths in the streaming demo / Table III benchmark.
            recv = sm.ContainerReceiver()
            driver.connect(recv.on_chunk)
            sm.ContainerStreamer(driver, self.cfg.chunk_size).send_container(message.payload)
        if isinstance(driver, sm.FileSpoolDriver):
            driver.flush()
        driver.close()
        payload = recv.result
        return Message(message.kind, payload, dict(message.headers))


class _SimClientProxy(ClientProxy):
    """Server-side handle for one simulated client; runs the full filtered

    round trip (the four filter points of paper §II-B) over the wire.

    ``filter_lock`` (async runtime only) serializes filter processing so
    stateful filters (error feedback, DP noise) stay consistent under
    concurrent round trips; the wire transfers themselves run unlocked.
    """

    def __init__(
        self,
        executor: Executor,
        server_filters: Dict[FilterPoint, FilterChain],
        client_filters: Dict[FilterPoint, FilterChain],
        wire: _Wire,
        filter_lock: Optional[threading.Lock] = None,
    ) -> None:
        self.name = executor.name
        self.executor = executor
        self.server_filters = server_filters
        self.client_filters = client_filters
        self.wire = wire
        self.filter_lock = filter_lock

    def _filter(self, chain: FilterChain, message: Message) -> Message:
        if self.filter_lock is None:
            return chain.process(message)
        with self.filter_lock:
            return chain.process(message)

    def submit_task(self, task: Message) -> Message:
        # destination goes in the headers so egress filters can be
        # link-aware (AdaptiveQuantizeFilter picks per-client precision)
        task.headers.setdefault("client", self.name)
        # 1. before Task Data leaves server
        task = self._filter(self.server_filters[FilterPoint.TASK_DATA_OUT], task)
        wire_bytes_down = task.payload_bytes()
        task = self.wire.transmit(task)
        # 2. before client accepts Task Data
        task = self._filter(self.client_filters[FilterPoint.TASK_DATA_IN], task)
        result = self.executor.execute(task)
        # 3. before Task Result leaves client
        result = self._filter(self.client_filters[FilterPoint.TASK_RESULT_OUT], result)
        wire_bytes_up = result.payload_bytes()
        result = self.wire.transmit(result)
        # 4. before server accepts Task Result
        result = self._filter(self.server_filters[FilterPoint.TASK_RESULT_IN], result)
        # actual on-the-wire sizes of both hops, for the runtime's network
        # model (quantized payloads => measurably shorter simulated rounds)
        result.headers["wire_bytes_down"] = wire_bytes_down
        result.headers["wire_bytes_up"] = wire_bytes_up
        return result


class FLSimulator:
    def __init__(
        self,
        executors: Sequence[Executor],
        aggregator: Any,
        config: Optional[SimulationConfig] = None,
        server_filters: Optional[Dict[FilterPoint, FilterChain]] = None,
        client_filters: Optional[Dict[FilterPoint, FilterChain]] = None,
        on_round_end: Optional[Callable[[int, Dict[str, Any], List[Message]], None]] = None,
        runtime: Optional[Any] = None,   # repro.runtime.RuntimeConfig -> async scheduler
        policy: Optional[Any] = None,    # repro.runtime.AggregationPolicy override
        network: Optional[Any] = None,   # repro.runtime.NetworkModel override
        availability: Optional[Any] = None,  # repro.runtime.AvailabilityTrace
    ) -> None:
        self.config = config or SimulationConfig()
        self.server_filters = server_filters or no_filters()
        self.client_filters = client_filters or no_filters()
        self.stats = TrafficStats()
        self.meter = MemoryMeter()
        use_async = (
            runtime is not None or policy is not None
            or network is not None or availability is not None
        )
        wire = _Wire(self.config, self.stats)
        filter_lock = threading.Lock() if use_async else None
        self.proxies = [
            _SimClientProxy(ex, self.server_filters, self.client_filters, wire, filter_lock)
            for ex in executors
        ]
        self.controller: Optional[ScatterAndGather] = None
        self.scheduler: Optional[Any] = None
        if use_async:
            # imported lazily: repro.runtime depends on repro.fl.controller,
            # so a module-level import here would be circular
            from repro.runtime.async_agg import SyncPolicy
            from repro.runtime.scheduler import AsyncFLScheduler, RuntimeConfig

            self.scheduler = AsyncFLScheduler(
                self.proxies,
                policy or SyncPolicy(aggregator, self.config.num_rounds, on_round_end),
                network=network,
                config=runtime or RuntimeConfig(),
                availability=availability,
            )
        else:
            self.controller = ScatterAndGather(
                self.proxies, aggregator, self.config.num_rounds, on_round_end=on_round_end
            )

    def run(self, initial_weights: Dict[str, Any]) -> Dict[str, Any]:
        driver = self.scheduler if self.scheduler is not None else self.controller
        with self.meter.activate():
            return driver.run(initial_weights)

    @property
    def sim_time_s(self) -> Optional[float]:
        """Simulated makespan (async runtime only; None for the classic path)."""
        if self.scheduler is None:
            return None
        return self.scheduler.stats.sim_time_s
