"""In-process FL simulator: wires Controller, Executors, the wire
pipelines and the streaming transport into one runnable federation —
NVFlare's simulator analogue. Every message physically crosses the
streaming layer (encoded, framed, chunked, reassembled), so byte counts
and peak transmission memory are real, not estimated.

Message transforms are :class:`~repro.core.pipeline.WirePipeline` stacks,
one per hop direction (``task_data`` server->client, ``task_result``
client->server); stages execute *inside* the streaming loop, so a
container-streamed quantized+compressed transfer peaks at ~one item of
transmission memory. The legacy four-point ``Filter``/``FilterChain``
configuration (``server_filters=``/``client_filters=``) still works — it
is adapted onto whole-message pipeline stages via
:func:`~repro.core.pipeline.legacy_wire_pipelines`, bitwise identical
but materializing the full transformed payload (deprecated; prefer
``pipelines=``).

Wire accounting is honest: :class:`TrafficStats` counts every byte that
crosses a driver — frame headers, pipeline envelopes, and the
transmitted message-header item included — not just tensor payloads, so
compression stages report true ratios and the async runtime's simulated
transfer times are driven by real bytes (retransmissions included).

Chunk-level fault injection composes underneath: set
``chunk_drop_prob``/``chunk_dup_prob``/``chunk_reorder_window`` on
:class:`SimulationConfig` and every hop runs through
:class:`~repro.core.resilience.LossyDriver` +
:class:`~repro.core.resilience.ReliableTransfer`, with retransmitted
chunks feeding back into the byte counts (and hence simulated time).

Two runtimes drive the same proxies:

* the classic sequential :class:`~repro.fl.controller.ScatterAndGather`
  controller (default — one client at a time), or
* the event-driven :class:`~repro.runtime.scheduler.AsyncFLScheduler`
  (pass ``runtime=``/``policy=``/``network=``): clients run concurrently
  on a thread pool over the real transport, ordered by a deterministic
  simulated clock fed by actual wire bytes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections.abc import Callable, Sequence
from typing import Any, Optional, Union

from repro.core import resilience as rs
from repro.core import streaming as sm
from repro.core.filters import FilterChain, FilterPoint, no_filters
from repro.core.messages import Message
from repro.core.pipeline import StageSpec, WirePipeline, legacy_wire_pipelines
from repro.fl.controller import ClientProxy, ScatterAndGather
from repro.fl.executor import Executor
from repro.obs import MetricsRegistry, Tracer
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils import mem
from repro.utils.mem import MemoryMeter

PipelineLike = Union[WirePipeline, list[StageSpec], None]


@dataclasses.dataclass
class SimulationConfig:
    num_rounds: int = 1
    transmission: str = "container"     # regular | container | file
    chunk_size: int = sm.DEFAULT_CHUNK_SIZE
    driver: str = "loopback"            # any registered driver name
    spool_dir: Optional[str] = None
    # chunk-level fault injection (loopback/spool drivers): every hop then
    # runs LossyDriver + ReliableTransfer, and retransmitted chunks are
    # counted into the wire bytes that drive simulated transfer time
    chunk_drop_prob: float = 0.0
    chunk_dup_prob: float = 0.0
    chunk_reorder_window: int = 0
    fault_seed: int = 0
    max_repair_rounds: int = 40

    @property
    def faulty(self) -> bool:
        return (
            self.chunk_drop_prob > 0
            or self.chunk_dup_prob > 0
            or self.chunk_reorder_window > 0
        )


@dataclasses.dataclass
class TrafficStats:
    """Wire-level counters.

    ``bytes_sent`` is **true bytes on the wire**: frame headers, pipeline
    envelopes, the transmitted message-header item, and chunk
    retransmissions all included — what a packet capture would total.
    ``payload_bytes`` is the logical **pre-transform** tensor-payload
    size (before any quantize/compress stage or legacy filter ran), so
    ``bytes_sent / payload_bytes`` is the honest end-to-end wire ratio
    on both the pipeline and legacy-shim paths.

    Thread-safe: the async runtime transmits from a pool of worker
    threads, so ``add`` must be atomic (a bare ``+=`` on two fields loses
    counts under contention).
    """

    messages: int = 0
    bytes_sent: int = 0
    payload_bytes: int = 0
    retransmits: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, nbytes: int, payload_nbytes: int = 0, retransmits: int = 0) -> None:
        with self._lock:
            self.messages += 1
            self.bytes_sent += int(nbytes)
            self.payload_bytes += int(payload_nbytes)
            self.retransmits += int(retransmits)

    def as_dict(self) -> dict[str, int]:
        """JSON-safe export (the metrics-snapshot schema)."""
        with self._lock:
            return {
                "messages": self.messages,
                "bytes_sent": self.bytes_sent,
                "payload_bytes": self.payload_bytes,
                "retransmits": self.retransmits,
            }


class CountingDriver(sm.Driver):
    """Transparent driver wrapper totalling encoded frame bytes — the
    sender's egress NIC view: dropped chunks were still transmitted,
    retransmissions count again, network-made duplicates don't."""

    def __init__(self, inner: sm.Driver) -> None:
        self.inner = inner
        self.bytes_sent = 0

    def connect(self, on_chunk: Callable[[sm.Chunk], None]) -> None:
        self.inner.connect(on_chunk)

    def send(self, chunk: sm.Chunk) -> None:
        self.bytes_sent += sm._HDR.size + chunk.nbytes
        self.inner.send(chunk)

    def flush(self) -> None:
        if hasattr(self.inner, "flush"):
            self.inner.flush()

    def close(self) -> None:
        self.inner.close()


class _Wire:
    """One pipelined, streamed hop: stage-encode -> frames -> reassemble
    -> stage-decode, all inside the streaming loop.

    Stateless per transmit (a fresh driver/receiver/decoder per call), so
    concurrent transmits from different scheduler threads don't share
    buffers. Stateful pipelines additionally serialize their encode/
    decode under the caller-provided lock.
    """

    def __init__(self, cfg: SimulationConfig, stats: TrafficStats) -> None:
        self.cfg = cfg
        self.stats = stats
        if cfg.faulty and cfg.driver == "tcp":
            raise ValueError(
                "chunk fault injection is not supported over the tcp driver "
                "(its receiver thread stops at EOF, so gap repair cannot "
                "complete); use loopback or spool"
            )

    def _driver(self) -> sm.Driver:
        kwargs: dict[str, Any] = {}
        if self.cfg.driver == "spool":
            assert self.cfg.spool_dir is not None
            kwargs["spool_dir"] = self.cfg.spool_dir
        return sm.make_driver(self.cfg.driver, **kwargs)

    def _fault_key(self, message: Message) -> str:
        # stable across runs and thread interleavings: keyed by message
        # identity, not by wall-clock send order
        h = message.headers
        return (
            f"wirefault:{self.cfg.fault_seed}:{h.get('client', '')}:"
            f"{message.kind.value}:{h.get('round', h.get('model_version', ''))}"
        )

    def transmit(
        self,
        message: Message,
        pipeline: WirePipeline,
        lock: Optional[threading.Lock] = None,
        sink: Optional[Any] = None,
        count_only: bool = False,
        record_stats: bool = True,
    ) -> tuple[Optional[Message], int]:
        tr = obs_trace.ACTIVE
        if tr is None:
            return self._transmit(message, pipeline, lock, sink,
                                  count_only, record_stats)
        h = message.headers
        with tr.span(
            "wire.transmit", "wire", kind=message.kind.value,
            client=str(h.get("client", "")),
            round=h.get("round", h.get("model_version")),
            count_only=count_only, streaming_fold=sink is not None,
        ) as sp:
            out, nbytes = self._transmit(message, pipeline, lock, sink,
                                         count_only, record_stats)
            sp.args["wire_bytes"] = nbytes
            return out, nbytes

    def _transmit(
        self,
        message: Message,
        pipeline: WirePipeline,
        lock: Optional[threading.Lock] = None,
        sink: Optional[Any] = None,
        count_only: bool = False,
        record_stats: bool = True,
    ) -> tuple[Optional[Message], int]:
        """Send ``message`` through ``pipeline`` over one fresh driver;
        returns the received message and the true bytes put on the wire.

        ``sink`` switches the receiving end to streaming aggregation:
        each decoded item is folded via ``sink.begin``/``sink.
        accept_item`` inside the receive loop and freed, and the returned
        Message carries headers only. ``count_only`` runs the encode and
        framing with a null receiver — the byte-pricing pass the async
        scheduler uses to simulate uplink time before the deferred fold
        transfer (stage encode is deterministic for stateless pipelines,
        so the later fold pass produces identical bytes). ``record_stats
        =False`` keeps a second pass over the same message out of
        :class:`TrafficStats`.
        """
        cfg = self.cfg
        base = self._driver()
        if cfg.faulty:
            base = rs.LossyDriver(
                base,
                drop_prob=cfg.chunk_drop_prob,
                dup_prob=cfg.chunk_dup_prob,
                reorder_window=cfg.chunk_reorder_window,
                seed=self._fault_key(message),
            )
        driver = CountingDriver(base)
        regular = cfg.transmission == "regular"
        decoder: Optional[Any] = None
        if count_only:
            recv: Any = _NullReceiver()
        elif regular:
            decoder = pipeline.decoder(sink=sink)
            recv = sm.BlobReceiver(decode_container=decoder.decode_blob)
        else:
            # container streaming is also the carrier for "file" payloads in
            # the simulator; true file transfer is exercised by FileStreamer
            # paths in the streaming demo / Table III benchmark.
            decoder = pipeline.decoder(sink=sink)
            recv = sm.ContainerReceiver(consume=decoder.on_item,
                                        decode_item=decoder.decode_item)
        hold = lock if (lock is not None and pipeline.stateful) else contextlib.nullcontext()
        with hold:
            msg, ctx = pipeline.begin_encode(message)
            held = int(ctx.state.get("held_bytes", 0))
            if held:  # legacy whole-message transform: charge the full payload
                mem.record_alloc(held)
            try:
                if cfg.faulty:
                    xfer = rs.ReliableTransfer(driver, cfg.chunk_size)
                    if regular:
                        ok = xfer.send_blob(pipeline.encode_blob(msg, ctx), recv,
                                            max_rounds=cfg.max_repair_rounds)
                    else:
                        ok = xfer.send_items(pipeline.iter_encode_views(msg, ctx),
                                             pipeline.n_items(msg), recv,
                                             max_rounds=cfg.max_repair_rounds)
                    retransmits = xfer.retransmits
                    if not ok:
                        raise RuntimeError(
                            f"wire stream failed to complete within "
                            f"{cfg.max_repair_rounds} repair rounds "
                            f"(chunk_drop_prob={cfg.chunk_drop_prob})"
                        )
                else:
                    retransmits = 0
                    driver.connect(recv.on_chunk)
                    if regular:
                        sm.ObjectStreamer(driver, cfg.chunk_size).send_blob(
                            pipeline.encode_blob(msg, ctx)
                        )
                    else:
                        sm.ContainerStreamer(driver, cfg.chunk_size).send_items(
                            pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg)
                        )
                    driver.flush()  # no-op unless a spool driver is underneath
                driver.close()
            finally:
                if held:
                    mem.record_free(held)
            out = (
                decoder.finish(msg.kind, pipeline.unsent_headers(msg))
                if decoder is not None else None
            )
        # payload_bytes is the *pre-transform* logical size on both wire
        # paths (the legacy shim replaces msg's payload in begin_encode),
        # so bytes_sent / payload_bytes is an honest end-to-end ratio
        if record_stats:
            self.stats.add(driver.bytes_sent, message.payload_bytes(), retransmits)
        return out, driver.bytes_sent


class _NullReceiver:
    """Byte-pricing receiver: frames arrive, nothing is reassembled."""

    def on_chunk(self, chunk: sm.Chunk) -> None:
        pass


class _SimClientProxy(ClientProxy):
    """Server-side handle for one simulated client; runs the full
    pipelined round trip (both hop directions) over the wire.

    ``filter_lock`` (async runtime only) serializes stateful pipelines
    (error feedback, DP noise, legacy filter stages) so their state stays
    consistent under concurrent round trips; stateless pipelines stream
    fully concurrently.
    """

    def __init__(
        self,
        executor: Executor,
        pipelines: dict[str, WirePipeline],
        wire: _Wire,
        filter_lock: Optional[threading.Lock] = None,
    ) -> None:
        self.name = executor.name
        self.executor = executor
        self.pipelines = pipelines
        self.wire = wire
        self.filter_lock = filter_lock

    def submit_task(self, task: Message, result_sink: Optional[Any] = None) -> Message:
        # destination goes in the headers so egress stages can be
        # link-aware (the adaptive stage picks per-client precision)
        task.headers.setdefault("client", self.name)
        task, wire_bytes_down = self.wire.transmit(
            task, self.pipelines["task_data"], self.filter_lock
        )
        result = self.executor.execute(task)
        # with a result_sink the uplink decode folds each item straight
        # into the sink (streaming aggregation); the returned message
        # then carries headers only
        result, wire_bytes_up = self.wire.transmit(
            result, self.pipelines["task_result"], self.filter_lock,
            sink=result_sink,
        )
        # actual on-the-wire sizes of both hops (frames + envelopes +
        # retransmissions), for the runtime's network model: quantized or
        # compressed payloads => measurably shorter simulated rounds
        result.headers["wire_bytes_down"] = wire_bytes_down
        result.headers["wire_bytes_up"] = wire_bytes_up
        return result

    def stream_task(self, task: Message) -> _PendingUplink:
        """Async streaming-aggregation round trip, first half: downlink +
        local compute + a byte-pricing pass over the uplink (encode and
        frame into a null receiver — no server-side buffering). The
        returned handle carries the timing headers the scheduler needs;
        the actual uplink fold transfer runs later, via
        :meth:`_PendingUplink.deliver`, at the completion instant in
        simulated-time order."""
        task.headers.setdefault("client", self.name)
        task, wire_bytes_down = self.wire.transmit(
            task, self.pipelines["task_data"], self.filter_lock
        )
        result = self.executor.execute(task)
        _, wire_bytes_up = self.wire.transmit(
            result, self.pipelines["task_result"], self.filter_lock,
            count_only=True,
        )
        headers = dict(result.headers)
        headers["wire_bytes_down"] = wire_bytes_down
        headers["wire_bytes_up"] = wire_bytes_up
        return _PendingUplink(self, result, headers)


class _PendingUplink:
    """A completed client computation whose uplink fold transfer is
    deferred: the client-side Task Result stays on the client until the
    scheduler delivers it into a policy sink at the simulated completion
    instant. ``headers`` already carry both hops' wire byte counts (from
    the pricing pass), so the scheduler's timing code reads this object
    exactly like a batch result."""

    def __init__(self, proxy: _SimClientProxy, result: Message,
                 headers: dict[str, Any]) -> None:
        self._proxy = proxy
        self._result = result
        self.headers = headers
        self.kind = result.kind

    def payload_bytes(self) -> int:
        return self._result.payload_bytes()

    def deliver(self, sink: Any) -> Message:
        """Run the real uplink transfer, folding each decoded item into
        ``sink`` — the server holds ~one item at a time. Bytes are not
        re-counted (the pricing pass already did); a mismatch against the
        priced total would mean the simulated clock was fed wrong bytes,
        so it is a hard error."""
        out, wire_bytes = self._proxy.wire.transmit(
            self._result, self._proxy.pipelines["task_result"],
            self._proxy.filter_lock, sink=sink, record_stats=False,
        )
        if wire_bytes != self.headers["wire_bytes_up"]:
            raise RuntimeError(
                f"uplink fold transfer produced {wire_bytes} wire bytes but "
                f"the pricing pass measured {self.headers['wire_bytes_up']} — "
                "the task_result pipeline is not deterministic (stateful "
                "stages cannot run under async streaming aggregation)"
            )
        out.headers.update(
            {k: self.headers[k] for k in ("wire_bytes_down", "wire_bytes_up")}
        )
        return out


def _as_pipeline(value: PipelineLike) -> WirePipeline:
    if isinstance(value, WirePipeline):
        return value
    return WirePipeline(list(value or []))


class FLSimulator:
    def __init__(
        self,
        executors: Sequence[Executor],
        aggregator: Any,
        config: Optional[SimulationConfig] = None,
        server_filters: Optional[dict[FilterPoint, FilterChain]] = None,
        client_filters: Optional[dict[FilterPoint, FilterChain]] = None,
        pipelines: Optional[dict[str, PipelineLike]] = None,
        on_round_end: Optional[Callable[[int, dict[str, Any], list[Message]], None]] = None,
        runtime: Optional[Any] = None,   # repro.runtime.RuntimeConfig -> async scheduler
        policy: Optional[Any] = None,    # repro.runtime.AggregationPolicy override
        network: Optional[Any] = None,   # repro.runtime.NetworkModel override
        availability: Optional[Any] = None,  # repro.runtime.AvailabilityTrace
        server_streaming_agg: bool = False,
        trace: Union[Tracer, bool, None] = None,
    ) -> None:
        """``pipelines`` maps hop direction -> wire stack: ``{"task_data":
        ["quantize:nf4", "zlib"], "task_result": WirePipeline([...])}``
        (missing directions get the identity pipeline).

        ``server_filters``/``client_filters`` are the deprecated
        four-point Filter configuration; they are adapted onto
        whole-message pipeline stages (bitwise-identical results, but the
        full transformed payload is materialized before streaming).
        Mutually exclusive with ``pipelines``.

        ``server_streaming_agg=True`` turns on streaming aggregation:
        Task Result items fold into the aggregation plane one at a time
        as they decode, so server peak transmission+aggregation memory is
        ~one item instead of one model per in-flight client. On the
        sequential controller the fold runs during the uplink transfer
        (bitwise-equal to batch aggregation — same order, same
        arithmetic); on the async scheduler the uplink is priced on the
        worker thread and the fold transfer runs at the simulated
        completion instant in event order (deterministic; see
        ``repro.runtime.scheduler``), which requires a *stateless*
        task_result pipeline.
        """
        self.config = config or SimulationConfig()
        if pipelines is not None and (server_filters is not None or client_filters is not None):
            raise ValueError("pass either pipelines= or the legacy *_filters=, not both")
        if pipelines is not None:
            self.pipelines = {
                "task_data": _as_pipeline(pipelines.get("task_data")),
                "task_result": _as_pipeline(pipelines.get("task_result")),
            }
            unknown = set(pipelines) - {"task_data", "task_result"}
            if unknown:
                raise ValueError(f"unknown pipeline directions {sorted(unknown)}")
        else:
            self.pipelines = legacy_wire_pipelines(
                server_filters or no_filters(), client_filters or no_filters()
            )
        self.stats = TrafficStats()
        self.meter = MemoryMeter()
        self.server_streaming_agg = server_streaming_agg
        use_async = (
            runtime is not None or policy is not None
            or network is not None or availability is not None
        )
        if server_streaming_agg:
            from repro.core.pipeline import IngressFilterStage

            if any(isinstance(s, IngressFilterStage)
                   for s in self.pipelines["task_result"].stages):
                raise ValueError(
                    "streaming aggregation folds items as they decode, but a "
                    "legacy server-ingress filter (TASK_RESULT_IN, e.g. "
                    "DequantizeFilter) transforms the payload only after full "
                    "reassembly; declare the uplink as per-item pipeline "
                    'stages instead (e.g. "quantize:nf4" — decode is '
                    "automatic from the envelope)"
                )
        if server_streaming_agg and use_async and self.pipelines["task_result"].stateful:
            raise ValueError(
                "async streaming aggregation encodes each uplink twice (a "
                "byte-pricing pass, then the fold transfer), so the "
                "task_result pipeline must be stateless — ef-quantize, "
                "dp-noise, delta and stateful legacy filters cannot run "
                "there; use the sequential controller or stateless stages"
            )
        # observability: tracing is opt-in (trace=True for a default
        # flight recorder, or pass a configured Tracer); the metrics
        # registry always exists — snapshots are cheap and pull-based
        self.tracer: Optional[Tracer] = (
            trace if isinstance(trace, Tracer) else (Tracer() if trace else None)
        )
        self.metrics = MetricsRegistry()
        wire = _Wire(self.config, self.stats)
        filter_lock = threading.Lock() if use_async else None
        self.proxies = [
            _SimClientProxy(ex, self.pipelines, wire, filter_lock)
            for ex in executors
        ]
        self.controller: Optional[ScatterAndGather] = None
        self.scheduler: Optional[Any] = None
        if use_async:
            # imported lazily: repro.runtime depends on repro.fl.controller,
            # so a module-level import here would be circular
            from repro.runtime.async_agg import SyncPolicy
            from repro.runtime.scheduler import AsyncFLScheduler, RuntimeConfig

            self.scheduler = AsyncFLScheduler(
                self.proxies,
                policy or SyncPolicy(aggregator, self.config.num_rounds, on_round_end),
                network=network,
                config=runtime or RuntimeConfig(),
                availability=availability,
                streaming_agg=server_streaming_agg,
            )
        else:
            self.controller = ScatterAndGather(
                self.proxies, aggregator, self.config.num_rounds,
                on_round_end=on_round_end, streaming=server_streaming_agg,
            )

    def run(self, initial_weights: dict[str, Any]) -> dict[str, Any]:
        driver = self.scheduler if self.scheduler is not None else self.controller
        tracing: Any = contextlib.nullcontext()
        if self.tracer is not None:
            if self.scheduler is not None and self.tracer.sim_clock is None:
                # wall-clock spans also carry the simulated time they ran at
                loop = self.scheduler.loop
                self.tracer.sim_clock = lambda: loop.now
            tracing = obs_trace.activate(self.tracer)
        with tracing, self.meter.activate(), obs_metrics.activate(self.metrics):
            out = driver.run(initial_weights)
        self._publish_metrics()
        return out

    def _publish_metrics(self) -> None:
        """Fold the island stats into the metrics registry (gauges)."""
        self.metrics.publish("traffic", self.stats.as_dict())
        self.metrics.publish("memory", self.meter.as_dict())
        if self.scheduler is not None:
            self.metrics.publish("runtime", self.scheduler.stats.as_dict())

    def telemetry(self) -> dict[str, Any]:
        """JSON-safe observability summary for this run: the wire /
        memory (/ runtime) stats plus the full metrics snapshot, and a
        flight-recorder summary when tracing is on."""
        out: dict[str, Any] = {
            "traffic": self.stats.as_dict(),
            "memory": self.meter.as_dict(),
            "metrics": self.metrics.snapshot(),
        }
        if self.scheduler is not None:
            out["runtime"] = self.scheduler.stats.as_dict()
        if self.tracer is not None:
            out["trace"] = {
                "total_events": self.tracer.total_events,
                "dropped_events": self.tracer.dropped,
                "capacity": self.tracer.capacity,
            }
        return out

    @property
    def round_log(self) -> list[dict[str, Any]]:
        """Per-round wall timing from the sequential controller
        (``{"round", "clients", "wall_s"}`` per entry, same shape the
        live federation server records); empty under the async
        scheduler, whose clock is simulated."""
        if self.controller is None:
            return []
        return list(self.controller.round_log)

    @property
    def sim_time_s(self) -> Optional[float]:
        """Simulated makespan (async runtime only; None for the classic path)."""
        if self.scheduler is None:
            return None
        return self.scheduler.stats.sim_time_s
