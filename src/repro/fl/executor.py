"""Client-side Executors (paper §II-A): receive Task Data, run the local

computation, return a Task Result. :class:`TrainExecutor` adapts any
``train_fn(params, round) -> (params, num_samples, metrics)`` — the
"client API" surface: the training script needs zero knowledge of
filters, quantization or streaming.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

from repro.core.messages import Message, MessageKind


class Executor:
    name: str = "executor"

    def execute(self, task: Message) -> Message:
        raise NotImplementedError


TrainFn = Callable[[dict[str, Any], int], tuple[dict[str, Any], int, dict[str, float]]]


class TrainExecutor(Executor):
    def __init__(self, name: str, train_fn: TrainFn) -> None:
        self.name = name
        self.train_fn = train_fn

    def execute(self, task: Message) -> Message:
        rnd = int(task.headers.get("round", 0))
        new_params, num_samples, metrics = self.train_fn(task.payload, rnd)
        return Message(
            MessageKind.TASK_RESULT,
            dict(new_params),
            headers={
                "round": rnd,
                "client": self.name,
                "num_samples": num_samples,
                "metrics": metrics,
            },
        )


class EvalExecutor(Executor):
    """Evaluation-only client: returns metrics, no weights."""

    def __init__(
        self, name: str, eval_fn: Callable[[dict[str, Any], int], dict[str, float]]
    ) -> None:
        self.name = name
        self.eval_fn = eval_fn

    def execute(self, task: Message) -> Message:
        rnd = int(task.headers.get("round", 0))
        metrics = self.eval_fn(task.payload, rnd)
        return Message(
            MessageKind.TASK_RESULT,
            {},
            headers={"round": rnd, "client": self.name, "num_samples": 0, "metrics": metrics},
        )
