"""Server-side aggregation.

:class:`FedAvgAggregator` is the paper-faithful path: Task Results arrive
*already dequantized* (the TASK_RESULT_IN filter ran), and aggregation is
a sample-weighted average at original precision. It accumulates
**incrementally** — one client at a time, and within a client one item at
a time — so it composes with container streaming without ever holding K
full models (only the running sum + one incoming item).

:class:`QuantizedFedAvgAggregator` is the beyond-paper path (DESIGN.md
§3): the server skips the ingress dequantize filter, stacks the int8
payloads and calls the fused dequant+accumulate kernel. The aggregate is
bit-identical to dequantize-then-average (tests assert this).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.messages import Message
from repro.core.quantization import QuantizedTensor
from repro.kernels import ops


class FedAvgAggregator:
    """Sample-weighted incremental FedAvg at original precision."""

    def __init__(self) -> None:
        self._sum: dict[str, np.ndarray] = {}
        self._weight = 0.0
        self.accepted = 0

    def accept(self, result: Message) -> None:
        w = float(result.headers.get("num_samples", 1))
        for name, value in result.payload.items():
            if isinstance(value, QuantizedTensor):
                raise TypeError(
                    f"FedAvgAggregator received a quantized item {name!r}; "
                    "install a DequantizeFilter at TASK_RESULT_IN or use "
                    "QuantizedFedAvgAggregator"
                )
            self.accept_item(name, value, w)
        self._weight += w
        self.accepted += 1

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        """Streaming entry point: one item of one client's result."""
        arr = np.asarray(value, dtype=np.float32) * weight
        if name in self._sum:
            self._sum[name] += arr
        else:
            self._sum[name] = arr

    def finish(self) -> dict[str, np.ndarray]:
        if self._weight <= 0:
            raise RuntimeError("no results accepted")
        out = {name: (arr / self._weight).astype(np.float32) for name, arr in self._sum.items()}
        self._sum = {}
        self._weight = 0.0
        self.accepted = 0
        return out


class QuantizedFedAvgAggregator:
    """Aggregates blockwise8 Task Results directly from int8 payloads

    via the fused Pallas kernel — the server never materializes K fp32
    models. Non-quantized (small) items fall back to plain averaging.
    """

    def __init__(self) -> None:
        self._q: dict[str, list[tuple[QuantizedTensor, float]]] = {}
        self._plain = FedAvgAggregator()
        self._plain_names: set[str] = set()
        self._weight = 0.0
        self.accepted = 0

    def accept(self, result: Message) -> None:
        w = float(result.headers.get("num_samples", 1))
        for name, value in result.payload.items():
            if isinstance(value, QuantizedTensor):
                if value.fmt != "blockwise8":
                    raise TypeError(
                        f"QuantizedFedAvgAggregator supports blockwise8; {name!r} is {value.fmt}"
                    )
                self._q.setdefault(name, []).append((value, w))
            else:
                self._plain.accept_item(name, value, w)
                self._plain_names.add(name)
        self._weight += w
        self.accepted += 1

    def finish(self) -> dict[str, np.ndarray]:
        out: dict[str, np.ndarray] = {}
        for name, contribs in self._q.items():
            qs = jnp.stack([np.asarray(qt.payload) for qt, _ in contribs])
            ams = jnp.stack([np.asarray(qt.absmax) for qt, _ in contribs])
            ws = jnp.asarray([w for _, w in contribs], jnp.float32) / self._weight
            agg2d = ops.dequant_accumulate8(qs, ams, ws)
            qt0 = contribs[0][0]
            n = int(np.prod(qt0.orig_shape))
            out[name] = np.asarray(agg2d).reshape(-1)[:n].reshape(qt0.orig_shape).astype(np.float32)
        if self._plain_names:
            # reuse the plain aggregator's running sum (shares self._weight)
            self._plain._weight = self._weight
            out.update(self._plain.finish())
        self._q = {}
        self._plain_names = set()
        self._weight = 0.0
        self.accepted = 0
        return out
