"""Server-side aggregation — the streaming-first aggregation plane.

Aggregators implement one uniform **streaming protocol**, registry-keyed
like pipeline stages and runtime policies:

* ``begin(meta) -> weight`` — a client contribution starts; ``meta`` is
  the transmitted message-header dict (``num_samples``, ``client``,
  ``round`` ...). Returns the sample weight every subsequent
  ``accept_item`` call for this contribution should carry.
* ``accept_item(name, value, weight)`` — one payload item of one
  contribution, folded into the running aggregate immediately. Called
  straight from the wire decode loop (``ContainerReceiver.consume`` ->
  ``WireDecoder`` -> here), so a quantized+compressed item is
  dequantized, folded, and freed before the next item arrives — the
  server never materializes a client's payload dict.
* ``finish() -> dict`` — close the aggregate and reset.

``accept(message)`` is the batch shim: it drives the exact same protocol
methods in payload order, so batch and streaming aggregation run
*identical arithmetic in identical order* — bitwise-equal results by
construction (tests assert this across every transmission mode).

:class:`FedAvgAggregator` is the paper-faithful path: Task Results arrive
*already dequantized* (the pipeline's value stages decode in the
streaming loop), and aggregation is a sample-weighted average at original
precision — running sum + one in-flight item, never K full models.

:class:`QuantizedFedAvgAggregator` is the beyond-paper path: the server
keeps the uplink in wire form (``decode_values=False``), stacks the int8
payloads and calls the fused dequant+accumulate kernel. The aggregate is
bit-identical to dequantize-then-average (tests assert this). Note its
buffering is inherently O(quantized payload x clients) — the kernel
batches — which is still ~4-8x below fp32 batch aggregation.

Thread safety: ``begin``/``accept_item``/``finish`` serialize on a
per-instance lock, so many clients may stream into one aggregator
concurrently (the MemoryMeter acceptance test drives 32 senders at
once). Fold *order* under concurrency follows stream interleaving;
sample-weighted sums are order-independent in exact arithmetic, and the
deterministic runtimes (sequential controller, event scheduler) fold in
a fixed order anyway.
"""
from __future__ import annotations

import threading
from collections.abc import Callable, Mapping
from typing import Any, Union

import jax.numpy as jnp
import numpy as np

from repro.core.messages import Message
from repro.core.quantization import QuantizedTensor
from repro.kernels import ops


class Aggregator:
    """Protocol base: the streaming begin/accept_item/finish surface.

    Subclasses override the three protocol methods; ``accept`` (the
    whole-message shim) is derived and should not normally be overridden.
    """

    name: str = "aggregator"

    def weight_of(self, meta: Mapping[str, Any]) -> float:
        """The item weight one contribution's headers imply (pure)."""
        return float(meta.get("num_samples", 1))

    def begin(self, meta: Mapping[str, Any]) -> float:
        """Register one client contribution; returns its item weight."""
        raise NotImplementedError

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        """Fold one payload item of one contribution."""
        raise NotImplementedError

    def finish(self) -> dict[str, Any]:
        """Close the aggregate, reset state, return the result."""
        raise NotImplementedError

    def accept(self, result: Message) -> None:
        """Batch shim: drive the streaming protocol in payload order.

        The contribution is registered (``begin``) only after every item
        folded, so a payload that fails validation mid-message never
        leaves a phantom sample weight diluting ``finish()``.
        """
        w = self.weight_of(result.headers)
        for name, value in result.payload.items():
            self.accept_item(name, value, w)
        self.begin(result.headers)


class FedAvgAggregator(Aggregator):
    """Sample-weighted incremental FedAvg at original precision."""

    name = "fedavg"

    def __init__(self) -> None:
        self._sum: dict[str, np.ndarray] = {}
        self._weight = 0.0
        self.accepted = 0
        self._lock = threading.Lock()

    def begin(self, meta: Mapping[str, Any]) -> float:
        w = self.weight_of(meta)
        with self._lock:
            self._weight += w
            self.accepted += 1
        return w

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        """Streaming entry point: one item of one client's result."""
        if isinstance(value, QuantizedTensor):
            raise TypeError(
                f"FedAvgAggregator received a quantized item {name!r}; "
                "decode values on the uplink pipeline (the default) or use "
                "QuantizedFedAvgAggregator"
            )
        arr = np.asarray(value, dtype=np.float32) * weight
        with self._lock:
            if name in self._sum:
                self._sum[name] += arr
            else:
                self._sum[name] = arr

    def finish(self) -> dict[str, np.ndarray]:
        with self._lock:
            if self._weight <= 0:
                raise RuntimeError("no results accepted")
            out = {
                name: (arr / self._weight).astype(np.float32)
                for name, arr in self._sum.items()
            }
            self._sum = {}
            self._weight = 0.0
            self.accepted = 0
        return out


class QuantizedFedAvgAggregator(Aggregator):
    """Aggregates blockwise8 Task Results directly from int8 payloads

    via the fused Pallas kernel — the server never materializes K fp32
    models. Non-quantized (small) items fall back to plain averaging.
    """

    name = "quantized-fedavg"

    def __init__(self) -> None:
        self._q: dict[str, list[tuple[QuantizedTensor, float]]] = {}
        self._plain = FedAvgAggregator()
        self._plain_names: set[str] = set()
        self._weight = 0.0
        self.accepted = 0
        self._lock = threading.Lock()

    def begin(self, meta: Mapping[str, Any]) -> float:
        w = self.weight_of(meta)
        with self._lock:
            self._weight += w
            self.accepted += 1
        return w

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        if isinstance(value, QuantizedTensor):
            if value.fmt != "blockwise8":
                raise TypeError(
                    f"QuantizedFedAvgAggregator supports blockwise8; {name!r} is {value.fmt}"
                )
            with self._lock:
                self._q.setdefault(name, []).append((value, weight))
        else:
            self._plain.accept_item(name, value, weight)
            with self._lock:
                self._plain_names.add(name)

    def finish(self) -> dict[str, np.ndarray]:
        with self._lock:
            out: dict[str, np.ndarray] = {}
            for name, contribs in self._q.items():
                qs = jnp.stack([np.asarray(qt.payload) for qt, _ in contribs])
                ams = jnp.stack([np.asarray(qt.absmax) for qt, _ in contribs])
                ws = jnp.asarray([w for _, w in contribs], jnp.float32) / self._weight
                agg2d = ops.dequant_accumulate8(qs, ams, ws)
                qt0 = contribs[0][0]
                n = int(np.prod(qt0.orig_shape))
                out[name] = (
                    np.asarray(agg2d).reshape(-1)[:n].reshape(qt0.orig_shape)
                    .astype(np.float32)
                )
            if self._plain_names:
                # reuse the plain aggregator's running sum (shares self._weight)
                self._plain._weight = self._weight
                out.update(self._plain.finish())
            self._q = {}
            self._plain_names = set()
            self._weight = 0.0
            self.accepted = 0
        return out


class CollectingSink:
    """Protocol-shaped sink that just rebuilds the payload dict — the
    fallback for consumers that still need whole-message results (e.g. a
    third-party policy without a streaming override)."""

    def __init__(self) -> None:
        self.payload: dict[str, Any] = {}
        self.meta: dict[str, Any] = {}

    def begin(self, meta: Mapping[str, Any]) -> float:
        self.meta = dict(meta)
        return float(meta.get("num_samples", 1))

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        self.payload[name] = value


# ---------------------------------------------------------------------------
# Aggregator registry (the job system resolves "aggregator" names here)
# ---------------------------------------------------------------------------

_AGGREGATORS: dict[str, Callable[..., Aggregator]] = {}


def register_aggregator(
    name: str,
) -> Callable[[Callable[..., Aggregator]], Callable[..., Aggregator]]:
    """Decorator binding a spec name to an aggregator factory — the same
    registry pattern as ``repro.core.pipeline.register_stage`` and
    ``repro.runtime.async_agg.register_policy``; third-party aggregators
    become addressable from job specs without touching :mod:`repro.fl.job`.
    """

    def deco(factory: Callable[..., Aggregator]) -> Callable[..., Aggregator]:
        if name in _AGGREGATORS:
            raise ValueError(
                f"aggregator name {name!r} already registered ({_AGGREGATORS[name]})"
            )
        _AGGREGATORS[name] = factory
        return factory

    return deco


def registered_aggregators() -> tuple[str, ...]:
    return tuple(sorted(_AGGREGATORS))


def build_aggregator(spec: Union[str, Mapping[str, Any], Aggregator, None],
                     default: str = "fedavg") -> Aggregator:
    """``"fedavg"`` | ``{"aggregator": "quantized-fedavg"}`` | instance."""
    if spec is None:
        spec = default
    if isinstance(spec, Aggregator):
        return spec
    kwargs: dict[str, Any] = {}
    if isinstance(spec, Mapping):
        kwargs = dict(spec)
        try:
            spec = kwargs.pop("aggregator")
        except KeyError:
            raise ValueError(
                f'aggregator dict spec needs an "aggregator" name key '
                f"(got {sorted(kwargs)}); registered: {registered_aggregators()}"
            ) from None
    try:
        factory = _AGGREGATORS[spec]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {spec!r}; registered: {registered_aggregators()}"
        ) from None
    return factory(**kwargs)


register_aggregator("fedavg")(FedAvgAggregator)
register_aggregator("quantized-fedavg")(QuantizedFedAvgAggregator)
