"""Server-side aggregation — the streaming-first aggregation plane.

Aggregators implement one uniform **streaming protocol**, registry-keyed
like pipeline stages and runtime policies:

* ``begin(meta) -> weight`` — a client contribution starts; ``meta`` is
  the transmitted message-header dict (``num_samples``, ``client``,
  ``round`` ...). Returns the sample weight every subsequent
  ``accept_item`` call for this contribution should carry.
* ``accept_item(name, value, weight)`` — one payload item of one
  contribution, folded into the running aggregate immediately. Called
  straight from the wire decode loop (``ContainerReceiver.consume`` ->
  ``WireDecoder`` -> here), so a quantized+compressed item is
  dequantized, folded, and freed before the next item arrives — the
  server never materializes a client's payload dict.
* ``finish() -> dict`` — close the aggregate and reset.

``accept(message)`` is the batch shim: it drives the exact same protocol
methods in payload order, so batch and streaming aggregation run
*identical arithmetic in identical order* — bitwise-equal results by
construction (tests assert this across every transmission mode).

:class:`FedAvgAggregator` is the paper-faithful path: Task Results arrive
*already dequantized* (the pipeline's value stages decode in the
streaming loop), and aggregation is a sample-weighted average at original
precision — running sum + one in-flight item, never K full models.

:class:`QuantizedFedAvgAggregator` is the beyond-paper path: the server
keeps the uplink in wire form (``decode_values=False``) and folds each
int8 item through the buffer-donating dequant-accumulate-into kernel as
it arrives — one fp32 running sum per tensor, updated in place, no
per-client payload buffering and no fp32 temporary of the dequantized
contribution. The aggregate equals dequantize-then-average (tests
assert this).

:class:`LoRAFedAvgAggregator` is the parameter-efficient path: clients
ship :class:`~repro.peft.lowrank.LowRankDelta` factor pairs (via the
``lora`` stage or native adapters) and the server folds weighted factors
— the dense average materializes once, at ``finish()``, via one fused
low-rank merge per tensor.

Thread safety: ``begin``/``accept_item``/``finish`` serialize on a
per-instance lock, so many clients may stream into one aggregator
concurrently (the MemoryMeter acceptance test drives 32 senders at
once). Fold *order* under concurrency follows stream interleaving;
sample-weighted sums are order-independent in exact arithmetic, and the
deterministic runtimes (sequential controller, event scheduler) fold in
a fixed order anyway.
"""
from __future__ import annotations

import threading
from collections.abc import Callable, Mapping
from typing import Any, Union

import jax.numpy as jnp
import numpy as np

from repro.core.messages import Message
from repro.core.quantization import QuantizedTensor, dequantize, dequantize_batch
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.peft.lowrank import LowRankDelta


class Aggregator:
    """Protocol base: the streaming begin/accept_item/finish surface.

    Subclasses override the three protocol methods; ``accept`` (the
    whole-message shim) is derived and should not normally be overridden.

    ``consumes_wire`` declares that the aggregator folds payload items in
    their *wire* form (QuantizedTensor / LowRankDelta) — the job system
    reads it (:func:`aggregator_consumes_wire`) and builds the uplink
    pipeline with ``decode_values=False`` so value stages skip their
    decode hooks and the raw containers reach ``accept_item``.
    """

    name: str = "aggregator"
    consumes_wire: bool = False

    def weight_of(self, meta: Mapping[str, Any]) -> float:
        """The item weight one contribution's headers imply (pure)."""
        return float(meta.get("num_samples", 1))

    def begin(self, meta: Mapping[str, Any]) -> float:
        """Register one client contribution; returns its item weight."""
        raise NotImplementedError

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        """Fold one payload item of one contribution."""
        raise NotImplementedError

    def finish(self) -> dict[str, Any]:
        """Close the aggregate, reset state, return the result."""
        raise NotImplementedError

    def accept(self, result: Message) -> None:
        """Batch shim: drive the streaming protocol in payload order.

        The contribution is registered (``begin``) only after every item
        folded, so a payload that fails validation mid-message never
        leaves a phantom sample weight diluting ``finish()``.
        """
        w = self.weight_of(result.headers)
        for name, value in result.payload.items():
            self.accept_item(name, value, w)
        self.begin(result.headers)


class FedAvgAggregator(Aggregator):
    """Sample-weighted incremental FedAvg at original precision."""

    name = "fedavg"

    def __init__(self) -> None:
        self._sum: dict[str, np.ndarray] = {}
        self._scratch: dict[tuple[int, ...], np.ndarray] = {}
        self._weight = 0.0
        self.accepted = 0
        self._lock = threading.Lock()

    def begin(self, meta: Mapping[str, Any]) -> float:
        w = self.weight_of(meta)
        with obs_trace.span("agg.begin", "agg",
                            client=str(meta.get("client", "")), weight=w):
            with self._lock:
                self._weight += w
                self.accepted += 1
        return w

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        """Streaming entry point: one item of one client's result.

        The fold reuses a per-shape scratch buffer for the weighted
        contribution (``w * x`` lands in scratch, scratch adds into the
        running sum), so folding an item allocates nothing after the
        first round — same arithmetic, same order, bitwise-equal
        results to the naive ``sum += value * weight``.
        """
        if isinstance(value, QuantizedTensor):
            raise TypeError(
                f"FedAvgAggregator received a quantized item {name!r}; "
                "decode values on the uplink pipeline (the default) or use "
                "QuantizedFedAvgAggregator"
            )
        arr = np.asarray(value, dtype=np.float32)
        with self._lock:
            acc = self._sum.get(name)
            if acc is None:
                self._sum[name] = arr * np.float32(weight)
                return
            scratch = self._scratch.get(arr.shape)
            if scratch is None:
                scratch = np.empty(arr.shape, np.float32)
                self._scratch[arr.shape] = scratch
            np.multiply(arr, np.float32(weight), out=scratch)
            acc += scratch

    def finish(self) -> dict[str, np.ndarray]:
        with obs_trace.span("agg.finish", "agg"), self._lock:
            if self._weight <= 0:
                raise RuntimeError("no results accepted")
            out = {
                name: (arr / self._weight).astype(np.float32)
                for name, arr in self._sum.items()
            }
            self._sum = {}
            self._weight = 0.0
            self.accepted = 0
        return out


class QuantizedFedAvgAggregator(Aggregator):
    """Aggregates blockwise8 Task Results directly from int8 payloads —

    the server never materializes K fp32 models. ``accept_item`` is a
    **fused streaming fold**: each contribution runs the buffer-donating
    dequant-accumulate-into kernel
    (:func:`repro.kernels.ops.dequant_accumulate8_into`), updating one
    fp32 running sum per tensor in place the moment the item decodes.
    Server state is O(1 accumulator per tensor) regardless of how many
    clients stream in — no per-client payload buffering, and the
    dequantized contribution never exists as a standalone fp32
    temporary. Non-quantized (small) items fall back to plain averaging.
    """

    name = "quantized-fedavg"
    consumes_wire = True

    def __init__(self) -> None:
        self._acc: dict[str, Any] = {}                    # running weighted sums
        self._shape: dict[str, tuple[int, ...]] = {}      # orig shapes
        self._plain = FedAvgAggregator()
        self._plain_names: set[str] = set()
        self._weight = 0.0
        self.accepted = 0
        self._lock = threading.Lock()

    def begin(self, meta: Mapping[str, Any]) -> float:
        w = self.weight_of(meta)
        with obs_trace.span("agg.begin", "agg",
                            client=str(meta.get("client", "")), weight=w):
            with self._lock:
                self._weight += w
                self.accepted += 1
        return w

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        if isinstance(value, QuantizedTensor):
            if value.fmt != "blockwise8":
                raise TypeError(
                    f"QuantizedFedAvgAggregator supports blockwise8; {name!r} is {value.fmt}"
                )
            with self._lock:
                known = self._shape.get(name)
                if known is not None and known != tuple(value.orig_shape):
                    raise ValueError(
                        f"contribution for {name!r} has shape "
                        f"{tuple(value.orig_shape)}; aggregate holds {known}"
                    )
                self._shape[name] = tuple(value.orig_shape)
                tr = obs_trace.ACTIVE
                if tr is None:
                    self._acc[name] = ops.dequant_accumulate8_into(
                        self._acc.get(name), value.payload, value.absmax, weight
                    )
                else:
                    with tr.span("kernel.dequant_accumulate8", "kernel",
                                 item=name,
                                 nbytes=int(np.asarray(value.payload).nbytes)):
                        self._acc[name] = ops.dequant_accumulate8_into(
                            self._acc.get(name), value.payload, value.absmax, weight
                        )
        else:
            self._plain.accept_item(name, value, weight)
            with self._lock:
                self._plain_names.add(name)

    def finish(self) -> dict[str, np.ndarray]:
        with obs_trace.span("agg.finish", "agg"), self._lock:
            # the fold's single sync point: every accept_item dispatch so
            # far was async (the donated fold kernel queues on XLA's own
            # threadpool while the receiver assembles the next item); one
            # barrier here beats a device round trip per tensor below
            ops.block_until_ready(list(self._acc.values()))
            out: dict[str, np.ndarray] = {}
            inv = np.float32(1.0) / np.float32(self._weight if self._weight else 1.0)
            for name, acc in self._acc.items():
                shape = self._shape[name]
                n = int(np.prod(shape))
                out[name] = (
                    np.asarray(acc).reshape(-1)[:n].reshape(shape) * inv
                ).astype(np.float32)
            if self._plain_names:
                # reuse the plain aggregator's running sum (shares self._weight)
                self._plain._weight = self._weight
                out.update(self._plain.finish())
            self._acc = {}
            self._shape = {}
            self._plain_names = set()
            self._weight = 0.0
            self.accepted = 0
        return out


class LoRAFedAvgAggregator(Aggregator):
    """Streams :class:`~repro.peft.lowrank.LowRankDelta` contributions
    into a sample-weighted average **without ever materializing a dense
    per-client delta**. ``accept_item`` appends the factor pair per
    tensor — the left factor pre-scaled by ``weight * alpha/rank``, the
    right factor kept by reference — so server state during the fold is
    ``O(clients * rank * dim)``, independent of the dense model size
    (the MemoryMeter acceptance test pins this). The weighted average

    .. math:: (1/W) \\sum_i w_i (\\alpha_i/r_i) A_i B_i
              = \\text{concat}_1(\\tilde A_i) \\cdot \\text{concat}_0(B_i) / W

    materializes exactly once, in ``finish()``, as one fused
    block-matmul dispatch per tensor
    (:func:`repro.kernels.ops.low_rank_merge` over the concatenated
    factor blocks). Contributions may carry *different* ranks/alphas per
    client — the concatenation is rank-heterogeneous by construction.

    Non-low-rank items fall back: QuantizedTensor stragglers (a composed
    ``lora -> quantize`` uplink keeps small dense tensors quantized)
    dequantize and fold through the plain path; dense arrays fold
    directly. Wire-form uplinks (``consumes_wire``) mean the job system
    builds the task-result pipeline with ``decode_values=False``.
    """

    name = "lora-fedavg"
    consumes_wire = True

    def __init__(self) -> None:
        self._a: dict[str, list[np.ndarray]] = {}        # weight-scaled left factors
        self._b: dict[str, list[np.ndarray]] = {}        # right factors (by reference)
        self._shape: dict[str, tuple[int, ...]] = {}
        self._plain = FedAvgAggregator()
        self._plain_names: set[str] = set()
        self._weight = 0.0
        self.accepted = 0
        self._lock = threading.Lock()

    def begin(self, meta: Mapping[str, Any]) -> float:
        w = self.weight_of(meta)
        with obs_trace.span("agg.begin", "agg",
                            client=str(meta.get("client", "")), weight=w):
            with self._lock:
                self._weight += w
                self.accepted += 1
        return w

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        if isinstance(value, LowRankDelta):
            with self._lock:
                known = self._shape.get(name)
                if known is not None and known != tuple(value.orig_shape):
                    raise ValueError(
                        f"contribution for {name!r} has shape "
                        f"{tuple(value.orig_shape)}; aggregate holds {known}"
                    )
                self._shape[name] = tuple(value.orig_shape)
                # the left factor absorbs this contribution's sample
                # weight and LoRA scale (one O(m*r) scaled copy); the
                # right factor is held as received — finish() then needs
                # no per-contribution bookkeeping at all
                self._a.setdefault(name, []).append(
                    np.asarray(value.a, np.float32)
                    * np.float32(weight * value.scale)
                )
                self._b.setdefault(name, []).append(
                    np.asarray(value.b, np.float32)
                )
        else:
            if isinstance(value, QuantizedTensor):
                # small tensors a composed lora->quantize stack left
                # quantized: recover precision, fold through plain FedAvg
                value = np.asarray(dequantize(value), np.float32)
            self._plain.accept_item(name, value, weight)
            with self._lock:
                self._plain_names.add(name)

    def finish(self) -> dict[str, np.ndarray]:
        with obs_trace.span("agg.finish", "agg"), self._lock:
            out: dict[str, np.ndarray] = {}
            inv = np.float32(1.0) / np.float32(self._weight if self._weight else 1.0)
            tr = obs_trace.ACTIVE
            for name, a_parts in self._a.items():
                shape = self._shape[name]
                a_cat = a_parts[0] if len(a_parts) == 1 else np.concatenate(a_parts, axis=1)
                b_parts = self._b[name]
                b_cat = b_parts[0] if len(b_parts) == 1 else np.concatenate(b_parts, axis=0)
                if tr is None:
                    dense = ops.low_rank_merge(a_cat, b_cat, inv)
                else:
                    with tr.span("kernel.lora_merge", "kernel", item=name,
                                 rank=int(a_cat.shape[1])):
                        dense = ops.low_rank_merge(a_cat, b_cat, inv)
                out[name] = np.asarray(dense).reshape(shape).astype(np.float32)
            if self._plain_names:
                # reuse the plain aggregator's running sum (shares self._weight)
                self._plain._weight = self._weight
                out.update(self._plain.finish())
            self._a = {}
            self._b = {}
            self._shape = {}
            self._plain_names = set()
            self._weight = 0.0
            self.accepted = 0
        return out


class CollectingSink:
    """Protocol-shaped sink that just rebuilds the payload dict — the
    fallback for consumers that still need whole-message results (e.g. a
    third-party policy without a streaming override)."""

    def __init__(self) -> None:
        self.payload: dict[str, Any] = {}
        self.meta: dict[str, Any] = {}

    def begin(self, meta: Mapping[str, Any]) -> float:
        self.meta = dict(meta)
        return float(meta.get("num_samples", 1))

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        self.payload[name] = value

    def finish(self) -> dict[str, Any]:
        """Close collect mode: any QuantizedTensor items still in wire
        form (a ``decode_values=False`` uplink) dequantize in **one
        fused kernel dispatch per format group** with a single device
        sync (:func:`repro.core.quantization.dequantize_batch`) instead
        of a dispatch-and-sync per item in the receive loop — bitwise
        the same dense payload, batched decode schedule. The payload
        dict is updated in place and returned."""
        self.payload = dequantize_batch(self.payload)
        return self.payload


# ---------------------------------------------------------------------------
# Aggregator registry (the job system resolves "aggregator" names here)
# ---------------------------------------------------------------------------

_AGGREGATORS: dict[str, Callable[..., Aggregator]] = {}


def register_aggregator(
    name: str,
) -> Callable[[Callable[..., Aggregator]], Callable[..., Aggregator]]:
    """Decorator binding a spec name to an aggregator factory — the same
    registry pattern as ``repro.core.pipeline.register_stage`` and
    ``repro.runtime.async_agg.register_policy``; third-party aggregators
    become addressable from job specs without touching :mod:`repro.fl.job`.
    """

    def deco(factory: Callable[..., Aggregator]) -> Callable[..., Aggregator]:
        if name in _AGGREGATORS:
            raise ValueError(
                f"aggregator name {name!r} already registered ({_AGGREGATORS[name]})"
            )
        _AGGREGATORS[name] = factory
        return factory

    return deco


def registered_aggregators() -> tuple[str, ...]:
    return tuple(sorted(_AGGREGATORS))


def build_aggregator(spec: Union[str, Mapping[str, Any], Aggregator, None],
                     default: str = "fedavg") -> Aggregator:
    """``"fedavg"`` | ``{"aggregator": "quantized-fedavg"}`` | instance."""
    if spec is None:
        spec = default
    if isinstance(spec, Aggregator):
        return spec
    kwargs: dict[str, Any] = {}
    if isinstance(spec, Mapping):
        kwargs = dict(spec)
        try:
            spec = kwargs.pop("aggregator")
        except KeyError:
            raise ValueError(
                f'aggregator dict spec needs an "aggregator" name key '
                f"(got {sorted(kwargs)}); registered: {registered_aggregators()}"
            ) from None
    try:
        factory = _AGGREGATORS[spec]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {spec!r}; registered: {registered_aggregators()}"
        ) from None
    return factory(**kwargs)


def aggregator_consumes_wire(
    spec: Union[str, Mapping[str, Any], Aggregator, None],
    default: str = "fedavg",
) -> bool:
    """Whether the aggregator a spec names folds wire-form payload items
    (``consumes_wire``) — resolved *without* instantiating, so the job
    system can decide ``decode_values`` while building pipelines. Unknown
    names resolve False here; :func:`build_aggregator` raises later with
    the full registered list."""
    if spec is None:
        spec = default
    if isinstance(spec, Aggregator):
        return bool(spec.consumes_wire)
    if isinstance(spec, Mapping):
        spec = spec.get("aggregator", default)
    factory = _AGGREGATORS.get(spec)
    return bool(getattr(factory, "consumes_wire", False))


register_aggregator("fedavg")(FedAvgAggregator)
register_aggregator("quantized-fedavg")(QuantizedFedAvgAggregator)
register_aggregator("lora-fedavg")(LoRAFedAvgAggregator)
