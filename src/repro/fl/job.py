"""Declarative FL job system (NVFlare-style): one JSON/dict describes the

whole federation — model, clients, data partitioning, the filter stack at
each of the four points, transmission mode — and the runner builds and
executes it. The paper's "no code change, just a configuration change"
claim is this surface: switching quantization on/off/format or streaming
mode touches only the job spec.

    spec = {
      "arch": "llama3.2-1b", "smoke": true,
      "rounds": 5, "local_steps": 4, "batch": 8, "seq": 64, "lr": 3e-3,
      "clients": 3, "partition": "dirichlet", "alpha": 0.5,
      "quantization": {"fmt": "blockwise8", "error_feedback": false},
      "dp_sigma": 0.0,
      "transmission": "container", "driver": "loopback", "chunk_mb": 1
    }
    result = run_job(spec)
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.filters import (
    DequantizeFilter,
    DPGaussianNoiseFilter,
    ErrorFeedbackQuantizeFilter,
    FilterChain,
    FilterPoint,
    QuantizeFilter,
    no_filters,
)
from repro.data import dirichlet_partition, iid_partition
from repro.fl.aggregator import FedAvgAggregator, QuantizedFedAvgAggregator
from repro.fl.executor import TrainExecutor
from repro.fl.simulator import FLSimulator, SimulationConfig
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

DEFAULTS: Dict[str, Any] = {
    "smoke": True,
    "rounds": 5,
    "local_steps": 4,
    "batch": 8,
    "seq": 64,
    "lr": 3e-3,
    "clients": 3,
    "partition": "iid",
    "alpha": 0.5,
    "quantization": None,
    "dp_sigma": 0.0,
    "transmission": "container",
    "driver": "loopback",
    "chunk_mb": 1,
    "server_quantized_aggregation": False,
    "seed": 0,
}


def _build_filters(spec: Dict[str, Any]):
    """Two-way scheme (+optional EF / DP) from the job spec."""
    server = no_filters()
    client = no_filters()
    q = spec.get("quantization")
    if q:
        fmt = q["fmt"]
        mk = (
            (lambda: ErrorFeedbackQuantizeFilter(fmt))
            if q.get("error_feedback")
            else (lambda: QuantizeFilter(fmt))
        )
        server[FilterPoint.TASK_DATA_OUT] = FilterChain([mk()])
        client[FilterPoint.TASK_DATA_IN] = FilterChain([DequantizeFilter()])
        out_chain: List[Any] = []
        if spec.get("dp_sigma"):
            out_chain.append(DPGaussianNoiseFilter(spec["dp_sigma"], seed=spec["seed"]))
        out_chain.append(mk())
        client[FilterPoint.TASK_RESULT_OUT] = FilterChain(out_chain)
        if not spec.get("server_quantized_aggregation"):
            server[FilterPoint.TASK_RESULT_IN] = FilterChain([DequantizeFilter()])
    elif spec.get("dp_sigma"):
        client[FilterPoint.TASK_RESULT_OUT] = FilterChain(
            [DPGaussianNoiseFilter(spec["dp_sigma"], seed=spec["seed"])]
        )
    return server, client


def run_job(spec: Dict[str, Any]) -> Dict[str, Any]:
    spec = {**DEFAULTS, **spec}
    cfg = get_smoke_config(spec["arch"]) if spec["smoke"] else get_config(spec["arch"])
    model = create_model(cfg)

    if spec["partition"] == "dirichlet":
        datasets = dirichlet_partition(
            cfg.vocab_size, spec["seq"], spec["clients"], alpha=spec["alpha"], seed=spec["seed"]
        )
    else:
        datasets = iid_partition(cfg.vocab_size, spec["seq"], spec["clients"], seed=spec["seed"])

    @jax.jit
    def local_step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(spec["lr"]))
        return params, opt, loss

    history: List[float] = []

    def make_client(name, data):
        def train_fn(flat_params, rnd):
            p = unflatten_state_dict(
                {k: jnp.asarray(np.asarray(v)) for k, v in flat_params.items()}
            )
            opt = adamw_init(p)
            loss = None
            for _ in range(spec["local_steps"]):
                batch = {k: jnp.asarray(v) for k, v in data.sample(spec["batch"]).items()}
                p, opt, loss = local_step(p, opt, batch)
            history.append(float(loss))
            return flatten_state_dict(p), spec["batch"] * spec["local_steps"], {"loss": float(loss)}

        return TrainExecutor(name, train_fn)

    server_filters, client_filters = _build_filters(spec)
    agg = (
        QuantizedFedAvgAggregator()
        if spec.get("server_quantized_aggregation") and spec.get("quantization")
        else FedAvgAggregator()
    )
    sim = FLSimulator(
        [make_client(f"site-{i}", d) for i, d in enumerate(datasets)],
        agg,
        SimulationConfig(
            num_rounds=spec["rounds"],
            transmission=spec["transmission"],
            chunk_size=int(spec["chunk_mb"] * (1 << 20)),
            driver=spec["driver"],
        ),
        server_filters=server_filters,
        client_filters=client_filters,
    )
    init = flatten_state_dict(model.init(jax.random.PRNGKey(spec["seed"])))
    final = sim.run(init)
    return {
        "final_weights": final,
        "history": history,
        "messages": sim.stats.messages,
        "wire_bytes": sim.stats.bytes_sent,
    }


def run_job_file(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        return run_job(json.load(fh))
