"""Declarative FL job system (NVFlare-style): one JSON/dict describes the

whole federation — model, clients, data partitioning, the filter stack at
each of the four points, transmission mode, and the runtime scenario —
and the runner builds and executes it. The paper's "no code change, just
a configuration change" claim is this surface: switching quantization
on/off/format, streaming mode, or the *entire scheduling regime* touches
only the job spec.

    spec = {
      "arch": "llama3.2-1b", "smoke": true,
      "rounds": 5, "local_steps": 4, "batch": 8, "seq": 64, "lr": 3e-3,
      "clients": 3, "partition": "dirichlet", "alpha": 0.5,
      "pipeline": {                      # per-direction wire stacks, by name
        "task_data_out": ["quantize:nf4", "zlib"],
        "task_result_out": ["quantize:nf4", "zlib", "crc32"]
      },
      "transmission": "container", "driver": "loopback", "chunk_mb": 1,
      "server_streaming_agg": true,   # fold uplink items as they decode
      "aggregator": "fedavg",         # any registered aggregator name
      "runtime": {                       # optional: async scenario engine
        "policy": "fedasync",            # any registered policy name
        "max_concurrency": 8, "dropout_prob": 0.1, "max_retries": 2,
        "total_tasks": 15,               # fedasync/fedbuff task budget
        "network": {"kind": "hetero", "tiers": ["fiber", "lte", "3g"]},
        "availability": {"kind": "random", "mean_online_s": 60,
                         "mean_offline_s": 20, "horizon_s": 600}
      }
    }
    result = run_job(spec)

``"pipeline"`` entries are registered stage specs
(:mod:`repro.core.pipeline`): strings like ``"quantize:nf4"`` /
``"zlib:9"`` or dicts like ``{"stage": "adaptive", "budget_s": 0.5}``;
stage transforms run per item inside the streaming loop, so a
container-streamed quantized+compressed hop peaks at ~one item of
transmission memory. Policy names resolve through the runtime's policy
registry, driver names through the streaming driver registry — third-
party stages/drivers/policies plug in by registering, no job.py edits.

The older ``"quantization"``/``"dp_sigma"`` keys still work and build
the legacy Filter chains (adapted through the deprecated whole-message
shim); they are mutually exclusive with ``"pipeline"``. With
``{"fmt": "adaptive"}`` (or an ``"adaptive"`` pipeline stage) and a
runtime network, each client's wire precision tracks its simulated link
(slow links get 8-bit/NF4, fast links fp16/fp32) — see
``result["adaptive_fmts"]``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.filters import (
    AdaptiveQuantizeFilter,
    DequantizeFilter,
    DPGaussianNoiseFilter,
    ErrorFeedbackQuantizeFilter,
    FilterChain,
    FilterPoint,
    QuantizeFilter,
    no_filters,
)
from repro.core.pipeline import AdaptiveQuantizeStage, build_pipeline
from repro.data import dirichlet_partition, iid_partition
from repro.fl.aggregator import aggregator_consumes_wire, build_aggregator
from repro.kernels import ops
from repro.fl.executor import TrainExecutor
from repro.fl.simulator import FLSimulator, SimulationConfig
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

DEFAULTS: dict[str, Any] = {
    "smoke": True,
    "rounds": 5,
    "local_steps": 4,
    "batch": 8,
    "seq": 64,
    "lr": 3e-3,
    "clients": 3,
    "partition": "iid",
    "alpha": 0.5,
    "quantization": None,
    "dp_sigma": 0.0,
    "pipeline": None,
    "transmission": "container",
    "driver": "loopback",
    "chunk_mb": 1,
    "server_quantized_aggregation": False,
    # streaming-first aggregation plane: Task Result items fold into the
    # aggregator one at a time inside the receive loop (server peak
    # transmission+aggregation memory ~ one item, not one model per
    # in-flight client); composes with every policy and with
    # server_quantized_aggregation
    "server_streaming_agg": False,
    # registry-keyed aggregator selection ("fedavg", "quantized-fedavg",
    # or anything registered via repro.fl.aggregator.register_aggregator);
    # None resolves from server_quantized_aggregation
    "aggregator": None,
    "runtime": None,
    # quantize-kernel backend for the whole run ("ref", "pallas",
    # "pallas_interpret", "auto"); None keeps the process default
    # (REPRO_KERNEL_BACKEND env, else auto). All backends produce
    # bitwise-identical payloads — this selects an implementation, never
    # a format — so it is a pure performance knob, declarative like
    # everything else here. The live federation plane passes it through
    # to the server and every client subprocess.
    "kernel_backend": None,
    # observability: truthy turns on the span tracer (flight recorder);
    # a string is also the Chrome-trace output path the run writes
    # (viewable in Perfetto / chrome://tracing). result["telemetry"]
    # carries the metrics snapshot either way.
    "trace": None,
    # fault tolerance (live plane only; the simulator ignores these).
    # quorum: fraction of the roster whose uplinks complete a round —
    # once reached and straggler_grace_s expires, the server folds the
    # contributors it has and re-invites stragglers next round. None
    # keeps the all-clients-or-round_timeout_s behavior.
    "quorum": None,
    "straggler_grace_s": 30.0,
    # reconnect budget per client process: transient ConnectionError /
    # timeout triggers capped exponential backoff + jitter, up to this
    # many attempts per run
    "max_reconnects": 5,
    # checkpoint: directory for atomic per-round server state (epoch +
    # global weights + roster) — the --resume restart point
    "checkpoint": None,
    # chaos: {client_name: fault plan} routed through a ChaosProxy per
    # afflicted client when spawning subprocesses (test/CI harness)
    "chaos": None,
    "seed": 0,
}


def normalize_spec(spec: dict[str, Any]) -> dict[str, Any]:
    """The canonical spec every builder consumes: ``DEFAULTS`` applied.

    Shared by :func:`build_job` and the live federation plane
    (:mod:`repro.launch.federation`) so both resolve identical settings
    from the same declarative input."""
    out = {**DEFAULTS, **spec}
    kb = out.get("kernel_backend")
    if kb is not None and kb not in ops.BACKENDS:
        raise ValueError(
            f'"kernel_backend" must be one of {ops.BACKENDS}, got {kb!r}'
        )
    return out


def kernel_backend_scope(spec: dict[str, Any]) -> Any:
    """Scoped application of the spec's ``"kernel_backend"`` selection —
    a :func:`repro.kernels.ops.backend` context when the key is set, a
    no-op otherwise. Shared by :meth:`Job.run` and the live federation
    plane (server run loop and client subprocess main), so one spec key
    selects the kernel implementation on every process of a deployment."""
    kb = spec.get("kernel_backend")
    return ops.backend(kb) if kb else contextlib.nullcontext()


def _adaptive_filter(q: dict[str, Any], network: Optional[Any]) -> AdaptiveQuantizeFilter:
    f = AdaptiveQuantizeFilter(
        bandwidth_bps=float(q.get("bandwidth_mbps", 80.0)) * 1e6,  # wifi-class fallback
        budget_s=float(q.get("budget_s", 1.0)),
        min_params=int(q.get("min_params", 0)),
    )
    if network is not None:
        f.bind_network(network)
    return f


_PIPELINE_DIRECTIONS = {
    # canonical hop names + legacy four-point OUT aliases
    "task_data": "task_data",
    "task_data_out": "task_data",
    "task_result": "task_result",
    "task_result_out": "task_result",
}


def _build_pipelines(spec: dict[str, Any], network: Optional[Any]):
    """Translate the ``"pipeline"`` spec block into FLSimulator pipelines.

    Returns (pipelines dict, adaptive stages found) — adaptive stages get
    the runtime network bound so per-client precision tracks the
    simulated link, and are reported in ``result["adaptive_fmts"]``.
    """
    p = spec["pipeline"]
    if spec.get("quantization") or spec.get("dp_sigma"):
        raise ValueError(
            '"pipeline" replaces the legacy "quantization"/"dp_sigma" keys; '
            'declare those transforms as stages (e.g. "quantize:nf4", '
            '{"stage": "dp-noise", "sigma": 0.01})'
        )
    unknown = set(p) - set(_PIPELINE_DIRECTIONS)
    if unknown:
        raise ValueError(
            f"unknown pipeline directions {sorted(unknown)}; "
            f"valid: {sorted(_PIPELINE_DIRECTIONS)}"
        )
    specs: dict[str, list[Any]] = {"task_data": [], "task_result": []}
    for key, stages in p.items():
        specs[_PIPELINE_DIRECTIONS[key]] += list(stages or [])
    # aggregators that fold wire-form payloads (QuantizedTensor /
    # LowRankDelta) need the uplink left undecoded
    keep_wire = bool(spec.get("server_quantized_aggregation")) or \
        aggregator_consumes_wire(aggregator_spec(spec))
    pipelines = {
        "task_data": build_pipeline(specs["task_data"]),
        "task_result": build_pipeline(specs["task_result"], decode_values=not keep_wire),
    }
    adaptive: list[AdaptiveQuantizeStage] = []
    for pl in pipelines.values():
        for stage in pl.stages:
            if isinstance(stage, AdaptiveQuantizeStage):
                if keep_wire:
                    raise ValueError(
                        "server_quantized_aggregation does not compose with the "
                        "adaptive stage: clients may ship mixed formats"
                    )
                if network is not None:
                    stage.bind_network(network)
                adaptive.append(stage)
    return pipelines, adaptive


def build_pipelines_from_spec(
    spec: dict[str, Any], network: Optional[Any] = None
) -> dict[str, Any]:
    """Wire pipelines for a job spec — the single construction path both
    federation planes share, so the server and every client subprocess of
    a live deployment provably run the same stage stacks the simulator
    would (the pipeline fingerprint in the live handshake hashes these).

    Specs without a ``"pipeline"`` block get identity pipelines (same
    wire container, no transforms). The legacy ``"quantization"`` /
    ``"dp_sigma"`` filter keys have no pipeline form and are rejected.
    """
    spec = normalize_spec(spec)
    if spec.get("pipeline"):
        pipelines, _ = _build_pipelines(spec, network)
        return pipelines
    if spec.get("quantization") or spec.get("dp_sigma"):
        raise ValueError(
            'the legacy "quantization"/"dp_sigma" keys build whole-message '
            'Filter chains with no streaming-pipeline form; declare them as '
            '"pipeline" stages (e.g. "quantize:nf4", '
            '{"stage": "dp-noise", "sigma": 0.01})'
        )
    keep_wire = bool(spec.get("server_quantized_aggregation")) or \
        aggregator_consumes_wire(aggregator_spec(spec))
    return {
        "task_data": build_pipeline([]),
        "task_result": build_pipeline([], decode_values=not keep_wire),
    }


def aggregator_spec(spec: dict[str, Any]) -> Any:
    """Resolve the spec's aggregator selection (registry key or config
    dict) exactly as :func:`build_job` does — shared with the live plane
    so a real server folds with the same aggregator the simulator would."""
    spec = normalize_spec(spec)
    agg = spec.get("aggregator")
    if agg is None:
        agg = (
            "quantized-fedavg"
            if spec.get("server_quantized_aggregation")
            and (spec.get("quantization") or spec.get("pipeline"))
            else "fedavg"
        )
    return agg


def _client_datasets(spec: dict[str, Any], cfg: Any) -> list[Any]:
    """Deterministic per-client datasets: seed-keyed partition, so every
    process that evaluates this (simulator or client subprocess) derives
    the identical per-client data streams."""
    if spec["partition"] == "dirichlet":
        return dirichlet_partition(
            cfg.vocab_size, spec["seq"], spec["clients"],
            alpha=spec["alpha"], seed=spec["seed"],
        )
    return iid_partition(
        cfg.vocab_size, spec["seq"], spec["clients"], seed=spec["seed"]
    )


def _jit_local_step(model: Any, lr: float):
    @jax.jit
    def local_step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(lr))
        return params, opt, loss

    return local_step


def _train_executor(
    name: str, data: Any, spec: dict[str, Any], local_step: Any,
    history: Optional[list[float]] = None,
) -> TrainExecutor:
    def train_fn(flat_params, rnd):
        p = unflatten_state_dict(
            {k: jnp.asarray(np.asarray(v)) for k, v in flat_params.items()}
        )
        opt = adamw_init(p)
        loss = None
        # round-keyed sampling makes the update a pure function of
        # (params, rnd): a client that reconnects or re-executes a round
        # after a fault regenerates the identical batches, so chaos and
        # resume runs stay bitwise-equal to clean ones
        for step in range(spec["local_steps"]):
            batch = {
                k: jnp.asarray(v)
                for k, v in data.sample_at(
                    spec["batch"], rnd * spec["local_steps"] + step
                ).items()
            }
            p, opt, loss = local_step(p, opt, batch)
        if history is not None:
            history.append(float(loss))
        return flatten_state_dict(p), spec["batch"] * spec["local_steps"], {"loss": float(loss)}

    return TrainExecutor(name, train_fn)


def build_client_executor(
    spec: dict[str, Any], index: int, history: Optional[list[float]] = None
) -> TrainExecutor:
    """The executor for client ``index`` exactly as the simulator builds
    it — same model init path, same jitted local step, same seed-keyed
    data partition slice. The live federation plane's client subprocess
    entrypoint: bitwise sim-vs-real weight equality rests on this being
    one construction path, not two that happen to agree."""
    spec = normalize_spec(spec)
    cfg = get_smoke_config(spec["arch"]) if spec["smoke"] else get_config(spec["arch"])
    model = create_model(cfg)
    datasets = _client_datasets(spec, cfg)
    if not 0 <= index < len(datasets):
        raise ValueError(f"client index {index} out of range for {len(datasets)} clients")
    return _train_executor(
        f"site-{index}", datasets[index], spec, _jit_local_step(model, spec["lr"]), history
    )


def initial_weights(spec: dict[str, Any]) -> dict[str, Any]:
    """Round-0 global weights for a spec (flat state dict) — the shared
    starting point the live server downlinks, identical to what
    :func:`build_job` hands the simulator."""
    spec = normalize_spec(spec)
    cfg = get_smoke_config(spec["arch"]) if spec["smoke"] else get_config(spec["arch"])
    model = create_model(cfg)
    return flatten_state_dict(model.init(jax.random.PRNGKey(spec["seed"])))


def _build_filters(spec: dict[str, Any], network: Optional[Any] = None):
    """Two-way scheme (+optional EF / DP / link-adaptive) from the job spec."""
    server = no_filters()
    client = no_filters()
    adaptive: list[AdaptiveQuantizeFilter] = []
    q = spec.get("quantization")
    if q:
        fmt = q["fmt"]
        if fmt == "adaptive":
            if q.get("error_feedback"):
                raise ValueError("error_feedback does not compose with adaptive precision")
            if spec.get("server_quantized_aggregation"):
                # per-client formats can differ (that's the point), and the
                # fused aggregator needs one uniform wire format
                raise ValueError(
                    "server_quantized_aggregation does not compose with adaptive "
                    "precision: clients may ship mixed formats"
                )

            def mk():
                adaptive.append(_adaptive_filter(q, network))
                return adaptive[-1]
        elif q.get("error_feedback"):
            def mk():
                return ErrorFeedbackQuantizeFilter(fmt)
        else:
            def mk():
                return QuantizeFilter(fmt)
        server[FilterPoint.TASK_DATA_OUT] = FilterChain([mk()])
        client[FilterPoint.TASK_DATA_IN] = FilterChain([DequantizeFilter()])
        out_chain: list[Any] = []
        if spec.get("dp_sigma"):
            out_chain.append(DPGaussianNoiseFilter(spec["dp_sigma"], seed=spec["seed"]))
        out_chain.append(mk())
        client[FilterPoint.TASK_RESULT_OUT] = FilterChain(out_chain)
        if not spec.get("server_quantized_aggregation"):
            server[FilterPoint.TASK_RESULT_IN] = FilterChain([DequantizeFilter()])
    elif spec.get("dp_sigma"):
        client[FilterPoint.TASK_RESULT_OUT] = FilterChain(
            [DPGaussianNoiseFilter(spec["dp_sigma"], seed=spec["seed"])]
        )
    return server, client, adaptive


def _build_runtime(
    spec: dict[str, Any], aggregator: Any, client_names: list[str]
) -> dict[str, Any]:
    """Translate the ``"runtime"`` spec block into FLSimulator kwargs."""
    r = spec.get("runtime")
    if not r:
        return {}
    # imported lazily, same circularity constraint as fl.simulator
    from repro.runtime import (
        RuntimeConfig,
        availability_from_spec,
        network_from_spec,
        polynomial_staleness,
    )
    from repro.runtime.async_agg import build_policy

    r = dict(r)
    policy_name = r.get("policy", "sync")
    if policy_name in ("fedbuff", "fedasync") and spec.get("server_quantized_aggregation"):
        # these policies aggregate deltas/weights directly (not through the
        # aggregator) and skip QuantizedTensor payload items — quantized
        # server ingress would silently aggregate nothing
        raise ValueError(
            f"server_quantized_aggregation is not supported with policy "
            f"{policy_name!r}; it requires the aggregator path (sync/tiered)"
        )
    seed = int(r.get("seed", spec["seed"]))
    network = network_from_spec(r["network"], client_names) if r.get("network") else None
    availability = (
        availability_from_spec(r["availability"], client_names)
        if r.get("availability") else None
    )
    config = RuntimeConfig(
        seed=seed,
        max_concurrency=int(r.get("max_concurrency", 8)),
        dropout_prob=float(r.get("dropout_prob", 0.0)),
        max_retries=int(r.get("max_retries", 2)),
    )
    # policy names resolve through the runtime's registry (sync -> None ->
    # the scheduler's default SyncPolicy), so registered third-party
    # policies are addressable from specs without touching this module
    policy = build_policy(policy_name, r, {
        "aggregator": aggregator,
        "rounds": spec["rounds"],
        "client_names": client_names,
        "network": network,
        "seed": seed,
        "total_tasks": int(r.get("total_tasks", spec["rounds"] * len(client_names))),
        "staleness": polynomial_staleness(float(r.get("staleness_alpha", 0.5))),
    })
    return {
        "runtime": config,
        "policy": policy,
        "network": network,
        "availability": availability,
    }


@dataclasses.dataclass
class Job:
    """A fully-constructed federation, ready to run (or inspect)."""

    spec: dict[str, Any]
    sim: FLSimulator
    init_weights: dict[str, Any]
    history: list[float]
    # legacy AdaptiveQuantizeFilter instances or adaptive pipeline stages —
    # anything exposing last_fmt_by_client
    adaptive_filters: list[Any]

    def run(self) -> dict[str, Any]:
        with kernel_backend_scope(self.spec):
            final = self.sim.run(self.init_weights)
        out = {
            "final_weights": final,
            "history": self.history,
            "messages": self.sim.stats.messages,
            "wire_bytes": self.sim.stats.bytes_sent,
            "round_log": self.sim.round_log,
            "telemetry": self.sim.telemetry(),
        }
        if self.sim.scheduler is not None:
            out["sim_time_s"] = self.sim.sim_time_s
            out["runtime_stats"] = self.sim.scheduler.stats.as_dict()
            out["policy"] = self.sim.scheduler.policy.name
        if self.sim.tracer is not None and isinstance(self.spec.get("trace"), str):
            out["trace"] = self.sim.tracer.write(self.spec["trace"])
        if self.adaptive_filters:
            fmts: dict[str, str] = {}
            for f in self.adaptive_filters:
                fmts.update(f.last_fmt_by_client)
            out["adaptive_fmts"] = fmts
        return out


def build_job(spec: dict[str, Any]) -> Job:
    """Construct the federation a spec describes, without running it.

    ``run_job`` is exactly ``build_job(spec).run()`` — tests use this to
    check the declarative surface against direct FLSimulator construction.
    """
    spec = normalize_spec(spec)
    cfg = get_smoke_config(spec["arch"]) if spec["smoke"] else get_config(spec["arch"])
    model = create_model(cfg)
    datasets = _client_datasets(spec, cfg)
    local_step = _jit_local_step(model, spec["lr"])
    history: list[float] = []

    def make_client(name, data):
        return _train_executor(name, data, spec, local_step, history)

    client_names = [f"site-{i}" for i in range(len(datasets))]
    agg = build_aggregator(aggregator_spec(spec))
    runtime_kwargs = _build_runtime(spec, agg, client_names)
    if spec.get("pipeline"):
        pipelines, adaptive = _build_pipelines(spec, runtime_kwargs.get("network"))
        wire_kwargs: dict[str, Any] = {"pipelines": pipelines}
    else:
        server_filters, client_filters, adaptive = _build_filters(
            spec, network=runtime_kwargs.get("network")
        )
        wire_kwargs = {"server_filters": server_filters, "client_filters": client_filters}
    sim = FLSimulator(
        [make_client(n, d) for n, d in zip(client_names, datasets)],
        agg,
        SimulationConfig(
            num_rounds=spec["rounds"],
            transmission=spec["transmission"],
            chunk_size=int(spec["chunk_mb"] * (1 << 20)),
            driver=spec["driver"],
        ),
        server_streaming_agg=bool(spec.get("server_streaming_agg")),
        trace=bool(spec.get("trace")),
        **wire_kwargs,
        **runtime_kwargs,
    )
    init = flatten_state_dict(model.init(jax.random.PRNGKey(spec["seed"])))
    return Job(spec, sim, init, history, adaptive)


def run_job(spec: dict[str, Any]) -> dict[str, Any]:
    return build_job(spec).run()


def run_job_file(path: str) -> dict[str, Any]:
    with open(path) as fh:
        return run_job(json.load(fh))


def main(argv: Optional[list[str]] = None) -> int:
    """``python -m repro.fl.job spec.json [--trace out.json]`` — run a
    declarative job and print a JSON summary (weights omitted). The
    ``--trace`` flag turns on the span tracer and writes the run's
    Chrome trace-event file, viewable at https://ui.perfetto.dev."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.fl.job",
        description="Run a declarative FL job spec.",
    )
    ap.add_argument("spec", help="path to a JSON job spec")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="record a dual-clock span trace and write Chrome "
                         "trace-event JSON here (open in Perfetto)")
    args = ap.parse_args(argv)
    with open(args.spec) as fh:
        spec = json.load(fh)
    if args.trace:
        spec["trace"] = args.trace
    result = run_job(spec)
    result.pop("final_weights", None)
    print(json.dumps(result, indent=1, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
