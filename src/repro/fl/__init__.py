from repro.fl.aggregator import (
    Aggregator,
    CollectingSink,
    FedAvgAggregator,
    LoRAFedAvgAggregator,
    QuantizedFedAvgAggregator,
    aggregator_consumes_wire,
    build_aggregator,
    register_aggregator,
    registered_aggregators,
)
from repro.fl.controller import ScatterAndGather, make_task
from repro.fl.executor import Executor, TrainExecutor
from repro.fl.simulator import FLSimulator, SimulationConfig, TrafficStats

__all__ = [
    "Aggregator",
    "CollectingSink",
    "FedAvgAggregator",
    "LoRAFedAvgAggregator",
    "QuantizedFedAvgAggregator",
    "aggregator_consumes_wire",
    "build_aggregator",
    "register_aggregator",
    "registered_aggregators",
    "ScatterAndGather",
    "make_task",
    "Executor",
    "TrainExecutor",
    "FLSimulator",
    "SimulationConfig",
    "TrafficStats",
]
