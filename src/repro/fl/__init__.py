from repro.fl.aggregator import FedAvgAggregator, QuantizedFedAvgAggregator
from repro.fl.controller import ScatterAndGather
from repro.fl.executor import Executor, TrainExecutor
from repro.fl.simulator import FLSimulator, SimulationConfig

__all__ = [
    "FedAvgAggregator",
    "QuantizedFedAvgAggregator",
    "ScatterAndGather",
    "Executor",
    "TrainExecutor",
    "FLSimulator",
    "SimulationConfig",
]
