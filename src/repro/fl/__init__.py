from repro.fl.aggregator import FedAvgAggregator, QuantizedFedAvgAggregator
from repro.fl.controller import ScatterAndGather, make_task
from repro.fl.executor import Executor, TrainExecutor
from repro.fl.simulator import FLSimulator, SimulationConfig, TrafficStats

__all__ = [
    "FedAvgAggregator",
    "QuantizedFedAvgAggregator",
    "ScatterAndGather",
    "make_task",
    "Executor",
    "TrainExecutor",
    "FLSimulator",
    "SimulationConfig",
    "TrafficStats",
]
