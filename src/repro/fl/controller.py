"""Server-side Controller (paper §II-A, Fig. 2).

:class:`ScatterAndGather` implements the canonical FL workflow: its
``run()`` loop broadcasts Task Data (global weights) to every client
proxy, gathers Task Results (local updates), aggregates, and repeats.
Transport, filtering and streaming live behind the :class:`ClientProxy`
interface so the same controller runs over the in-process simulator, TCP
drivers, or the mesh view.
"""
from __future__ import annotations

import inspect
import time
from collections.abc import Callable, Mapping, Sequence
from typing import Any, Optional

from repro.core.messages import Message, MessageKind
from repro.obs import trace as obs_trace


def make_task(rnd: int, global_weights: Mapping[str, Any]) -> Message:
    """Build one round's Task Data message.

    Shared by :class:`ScatterAndGather` and the async runtime's policies
    (``repro.runtime.async_agg``) so both construct byte-identical tasks —
    the basis of the runtime's bitwise sync-equivalence guarantee.
    """
    return Message(
        MessageKind.TASK_DATA,
        dict(global_weights),
        headers={"round": rnd, "task_name": "train"},
    )


class ClientProxy:
    """What the Controller sees of one client site.

    ``result_sink`` (optional) is the streaming-aggregation hook: a
    proxy that supports it feeds the Task Result's decoded items into
    ``sink.begin(meta)`` / ``sink.accept_item(name, value, weight)``
    *during* the uplink transfer and returns a payload-less Message
    (headers only) — the server never materializes the client's payload
    dict. Proxies that ignore the argument simply return the full result
    for batch aggregation.
    """

    name: str = "client"

    def submit_task(self, task: Message, result_sink: Optional[Any] = None) -> Message:
        raise NotImplementedError


class ScatterAndGather:
    def __init__(
        self,
        clients: Sequence[ClientProxy],
        aggregator: Any,
        num_rounds: int,
        on_round_end: Optional[Callable[[int, dict[str, Any], list[Message]], None]] = None,
        streaming: bool = False,
    ) -> None:
        """``streaming=True`` hands the aggregator to each proxy as the
        uplink result sink: one decoded item is folded into the running
        aggregate and freed before the next arrives, so server peak
        memory is ~one item instead of one model. Clients run one at a
        time in list order either way, so streaming and batch aggregation
        execute *identical arithmetic in identical order* — bitwise-equal
        final weights (tested). Requires an aggregator implementing the
        :class:`~repro.fl.aggregator.Aggregator` streaming protocol."""
        if not clients:
            raise ValueError("need at least one client")
        self.clients = list(clients)
        self.aggregator = aggregator
        self.num_rounds = num_rounds
        self.on_round_end = on_round_end
        self.streaming = streaming
        # per-round wall timing, same entry shape the live federation
        # server records — the --verify-sim summary zips the two
        self.round_log: list[dict[str, Any]] = []
        if streaming and not (
            hasattr(aggregator, "begin") and hasattr(aggregator, "accept_item")
        ):
            raise TypeError(
                f"streaming aggregation needs the begin/accept_item/finish "
                f"protocol; {type(aggregator).__name__} lacks it (see the "
                "README migration note for custom aggregators)"
            )
        if streaming:
            for c in self.clients:
                try:
                    accepts = "result_sink" in inspect.signature(
                        c.submit_task
                    ).parameters
                except (TypeError, ValueError):  # uninspectable: trust it
                    accepts = True
                if not accepts:
                    raise TypeError(
                        f"client proxy {type(c).__name__} predates streaming "
                        "aggregation: its submit_task takes no result_sink "
                        "argument — add the parameter (see ClientProxy) or "
                        "run without streaming"
                    )

    def run(self, initial_weights: dict[str, Any]) -> dict[str, Any]:
        """The Controller's run() method (paper §II-A): task distribution

        and aggregation of returns."""
        global_weights = dict(initial_weights)
        self.round_log = []
        for rnd in range(self.num_rounds):
            results: list[Message] = []
            t0 = time.monotonic()
            with obs_trace.span("round", "round", round=rnd):
                for client in self.clients:
                    task = make_task(rnd, global_weights)
                    with obs_trace.span("client.round_trip", "round",
                                        round=rnd, client=client.name):
                        if self.streaming:
                            # the uplink wire folds each decoded item straight
                            # into the aggregator; result carries headers only
                            result = client.submit_task(
                                task, result_sink=self.aggregator
                            )
                        else:
                            result = client.submit_task(task)
                            self.aggregator.accept(result)
                    results.append(result)
                global_weights = self.aggregator.finish()
            self.round_log.append({
                "round": rnd,
                "clients": len(results),
                "wall_s": time.monotonic() - t0,
            })
            if self.on_round_end is not None:
                self.on_round_end(rnd, global_weights, results)
        return global_weights
