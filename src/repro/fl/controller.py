"""Server-side Controller (paper §II-A, Fig. 2).

:class:`ScatterAndGather` implements the canonical FL workflow: its
``run()`` loop broadcasts Task Data (global weights) to every client
proxy, gathers Task Results (local updates), aggregates, and repeats.
Transport, filtering and streaming live behind the :class:`ClientProxy`
interface so the same controller runs over the in-process simulator, TCP
drivers, or the mesh view.
"""
from __future__ import annotations

from collections.abc import Callable, Mapping, Sequence
from typing import Any, Optional

from repro.core.messages import Message, MessageKind


def make_task(rnd: int, global_weights: Mapping[str, Any]) -> Message:
    """Build one round's Task Data message.

    Shared by :class:`ScatterAndGather` and the async runtime's policies
    (``repro.runtime.async_agg``) so both construct byte-identical tasks —
    the basis of the runtime's bitwise sync-equivalence guarantee.
    """
    return Message(
        MessageKind.TASK_DATA,
        dict(global_weights),
        headers={"round": rnd, "task_name": "train"},
    )


class ClientProxy:
    """What the Controller sees of one client site."""

    name: str = "client"

    def submit_task(self, task: Message) -> Message:
        raise NotImplementedError


class ScatterAndGather:
    def __init__(
        self,
        clients: Sequence[ClientProxy],
        aggregator: Any,
        num_rounds: int,
        on_round_end: Optional[Callable[[int, dict[str, Any], list[Message]], None]] = None,
    ) -> None:
        if not clients:
            raise ValueError("need at least one client")
        self.clients = list(clients)
        self.aggregator = aggregator
        self.num_rounds = num_rounds
        self.on_round_end = on_round_end

    def run(self, initial_weights: dict[str, Any]) -> dict[str, Any]:
        """The Controller's run() method (paper §II-A): task distribution

        and aggregation of returns."""
        global_weights = dict(initial_weights)
        for rnd in range(self.num_rounds):
            results: list[Message] = []
            for client in self.clients:
                task = make_task(rnd, global_weights)
                result = client.submit_task(task)
                self.aggregator.accept(result)
                results.append(result)
            global_weights = self.aggregator.finish()
            if self.on_round_end is not None:
                self.on_round_end(rnd, global_weights, results)
        return global_weights
