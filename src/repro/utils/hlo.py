"""Loop-aware analysis of compiled (SPMD-partitioned) HLO modules.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**
regardless of trip count — useless for scan-over-layers programs. This
module re-derives roofline inputs from ``compiled.as_text()`` with loop
multipliers:

1. parse the module into computations and a call graph
   (``body=/condition=/calls=/to_apply=/branch_computations=``);
2. recover each while loop's trip count from the largest integer constant
   in its condition computation (lax.scan lowers to exactly that form);
3. propagate multipliers from ENTRY (while bodies multiply by trip count);
4. FLOPs  = sum over ``dot``/``convolution`` ops of 2 * prod(result dims)
   * prod(contracting dims) * multiplier;
5. HBM traffic = sum over ops in *executable* computations (ENTRY, loop
   bodies, branches — fusion internals excluded) of operand+result bytes
   (slice-like ops touch only slice-sized memory) * multiplier;
6. collective wire bytes (ring model) * multiplier.

All quantities are **per device**: the partitioned module has per-shard
shapes and the collectives carry replica groups.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OP_SPLIT_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_KIND_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*?(\d+)")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)|branch_computations=\{([^}]*)\}"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "iota", "call",
}
_SLICE_LIKE = {"dynamic-slice", "gather", "slice"}


def _shape_elems_bytes(type_str: str) -> tuple[list[int], int]:
    total = 0
    dims_all: list[int] = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        dd = []
        if dims:
            for d in dims.split(","):
                if d:
                    dd.append(int(d))
                    n *= int(d)
        dims_all = dd  # last shape (for dot parsing single shapes only)
        total += n * _DTYPE_BYTES[dtype]
    return dims_all, total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]


def parse_module(text: str) -> tuple[dict[str, Computation], str, dict[str, str]]:
    """Returns (computations, entry_name, symbol->result_type)."""
    comps: dict[str, Computation] = {}
    symbols: dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [])
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_SPLIT_RE.match(line)
        if m:
            name, rest = m.group(1), m.group(2)
            km = _OP_KIND_RE.search(rest)
            if not km:
                continue
            rtype = rest[: km.start()].strip()
            kind = km.group(1)
            cur.ops.append(Op(name, kind, rtype, line))
            symbols[name] = rtype
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry, symbols


def _callees(op: Op) -> list[tuple[str, str]]:
    """[(attr_kind, computation_name)] for this op."""
    out = []
    for m in _CALL_ATTR_RE.finditer(op.line):
        if m.group(1):
            attr = m.group(0).split("=")[0]
            out.append((attr, m.group(1)))
        elif m.group(2):
            for nm in _OPERAND_RE.findall(m.group(2)):
                out.append(("branch", nm))
    return out


def _trip_count(while_line: str, cond: Optional[Computation]) -> int:
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for op in cond.ops:
            for c in _CONST_RE.findall(op.line):
                best = max(best, int(c))
    return best


def compute_multipliers(
    comps: dict[str, Computation], entry: str
) -> tuple[dict[str, float], dict[str, bool]]:
    """computation -> multiplier; computation -> executable?"""
    mult: dict[str, float] = {entry: 1.0}
    execu: dict[str, bool] = {entry: True}
    stack = [entry]
    seen = set()
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        comp = comps[name]
        m = mult.get(name, 1.0)
        for op in comp.ops:
            callees = _callees(op)
            trip = None
            if op.kind == "while":
                cond_name = next((c for a, c in callees if a == "condition"), None)
                trip = _trip_count(op.line, comps.get(cond_name))
            for attr, cname in callees:
                if attr == "body":
                    child_m = m * (trip or 1)
                    child_exec = True
                elif attr == "condition":
                    child_m = m * (trip or 1)
                    child_exec = True
                elif attr == "branch":
                    child_m = m
                    child_exec = True
                else:  # calls / to_apply (fusions, reducers)
                    child_m = m
                    child_exec = False
                if child_m > mult.get(cname, 0.0):
                    mult[cname] = child_m
                    seen.discard(cname)
                execu[cname] = execu.get(cname, False) or child_exec
                stack.append(cname)
    return mult, execu


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------

def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    result_dims, _ = _shape_elems_bytes(op.result_type)
    n_out = 1
    for d in result_dims:
        n_out *= d
    # contracting dims from lhs operand shape
    mm = re.search(rf"{op.kind}\(([^)]*)\)", op.line)
    if not mm:
        return 0.0
    operands = _OPERAND_RE.findall(mm.group(1))
    if not operands:
        return 0.0
    lhs_type = symbols.get(operands[0], "")
    lhs_dims, _ = _shape_elems_bytes(lhs_type)
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * n_out * contract


def module_flops(text: str) -> float:
    comps, entry, symbols = parse_module(text)
    mult, _ = compute_multipliers(comps, entry)
    total = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname)
        if not m:
            continue
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                total += m * _dot_flops(op, symbols)
    return total


# ---------------------------------------------------------------------------
# HBM traffic
# ---------------------------------------------------------------------------

def _fusion_root(comp: Computation) -> Optional[Op]:
    for op in comp.ops:
        if "ROOT" in op.line:
            return op
    return comp.ops[-1] if comp.ops else None


def _fusion_param_access(comp: Computation) -> dict[int, str]:
    """param index -> access kind ('slice' if only consumed via an internal

    dynamic-slice/gather, else 'full'). Scan-body fusions slice their
    residual-stack operands — HBM reads are page-sized, not full-tensor."""
    param_syms: dict[str, int] = {}
    for op in comp.ops:
        if op.kind == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                param_syms[op.name] = int(m.group(1))
    sliced: dict[int, bool] = {}
    for op in comp.ops:
        mm = re.search(rf"{op.kind}(?:-start|-done)?\(([^)]*)\)", op.line)
        if not mm:
            continue
        used = _OPERAND_RE.findall(mm.group(1))
        for pos, nm in enumerate(used):
            if nm not in param_syms:
                continue
            idx = param_syms[nm]
            is_slice_src = op.kind in ("dynamic-slice", "gather") and pos == 0
            if idx not in sliced:
                sliced[idx] = is_slice_src
            else:
                sliced[idx] = sliced[idx] and is_slice_src
    return {i: ("slice" if v else "full") for i, v in sliced.items()}


def _dus_update_bytes(root: Op, symbols: dict[str, str]) -> Optional[float]:
    """If `root` is a dynamic-update-slice, bytes of its update operand."""
    if root is None or root.kind != "dynamic-update-slice":
        return None
    mm = re.search(r"dynamic-update-slice\(([^)]*)\)", root.line)
    ops_ = _OPERAND_RE.findall(mm.group(1)) if mm else []
    if len(ops_) > 1:
        return float(_shape_elems_bytes(symbols.get(ops_[1], ""))[1])
    return None


def module_traffic_bytes(text: str) -> float:
    comps, entry, symbols = parse_module(text)
    mult, execu = compute_multipliers(comps, entry)
    total = 0.0
    for cname, comp in comps.items():
        if not execu.get(cname):
            continue
        m = mult.get(cname, 0.0)
        if not m:
            continue
        for op in comp.ops:
            if op.kind in _SKIP_TRAFFIC:
                continue
            if op.kind == "fusion":
                callee_name = next(
                    (c for a, c in _callees(op) if a not in ("body", "condition")), None
                )
                callee = comps.get(callee_name)
                if callee is not None:
                    # result side: in-place DUS-rooted accumulators write
                    # only the updated slice
                    ub = _dus_update_bytes(_fusion_root(callee), symbols)
                    rbytes_f = 2.0 * ub if ub is not None else _shape_elems_bytes(op.result_type)[1]
                    # operand side: params consumed only via internal
                    # dynamic-slice/gather read page-sized data
                    access = _fusion_param_access(callee)
                    mm = re.search(r"fusion\(([^)]*)\)", op.line)
                    obytes_f = 0.0
                    if mm:
                        for pos, nm in enumerate(_OPERAND_RE.findall(mm.group(1))):
                            full = _shape_elems_bytes(symbols.get(nm, ""))[1]
                            if access.get(pos) == "slice":
                                # slice extent unknown here; bounded by the
                                # fusion's own result size (scan bodies touch
                                # one step's page)
                                obytes_f += min(full, _shape_elems_bytes(op.result_type)[1])
                            else:
                                obytes_f += full
                    total += m * (rbytes_f + obytes_f)
                    continue
            _, rbytes = _shape_elems_bytes(op.result_type)
            if op.kind in _SLICE_LIKE:
                total += m * 2.0 * rbytes  # touches slice-sized memory
                continue
            if op.kind == "dynamic-update-slice":
                # in-place update: writes the update operand's extent
                mm = re.search(r"dynamic-update-slice\(([^)]*)\)", op.line)
                ops_ = _OPERAND_RE.findall(mm.group(1)) if mm else []
                ub = _shape_elems_bytes(symbols.get(ops_[1], ""))[1] if len(ops_) > 1 else rbytes
                total += m * 2.0 * ub
                continue
            # operands + result
            obytes = 0
            mm = re.search(rf"{op.kind}(?:-start|-done)?\(([^)]*)\)", op.line)
            if mm:
                for nm in _OPERAND_RE.findall(mm.group(1)):
                    obytes += _shape_elems_bytes(symbols.get(nm, ""))[1]
            total += m * (rbytes + obytes)
    return total


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------

def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_stats(text: str) -> dict[str, dict[str, float]]:
    """Loop-aware per-op-kind {count, result_bytes, wire_bytes} per device."""
    comps, entry, symbols = parse_module(text)
    mult, _ = compute_multipliers(comps, entry)
    stats: dict[str, dict[str, float]] = {}
    for cname, comp in comps.items():
        m = mult.get(cname)
        if not m:
            continue
        for op in comp.ops:
            kind = op.kind.replace("-start", "")
            if kind not in COLLECTIVE_OPS or op.kind.endswith("-done"):
                continue
            _, size = _shape_elems_bytes(op.result_type)
            n = _group_size(op.line)
            frac = (n - 1) / n if n > 1 else 0.0
            if kind == "all-reduce":
                wire = 2.0 * size * frac
            elif kind == "collective-permute":
                wire = float(size)
            else:
                wire = size * frac
            s = stats.setdefault(
                kind, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0}
            )
            s["count"] += m
            s["result_bytes"] += m * size
            s["wire_bytes"] += m * wire
    return stats


def total_collective_wire_bytes(text: str) -> float:
    return sum(s["wire_bytes"] for s in collective_stats(text).values())


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def analyze_module(text: str) -> dict[str, float]:
    return {
        "flops": module_flops(text),
        "traffic_bytes": module_traffic_bytes(text),
        "collective_wire_bytes": total_collective_wire_bytes(text),
    }
