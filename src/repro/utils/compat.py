"""Version-compatibility shims for the jax API surface we depend on.

The repo targets the modern jax API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``, ``pallas.tpu.CompilerParams``) but
must also run on the 0.4.x toolchain baked into the CI image, where those
spell ``jax.experimental.shard_map.shard_map(check_rep=...)``,
``jax.make_mesh`` without axis types, and ``TPUCompilerParams``. Every
call site goes through these helpers instead of feature-testing inline.
"""
from __future__ import annotations

import inspect
from collections.abc import Sequence
from typing import Any

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Any:
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(axis_names),
            )
        except TypeError:  # make_mesh predates axis_types
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f: Any, mesh: Any, in_specs: Any, out_specs: Any, check: bool = True) -> Any:
    """``jax.shard_map``; ``check`` maps to check_vma / check_rep."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    flag = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{flag: check})


def pallas_tpu_compiler_params(**kwargs: Any) -> Any:
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
