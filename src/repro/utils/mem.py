"""Byte-exact peak-memory accounting for the streaming benchmarks.

The paper's Table III reports host peak-RSS under three transmission
settings. RSS is machine/allocator dependent, so the framework instruments
the *transmission buffers themselves*: every buffer the message layer
allocates registers its size with the active :class:`MemoryMeter`, which
tracks live bytes and the high-water mark. This reproduces the paper's
mechanism (regular = whole blob live, container = one item live, file =
one chunk live) deterministically.

An optional RSS probe (``/proc/self/status`` VmHWM) is included for the
benchmark's "measured" column when running the real simulation.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from collections.abc import Iterator
from typing import Optional


class MemoryMeter:
    """Tracks live transmission-buffer bytes and the peak.

    Besides the live/peak pair, two cumulative counters feed the
    zero-copy wire benchmarks: ``total_allocated`` sums every buffer the
    wire layer registered (how many times data got a new home), and
    ``copied`` sums the bytes the layer physically memcpy'd (joins,
    ``tobytes()`` exports, reassembly fills). A scatter-gather transfer
    moves the same wire bytes with a fraction of both.

    Thread-safe: the async runtime's worker threads stream concurrently,
    so ``alloc``/``free``/``hold`` all serialize on a per-instance lock
    (per-instance so independent meters don't contend).
    """

    _active: Optional[MemoryMeter] = None

    def __init__(self) -> None:
        self.live = 0
        self.peak = 0
        self.total_allocated = 0
        self.copied = 0
        self._lock = threading.Lock()

    # -- accounting -------------------------------------------------------
    def alloc(self, nbytes: int) -> None:
        with self._lock:
            self.live += int(nbytes)
            self.total_allocated += int(nbytes)
            if self.live > self.peak:
                self.peak = self.live

    def free(self, nbytes: int) -> None:
        with self._lock:
            self.live = max(0, self.live - int(nbytes))

    def copy(self, nbytes: int) -> None:
        with self._lock:
            self.copied += int(nbytes)

    @contextmanager
    def hold(self, nbytes: int) -> Iterator[None]:
        self.alloc(nbytes)
        try:
            yield
        finally:
            self.free(nbytes)

    def as_dict(self) -> dict:
        """JSON-safe export (the metrics-snapshot schema)."""
        with self._lock:
            return {
                "live": self.live,
                "peak": self.peak,
                "total_allocated": self.total_allocated,
                "copied": self.copied,
            }

    # -- active-meter plumbing --------------------------------------------
    @classmethod
    def current(cls) -> Optional[MemoryMeter]:
        return cls._active

    @contextmanager
    def activate(self) -> Iterator[MemoryMeter]:
        prev = MemoryMeter._active
        MemoryMeter._active = self
        try:
            yield self
        finally:
            MemoryMeter._active = prev


def record_alloc(nbytes: int) -> None:
    meter = MemoryMeter.current()
    if meter is not None:
        meter.alloc(nbytes)


def record_free(nbytes: int) -> None:
    meter = MemoryMeter.current()
    if meter is not None:
        meter.free(nbytes)


def record_copy(nbytes: int) -> None:
    """One physical byte-copy performed by the wire layer (join,
    ``tobytes`` export, receive-buffer fill)."""
    meter = MemoryMeter.current()
    if meter is not None:
        meter.copy(nbytes)


@contextmanager
def record_hold(nbytes: int) -> Iterator[None]:
    meter = MemoryMeter.current()
    if meter is None:
        yield
    else:
        with meter.hold(nbytes):
            yield


def rss_peak_kb() -> Optional[int]:
    """VmHWM from /proc, if available (Linux)."""
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmHWM"):
                    return int(line.split()[1])
    except OSError:
        return None
    return None
