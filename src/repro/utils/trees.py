"""Pytree / state-dict utilities shared across the framework.

The FL message layer works on *state dicts* — flat ``{name: array}``
mappings, the JAX analogue of a torch ``state_dict`` and the unit of
transmission in the paper (one dict item == one "layer" for container
streaming). Models internally use nested pytrees; these helpers convert
between the two and provide byte/param accounting used by the Table II/III
benchmarks.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Any

import jax
import numpy as np

SEP = "."


def tree_bytes(tree: Any) -> int:
    """Total payload bytes of every leaf array in ``tree``."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    return sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree) if hasattr(l, "shape")
    )


def flatten_state_dict(tree: Any, prefix: str = "") -> dict[str, Any]:
    """Flatten a nested dict/pytree of arrays to ``{dotted.name: array}``.

    Ordering is deterministic (sorted at each level) so that sender and
    receiver agree on the container-streaming item order without
    negotiation.
    """
    out: dict[str, Any] = {}

    def rec(node: Any, path: str) -> None:
        if isinstance(node, Mapping):
            for key in sorted(node.keys()):
                sub = f"{path}{SEP}{key}" if path else str(key)
                rec(node[key], sub)
        elif isinstance(node, (list, tuple)):
            for i, item in enumerate(node):
                sub = f"{path}{SEP}{i}" if path else str(i)
                rec(item, sub)
        else:
            out[path if path else "_"] = node

    rec(tree, prefix)
    return out


def unflatten_state_dict(flat: Mapping[str, Any]) -> dict[str, Any]:
    """Inverse of :func:`flatten_state_dict` (lists come back as dicts of

    int-keyed entries converted to lists when keys are contiguous ints).
    """
    nested: dict[str, Any] = {}
    for name, value in flat.items():
        parts = name.split(SEP)
        node = nested
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = value

    def fix_lists(node: Any) -> Any:
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            idx = sorted(int(k) for k in keys)
            if idx == list(range(len(idx))):
                return [fix_lists(node[str(i)]) for i in idx]
        return {k: fix_lists(v) for k, v in node.items()}

    return fix_lists(nested)
