from repro.utils.trees import (
    tree_bytes,
    tree_param_count,
    flatten_state_dict,
    unflatten_state_dict,
)
from repro.utils.mem import MemoryMeter

__all__ = [
    "tree_bytes",
    "tree_param_count",
    "flatten_state_dict",
    "unflatten_state_dict",
    "MemoryMeter",
]
