"""Federated data partitioners: IID and Dirichlet non-IID.

For the synthetic Markov corpus, "non-IID" means each client draws from a
different transition-table mode with Dirichlet-weighted mixture — the
standard label-skew analogue for LM streams.
"""
from __future__ import annotations


import numpy as np

from repro.data.pipeline import SyntheticLMDataset


def iid_partition(
    vocab_size: int, seq_len: int, num_clients: int, *, seed: int = 0
) -> list[SyntheticLMDataset]:
    """Every client samples the same chain (different streams)."""
    return [
        SyntheticLMDataset(vocab_size, seq_len, seed=seed, num_modes=1, mode=0)
        for _ in range(num_clients)
    ]


def dirichlet_partition(
    vocab_size: int,
    seq_len: int,
    num_clients: int,
    *,
    alpha: float = 0.5,
    num_modes: int = 4,
    seed: int = 0,
) -> list[SyntheticLMDataset]:
    """Each client's stream comes from a Dirichlet-sampled dominant mode."""
    rng = np.random.default_rng(seed)
    out = []
    for c in range(num_clients):
        weights = rng.dirichlet([alpha] * num_modes)
        mode = int(np.argmax(weights))
        out.append(
            SyntheticLMDataset(
                vocab_size, seq_len, seed=seed, num_modes=num_modes, mode=mode
            )
        )
    return out
