from repro.data.pipeline import SyntheticLMDataset, make_batch_iterator
from repro.data.partition import dirichlet_partition, iid_partition

__all__ = [
    "SyntheticLMDataset",
    "make_batch_iterator",
    "iid_partition",
    "dirichlet_partition",
]
