"""granite-8b — llama-arch dense code model [arXiv:2405.04324].

36L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 49152.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    source="arXiv:2405.04324",
)

SMOKE_OVERRIDES = dict(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512
)
