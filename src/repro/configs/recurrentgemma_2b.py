"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1:2 attn:rec

[arXiv:2402.19427].

26L (8 x (rec, rec, attn) super-blocks + 2 rec tail), d_model 2560,
10 heads x head_dim 256 (MQA kv=1), d_ff 7680, vocab 256000,
local window 2048, RG-LRU width 2560. O(window)/O(1) state -> native
long_500k decode.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    rglru_width=2560,
    source="arXiv:2402.19427",
)

SMOKE_OVERRIDES = dict(
    num_layers=3,
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    local_window=16,
    rglru_width=256,
)
