"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 10752, vocab 100352.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    source="hf:databricks/dbrx-base",
)

SMOKE_OVERRIDES = dict(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
)
