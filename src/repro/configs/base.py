"""Architecture config registry.

Every assigned architecture has one module in this package defining
``CONFIG`` (the exact full-scale spec, citing its source in
``ModelConfig.source``) and ``SMOKE_OVERRIDES`` (the reduced variant used
by CPU smoke tests: <=2-ish layers, d_model<=512, <=4 experts). Full
configs are exercised only via the dry-run (ShapeDtypeStruct — no
allocation).
"""
from __future__ import annotations

import importlib

from repro.models.base import ModelConfig

ARCH_IDS: list[str] = [
    "xlstm-125m",
    "stablelm-1.6b",
    "dbrx-132b",
    "whisper-small",
    "llama4-scout-17b-a16e",
    "qwen1.5-0.5b",
    "recurrentgemma-2b",
    "granite-8b",
    "phi-3-vision-4.2b",
    "qwen2.5-32b",
    # the paper's own experiment model
    "llama3.2-1b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MOD:
        raise KeyError(f"unknown arch {arch_id!r}; valid: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG.with_overrides(**mod.SMOKE_OVERRIDES)


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
