"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 layers = 6 (mLSTM, sLSTM) super-blocks, d_model 768, 4 heads,
d_ff 0 (the FFN lives inside the blocks: mLSTM up-factor 2, sLSTM 4/3),
vocab 50304. Recurrent O(1) state -> native long_500k decode.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    source="arXiv:2405.04517",
)

SMOKE_OVERRIDES = dict(num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, vocab_size=512)
