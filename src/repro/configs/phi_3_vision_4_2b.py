"""phi-3-vision-4.2b — phi3-mini decoder + CLIP vision (stub)

[hf:microsoft/Phi-3-vision-128k-instruct].

32L, d_model 3072, 32 heads (MHA), d_ff 8192, vocab 32064. The
ViT/projector frontend is a stub: input_specs provides 576 patch
embeddings that prefix the token sequence.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE_OVERRIDES = dict(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    num_patches=16,
)
