"""stablelm-1.6b — dense decoder [hf:stabilityai/stablelm-2-1_6b].

24L, d_model 2048, 32 heads (MHA: kv=32), d_ff 5632, vocab 100352.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE_OVERRIDES = dict(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512
)
