"""whisper-small — enc-dec audio backbone [arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model 768, 12 heads (MHA), d_ff 3072,
vocab 51865. Conv/mel frontend is a stub: input_specs provides 1500
frame embeddings (30 s at 50 Hz post-conv).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_seq=1500,
    use_rope=False,
    source="arXiv:2212.04356",
)

SMOKE_OVERRIDES = dict(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    encoder_layers=2,
    encoder_seq=32,
)
