"""llama4-scout-17b-a16e — MoE 16 experts top-1, early fusion

[hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model 5120, 40 heads (GQA kv=8), expert d_ff 8192, vocab 202048.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE_OVERRIDES = dict(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    num_experts=4,
    experts_per_token=1,
)
