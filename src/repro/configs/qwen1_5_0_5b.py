"""qwen1.5-0.5b — dense decoder with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L, d_model 1024, 16 heads (MHA), d_ff 2816, vocab 151936.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE_OVERRIDES = dict(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512
)
