"""llama3.2-1b — the paper's own experiment model (Table I/II)

[hf:meta-llama/Llama-3.2-1B].

16L, d_model 2048, 32 heads (GQA kv=8), d_ff 8192, vocab 128256.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    source="hf:meta-llama/Llama-3.2-1B",
)

SMOKE_OVERRIDES = dict(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512
)
