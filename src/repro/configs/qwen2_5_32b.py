"""qwen2.5-32b — dense decoder, GQA + QKV bias [hf:Qwen/Qwen2.5-32B;

config card cited in the assignment as hf:Qwen/Qwen2.5-0.5B].

64L, d_model 5120, 40 heads (GQA kv=8), d_ff 27648, vocab 152064.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B",
)

SMOKE_OVERRIDES = dict(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512
)
