"""AdamW with decoupled weight decay and global-norm clipping.

Implemented directly on pytrees (no optax dependency in this offline
environment). Optimizer state dtype follows the params — the dry-run
passes fp32 m/v for the memory analysis, matching mixed-precision
practice (bf16 params + fp32 optimizer state is configured by the
launcher via ``state_dtype``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params: Any, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    clipped = jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
    return clipped, gnorm


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
) -> tuple[Any, AdamWState, dict[str, jnp.ndarray]]:
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.float32(0.0)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    params_new = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return params_new, AdamWState(step, m_new, v_new), {"grad_norm": gnorm}
