"""NVFlare-style filter mechanism (paper §II-B) and the two-way

quantization workflow built on it (paper §II-C).

Filters transform messages at the four points of a federated round:

* ``TASK_DATA_OUT``    — before Task Data leaves the server
* ``TASK_DATA_IN``     — before clients accept Task Data
* ``TASK_RESULT_OUT``  — before Task Result leaves a client
* ``TASK_RESULT_IN``   — before the server accepts a Task Result

The two-way quantization scheme installs a :class:`QuantizeFilter` on both
*OUT* points and a :class:`DequantizeFilter` on both *IN* points, so every
message crosses the wire quantized while **training and aggregation always
see original precision** — the paper's key design point, and the reason no
training-script change is needed (swapping filter configs is enough).

.. deprecated:: the ``Filter``/``FilterChain`` surface is superseded by
   the registry-driven :class:`~repro.core.pipeline.WirePipeline`, whose
   stages run **per item inside the streaming loop** (peak transmission
   memory ~one item) instead of materializing the whole transformed
   payload up front, as every filter here must. Existing configurations
   keep working: the simulator adapts filter chains onto whole-message
   pipeline stages via
   :func:`~repro.core.pipeline.legacy_wire_pipelines`, with bitwise-
   identical results. New transforms should be written as registered
   pipeline stages (``@register_stage``), not filters.
"""
from __future__ import annotations

import enum
from collections.abc import Callable, Iterable
from typing import Any, Optional

import numpy as np

from repro.core.messages import Message
from repro.core.quantization import (
    QuantizedTensor,
    dequantize_state_dict,
    quantize_state_dict,
)


class FilterPoint(enum.Enum):
    TASK_DATA_OUT = "task_data_out"        # server egress
    TASK_DATA_IN = "task_data_in"          # client ingress
    TASK_RESULT_OUT = "task_result_out"    # client egress
    TASK_RESULT_IN = "task_result_in"      # server ingress


class Filter:
    """Message transform. Stateless unless documented otherwise."""

    def process(self, message: Message) -> Message:
        raise NotImplementedError


class FilterChain:
    def __init__(self, filters: Optional[Iterable[Filter]] = None) -> None:
        self.filters: list[Filter] = list(filters or [])

    def process(self, message: Message) -> Message:
        for f in self.filters:
            message = f.process(message)
        return message


class QuantizeFilter(Filter):
    """Quantize every float tensor in the payload to ``fmt``.

    Already-quantized items and small/integer tensors pass through
    unchanged (quantizing a 2-KiB layernorm saves nothing and the paper's
    bitsandbytes path equally skips non-float tensors).
    """

    def __init__(self, fmt: str, min_params: int = 0) -> None:
        self.fmt = fmt
        self.min_params = min_params

    def process(self, message: Message) -> Message:
        out: dict[str, Any] = {}
        for name, value in message.payload.items():
            if isinstance(value, QuantizedTensor):
                out[name] = value
                continue
            arr = np.asarray(value) if not hasattr(value, "dtype") else value
            if not np.issubdtype(np.asarray(arr).dtype, np.floating) or int(
                np.prod(arr.shape)
            ) < self.min_params:
                out[name] = value
                continue
            out[name] = quantize_state_dict({name: arr}, self.fmt)[name]
        msg = message.replace_payload(out)
        msg.headers["quantized_fmt"] = self.fmt
        return msg


class DequantizeFilter(Filter):
    """Recover original precision for every QuantizedTensor item."""

    def process(self, message: Message) -> Message:
        q = {n: v for n, v in message.payload.items() if isinstance(v, QuantizedTensor)}
        rest = {n: v for n, v in message.payload.items() if not isinstance(v, QuantizedTensor)}
        out = dict(rest)
        out.update(dequantize_state_dict(q))
        # preserve original insertion order
        ordered = {n: out[n] for n in message.payload.keys()}
        msg = message.replace_payload(ordered)
        msg.headers.pop("quantized_fmt", None)
        return msg


class DPGaussianNoiseFilter(Filter):
    """Gaussian-mechanism DP filter — demonstrates the paper's claim that

    quantization composes with privacy filters (§V): install it *before*
    the quantize filter on TASK_RESULT_OUT so noise is added at full
    precision, then quantized for the wire.
    """

    def __init__(self, sigma: float, seed: int = 0) -> None:
        self.sigma = sigma
        self._rng = np.random.default_rng(seed)

    def process(self, message: Message) -> Message:
        out: dict[str, Any] = {}
        for name, value in message.payload.items():
            if isinstance(value, QuantizedTensor) or not np.issubdtype(
                np.asarray(value).dtype, np.floating
            ):
                out[name] = value
            else:
                arr = np.asarray(value)
                out[name] = arr + self._rng.normal(0.0, self.sigma, arr.shape).astype(arr.dtype)
        return message.replace_payload(out)


class SelectiveQuantizeFilter(Filter):
    """Per-layer precision policy (paper §V "per-layer sensitivity"):

    a list of (substring, fmt) rules decides each tensor's format; first
    match wins; ``default_fmt`` covers the rest; fmt None = keep fp32.
    E.g. keep norms/embeddings at fp16 while the bulk goes nf4.
    """

    def __init__(self, rules, default_fmt: str = "nf4", min_params: int = 0) -> None:
        self.rules = list(rules)
        self.default_fmt = default_fmt
        self.min_params = min_params

    def _fmt_for(self, name: str) -> Optional[str]:
        for substr, fmt in self.rules:
            if substr in name:
                return fmt
        return self.default_fmt

    def process(self, message: Message) -> Message:
        out: dict[str, Any] = {}
        fmts = set()
        for name, value in message.payload.items():
            arr = np.asarray(value)
            fmt = self._fmt_for(name)
            if (
                isinstance(value, QuantizedTensor)
                or fmt is None
                or not np.issubdtype(arr.dtype, np.floating)
                or int(np.prod(arr.shape)) < self.min_params
            ):
                out[name] = value
                continue
            out[name] = quantize_state_dict({name: arr}, fmt)[name]
            fmts.add(fmt)
        msg = message.replace_payload(out)
        msg.headers["quantized_fmt"] = "mixed:" + ",".join(sorted(fmts))
        return msg


class ErrorFeedbackQuantizeFilter(Filter):
    """Quantize with **error feedback** (the paper's §V future work,

    implemented): the filter keeps the per-tensor quantization residual
    e_t and transmits Q(x_t + e_{t-1}), so errors accumulate toward zero
    over rounds instead of compounding — the EF-SGD/EF21 mechanism. At
    aggressive 4-bit precision this removes the steady-state error floor
    of plain quantization (see tests/test_error_feedback.py).

    Stateful: one filter instance per site per direction.
    """

    def __init__(self, fmt: str, min_params: int = 0) -> None:
        self.fmt = fmt
        self.min_params = min_params
        self._residual: dict[str, np.ndarray] = {}

    def process(self, message: Message) -> Message:
        out: dict[str, Any] = {}
        for name, value in message.payload.items():
            if isinstance(value, QuantizedTensor) or not np.issubdtype(
                np.asarray(value).dtype, np.floating
            ) or int(np.prod(np.asarray(value).shape)) < self.min_params:
                out[name] = value
                continue
            arr = np.asarray(value, np.float32)
            corrected = arr + self._residual.get(name, 0.0)
            qt = quantize_state_dict({name: corrected}, self.fmt)[name]
            deq = np.asarray(dequantize_state_dict({name: qt})[name], np.float32)
            self._residual[name] = corrected - deq
            out[name] = qt
        msg = message.replace_payload(out)
        msg.headers["quantized_fmt"] = self.fmt
        msg.headers["error_feedback"] = True
        return msg


def _network_link_fn(network: Any) -> Callable[[str], float]:
    """client -> bits/s from a NetworkModel (or anything link()-shaped)."""
    fn = getattr(network, "bandwidth_bps", None)
    if callable(fn):
        return fn
    return lambda client: network.link(client).bandwidth_mbps * 1e6


class AdaptiveQuantizeFilter(Filter):
    """Bandwidth-adaptive precision (paper §V: "adaptive ... mechanisms

    based on network conditions"): picks the cheapest format whose
    estimated transfer time fits the round's bandwidth budget, falling
    back toward fp32 when the link is fast enough to afford fidelity.

    Two bandwidth sources, checked in order:

    * ``link_fn(client) -> bits/s`` — a **per-client** hook, resolved from
      the message's ``client`` header at process time. Wire it to the
      async runtime's per-client link model with :meth:`bind_network`:
      slow links (3G, satellite) then automatically ship 8-bit/NF4 while
      fast links (fiber) afford fp16/fp32 — precision tracks the
      simulated network, per client, with no job-script change.
    * ``bandwidth_bps`` — a fleet-wide constant, the original behaviour
      and the fallback for messages without a ``client`` header.

    ``last_fmt_by_client`` records the most recent per-client decision
    (key ``""`` for unattributed messages) for tests and benchmarks.
    """

    LADDER = ("fp32", "fp16", "blockwise8", "nf4")
    BITS = {"fp32": 32, "fp16": 16, "blockwise8": 8 + 32 / 4096, "nf4": 4 + 32 / 64}

    def __init__(
        self,
        bandwidth_bps: Optional[float] = None,
        budget_s: float = 1.0,
        min_params: int = 0,
        link_fn: Optional[Callable[[str], float]] = None,
    ) -> None:
        if bandwidth_bps is None and link_fn is None:
            raise ValueError("need bandwidth_bps, link_fn, or bind_network()")
        self.bandwidth_bps = bandwidth_bps
        self.budget_s = budget_s
        self.min_params = min_params
        self.link_fn = link_fn
        self.last_fmt: Optional[str] = None
        self.last_fmt_by_client: dict[str, str] = {}

    @classmethod
    def from_network(
        cls, network: Any, budget_s: float = 1.0, min_params: int = 0
    ) -> AdaptiveQuantizeFilter:
        """Link-aware construction from a runtime NetworkModel. The
        filter has no fleet-wide fallback, so a message without a
        ``client`` header raises rather than guessing a bandwidth."""
        return cls(budget_s=budget_s, min_params=min_params,
                   link_fn=_network_link_fn(network))

    def bind_network(self, network: Any) -> None:
        """Feed per-client bandwidth from ``network.link(client)`` — any
        object with that method returning a LinkProfile-like (e.g.
        :class:`repro.runtime.network.NetworkModel`)."""
        self.link_fn = _network_link_fn(network)

    def _bandwidth_for(self, client: Optional[str]) -> float:
        if self.link_fn is not None and client:
            return float(self.link_fn(client))
        if self.bandwidth_bps is None:
            raise ValueError(
                "AdaptiveQuantizeFilter has only a per-client link_fn but the "
                "message carries no 'client' header; set bandwidth_bps as fallback"
            )
        return self.bandwidth_bps

    def _payload_bits(self, message: Message, fmt: str) -> float:
        n = sum(
            int(np.prod(np.asarray(v).shape))
            for v in message.payload.values()
            if not isinstance(v, QuantizedTensor)
            and np.issubdtype(np.asarray(v).dtype, np.floating)
        )
        return n * self.BITS[fmt]

    def fmt_for(self, message: Message) -> str:
        """The precision this filter would pick for ``message`` (pure).

        ``bandwidth_bps``/``link_fn`` are true bits-per-second, matching
        :class:`~repro.runtime.network.LinkProfile` semantics."""
        bandwidth = self._bandwidth_for(message.headers.get("client"))
        for cand in self.LADDER:
            if self._payload_bits(message, cand) / bandwidth <= self.budget_s:
                return cand
        return self.LADDER[-1]

    def process(self, message: Message) -> Message:
        fmt = self.fmt_for(message)
        self.last_fmt = fmt
        self.last_fmt_by_client[str(message.headers.get("client", ""))] = fmt
        if fmt == "fp32":
            return message
        return QuantizeFilter(fmt, self.min_params).process(message)


def two_way_quantization(fmt: str) -> dict[FilterPoint, FilterChain]:
    """The paper's §II-C scheme: quantize on both egress points,

    dequantize on both ingress points."""
    return {
        FilterPoint.TASK_DATA_OUT: FilterChain([QuantizeFilter(fmt)]),
        FilterPoint.TASK_DATA_IN: FilterChain([DequantizeFilter()]),
        FilterPoint.TASK_RESULT_OUT: FilterChain([QuantizeFilter(fmt)]),
        FilterPoint.TASK_RESULT_IN: FilterChain([DequantizeFilter()]),
    }


def no_filters() -> dict[FilterPoint, FilterChain]:
    return {p: FilterChain() for p in FilterPoint}
