"""Operational resilience for the streaming layer (paper §I: "potential

job disruptions due to network interruptions"; §V: "evaluation of
operational resilience for the streaming mechanism").

Components:

* :class:`LossyDriver` — fault-injection wrapper for any driver: seeded
  random chunk drop / duplication / reordering (the WAN misbehaviours an
  FL deployment sees).
* :class:`OrderedDeliveryBuffer` — receiver-side sequencer: deduplicates
  and releases chunks to the real receiver strictly in ``seq`` order, and
  reports the missing-seq set.
* :class:`ReliableTransfer` — sender-side repair loop: records framed
  chunks, transmits through the (possibly lossy) driver, then
  retransmits whatever the receiver reports missing until the stream
  completes (NVFlare's resend-on-gap, pull-based) or retries exhaust.
* :class:`ChaosProxy` — the live-plane sibling of :class:`LossyDriver`:
  a TCP forwarder that injects deterministic byte-offset-triggered
  faults (stall / blackhole / corrupt / throttle) between one real
  client and the federation server, so the fault-tolerance layer is
  tested against real sockets, not simulated drivers.

Works with every streamer/receiver pair unchanged — resilience is a
transport concern, invisible to the container/file layers above
(the SFM layering claim of the paper). The simulator wire composes these
end-to-end: set ``chunk_drop_prob``/``chunk_dup_prob``/
``chunk_reorder_window`` on :class:`~repro.fl.simulator.SimulationConfig`
and every hop runs through LossyDriver + ReliableTransfer, with
retransmitted chunks counted into the true wire bytes that drive the
async runtime's simulated transfer time.
"""
from __future__ import annotations

import contextlib
import random
import socket
import threading
import time
from collections.abc import Callable, Mapping
from typing import Any, Optional

from repro.core import streaming as sm


class LossyDriver(sm.Driver):
    """Randomly drops, duplicates and reorders chunks (seeded)."""

    def __init__(
        self,
        inner: sm.Driver,
        *,
        drop_prob: float = 0.0,
        dup_prob: float = 0.0,
        reorder_window: int = 0,
        seed: int = 0,
    ) -> None:
        self.inner = inner
        self.drop_prob = drop_prob
        self.dup_prob = dup_prob
        self.reorder_window = reorder_window
        self._rng = random.Random(seed)
        self._pending: list[sm.Chunk] = []

    def connect(self, on_chunk: Callable[[sm.Chunk], None]) -> None:
        self.inner.connect(on_chunk)

    def _emit(self, chunk: sm.Chunk) -> None:
        if self._rng.random() < self.drop_prob:
            return
        self.inner.send(chunk)
        if self._rng.random() < self.dup_prob:
            self.inner.send(chunk)

    def send(self, chunk: sm.Chunk) -> None:
        if self.reorder_window > 0:
            self._pending.append(chunk)
            if len(self._pending) >= self.reorder_window:
                self._rng.shuffle(self._pending)
                for c in self._pending:
                    self._emit(c)
                self._pending.clear()
        else:
            self._emit(chunk)

    def flush(self) -> None:
        for c in self._pending:
            self._emit(c)
        self._pending.clear()
        if hasattr(self.inner, "flush"):
            self.inner.flush()

    def close(self) -> None:
        self.flush()
        self.inner.close()


class OrderedDeliveryBuffer:
    """Dedup + in-order release to the wrapped receiver callback."""

    def __init__(self, on_chunk: Callable[[sm.Chunk], None]) -> None:
        self._on_chunk = on_chunk
        self._buffer: dict[int, sm.Chunk] = {}
        self._next_seq = 0
        self._eof_seq: Optional[int] = None
        self.complete = False

    def on_chunk(self, chunk: sm.Chunk) -> None:
        if chunk.seq < self._next_seq or chunk.seq in self._buffer:
            return  # duplicate
        self._buffer[chunk.seq] = chunk
        if chunk.eof:
            self._eof_seq = chunk.seq
        while self._next_seq in self._buffer:
            c = self._buffer.pop(self._next_seq)
            self._on_chunk(c)
            self._next_seq += 1
        if self._eof_seq is not None and self._next_seq > self._eof_seq:
            self.complete = True

    def missing(self) -> set[int]:
        """Known gaps below the highest seq seen (or below eof)."""
        high = self._eof_seq if self._eof_seq is not None else (
            max(self._buffer) if self._buffer else self._next_seq - 1
        )
        return {
            s for s in range(self._next_seq, high + 1) if s not in self._buffer
        }


class ReliableTransfer:
    """Record-and-repair send of one container/blob/file stream."""

    def __init__(self, driver: sm.Driver, chunk_size: int = sm.DEFAULT_CHUNK_SIZE) -> None:
        self.driver = driver
        self.chunk_size = chunk_size
        self.retransmits = 0

    def _send(self, stream_fn: Callable[[sm.Driver], None], receiver, max_rounds: int) -> bool:
        """Stream through a recording wrapper, then repair gaps the
        receiver-side :class:`OrderedDeliveryBuffer` reports until the
        stream completes or ``max_rounds`` retransmission rounds pass.
        Returns True when the receiver's stream completed."""
        sent: dict[int, sm.Chunk] = {}
        buffer = OrderedDeliveryBuffer(receiver.on_chunk)

        class _Recording(sm.Driver):
            def __init__(self, inner: sm.Driver) -> None:
                self.inner = inner

            def connect(self, cb):  # pragma: no cover - wired below
                self.inner.connect(cb)

            def send(self, chunk: sm.Chunk) -> None:
                sent[chunk.seq] = chunk
                self.inner.send(chunk)

        self.driver.connect(buffer.on_chunk)
        stream_fn(_Recording(self.driver))
        if hasattr(self.driver, "flush"):
            self.driver.flush()

        rounds = 0
        while not buffer.complete and rounds < max_rounds:
            gaps = buffer.missing()
            if not gaps and buffer._eof_seq is None:
                # eof itself was lost: resend the tail
                gaps = {max(sent)}
            for seq in sorted(gaps):
                self.driver.send(sent[seq])
                self.retransmits += 1
            if hasattr(self.driver, "flush"):
                self.driver.flush()
            rounds += 1
        return buffer.complete

    def send_container(
        self,
        sd,
        receiver,
        *,
        mode: str = "container",
        max_rounds: int = 20,
    ) -> bool:
        """Returns True when the receiver's stream completed."""
        if mode == "container":
            fn = lambda d: sm.ContainerStreamer(d, self.chunk_size).send_container(sd)
        else:
            fn = lambda d: sm.ObjectStreamer(d, self.chunk_size).send_container(sd)
        return self._send(fn, receiver, max_rounds)

    def send_items(self, items, total: int, receiver, *, max_rounds: int = 20) -> bool:
        """Container-mode send of pre-encoded (name, bytes) items — the
        wire-pipeline path: stage transforms ran upstream, per item."""
        return self._send(
            lambda d: sm.ContainerStreamer(d, self.chunk_size).send_items(items, total),
            receiver, max_rounds,
        )

    def send_blob(self, blob: bytes, receiver, *, max_rounds: int = 20) -> bool:
        """Regular-mode send of one pre-encoded blob."""
        return self._send(
            lambda d: sm.ObjectStreamer(d, self.chunk_size).send_blob(blob),
            receiver, max_rounds,
        )


class ChaosProxy:
    """Deterministic TCP fault injector between one client and a server.

    Listens on its own port and forwards every accepted connection to
    ``target``, two pump threads per connection (one per direction).
    The fault ``plan`` triggers at an exact byte offset of the faulted
    direction's stream, so a given (plan, traffic) pair always fails at
    the same protocol position — chaos tests are reproducible, and a
    seeded offset (``plan["seed"]`` when ``after_bytes`` is omitted) is
    still a pure function of the plan:

    * ``{"kind": "stall", "after_bytes": N, "stall_s": S}`` — stop
      forwarding the faulted direction for ``S`` seconds at offset ``N``
      (the other direction keeps flowing), then resume losslessly: a
      straggler, not a crash.
    * ``{"kind": "blackhole", "after_bytes": N}`` — forward ``N`` bytes,
      then drop both sockets: the mid-stream death a flaky link causes.
    * ``{"kind": "corrupt", "after_bytes": N, "xor": M}`` — flip the
      byte at offset ``N`` (XOR with ``M``, default 0xFF) and keep
      forwarding: framing survives, payload integrity does not — the
      receiver's crc32/decode stage must catch it.
    * ``{"kind": "throttle", "after_bytes": N, "bps": R}`` — pace the
      faulted direction at ``R`` bytes/second from offset ``N`` on.

    ``direction`` selects the counted stream (``"up"`` = client→server,
    the default; ``"down"`` = server→client). ``triggers`` (default 1)
    arms the fault on that many connections; later connections through
    the same proxy forward untouched, so a client reconnecting after a
    blackhole lands on a clean path — exactly the transient-fault shape
    reconnect-with-backoff must survive.
    """

    def __init__(self, target: tuple, plan: Optional[Mapping[str, Any]] = None,
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self.target = (str(target[0]), int(target[1]))
        self.plan = dict(plan or {})
        if self.plan and "after_bytes" not in self.plan:
            self.plan["after_bytes"] = random.Random(
                int(self.plan.get("seed", 0))).randrange(1 << 10, 1 << 16)
        self._srv = socket.create_server((host, port))
        self.address = self._srv.getsockname()
        self._accept_thread: Optional[threading.Thread] = None
        self._threads: list[threading.Thread] = []
        self._socks: list[socket.socket] = []
        self._lock = threading.Lock()
        self._closing = False
        self.connections = 0
        self.triggered = 0

    def start(self) -> "ChaosProxy":
        if self._accept_thread is not None:
            raise RuntimeError("start() already called")
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"chaos-accept-{self.address[1]}")
        self._accept_thread.start()
        return self

    def _accept_loop(self) -> None:
        while True:
            try:
                csock, _peer = self._srv.accept()
            except OSError:
                return  # listener closed — clean shutdown
            if self._closing:
                csock.close()
                return
            try:
                ssock = socket.create_connection(self.target)
            except OSError:
                csock.close()
                continue
            with self._lock:
                self.connections += 1
                armed = bool(self.plan) and \
                    self.connections <= int(self.plan.get("triggers", 1))
                if armed:
                    self.triggered += 1
                self._socks += [csock, ssock]
            faulted = self.plan.get("direction", "up")
            for src, dst, tag in ((csock, ssock, "up"), (ssock, csock, "down")):
                plan = self.plan if armed and tag == faulted else None
                t = threading.Thread(
                    target=self._pump, args=(src, dst, plan), daemon=True,
                    name=f"chaos-{tag}-{self.address[1]}")
                t.start()
                with self._lock:
                    self._threads.append(t)

    def _pump(self, src: socket.socket, dst: socket.socket,
              plan: Optional[Mapping[str, Any]]) -> None:
        kind = (plan or {}).get("kind")
        after = int((plan or {}).get("after_bytes", 0))
        seen = 0
        fired = False
        kill = False
        try:
            while True:
                data = src.recv(1 << 16)
                if not data:
                    break
                if kind and not fired and seen + len(data) > after:
                    cut = after - seen  # bytes before the fault offset
                    fired = True
                    if kind == "stall":
                        if cut:
                            dst.sendall(data[:cut])
                        time.sleep(float(plan.get("stall_s", 1.0)))
                        dst.sendall(data[cut:])
                    elif kind == "blackhole":
                        if cut:
                            dst.sendall(data[:cut])
                        kill = True
                        break
                    elif kind == "corrupt":
                        flipped = bytearray(data)
                        flipped[cut] ^= int(plan.get("xor", 0xFF)) or 0xFF
                        dst.sendall(bytes(flipped))
                    else:  # throttle: pacing starts at the offset
                        dst.sendall(data)
                        time.sleep(len(data) / float(plan.get("bps", 1e6)))
                elif kind == "throttle" and fired:
                    dst.sendall(data)
                    time.sleep(len(data) / float(plan.get("bps", 1e6)))
                else:
                    dst.sendall(data)
                seen += len(data)
        except OSError:
            kill = True
        if kill:
            # shutdown before close: a plain close is deferred while the
            # opposite pump blocks in recv on the same socket (CPython
            # holds the fd open), so no FIN would reach either peer and
            # the "dead" link would hang everyone until their timeouts.
            # shutdown() takes effect immediately.
            for s in (src, dst):
                with contextlib.suppress(OSError):
                    s.shutdown(socket.SHUT_RDWR)
                with contextlib.suppress(OSError):
                    s.close()
        else:
            # clean EOF: half-close downstream so the opposite pump can
            # keep forwarding until its own side ends
            with contextlib.suppress(OSError):
                dst.shutdown(socket.SHUT_WR)

    def close(self) -> None:
        self._closing = True
        try:
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            with contextlib.suppress(OSError):
                socket.create_connection(self.address, timeout=1).close()
        self._srv.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
        with self._lock:
            socks, threads = list(self._socks), list(self._threads)
        for s in socks:
            with contextlib.suppress(OSError):
                s.shutdown(socket.SHUT_RDWR)  # wake pumps blocked in recv
            with contextlib.suppress(OSError):
                s.close()
        for t in threads:
            t.join(timeout=5)
