"""Operational resilience for the streaming layer (paper §I: "potential

job disruptions due to network interruptions"; §V: "evaluation of
operational resilience for the streaming mechanism").

Components:

* :class:`LossyDriver` — fault-injection wrapper for any driver: seeded
  random chunk drop / duplication / reordering (the WAN misbehaviours an
  FL deployment sees).
* :class:`OrderedDeliveryBuffer` — receiver-side sequencer: deduplicates
  and releases chunks to the real receiver strictly in ``seq`` order, and
  reports the missing-seq set.
* :class:`ReliableTransfer` — sender-side repair loop: records framed
  chunks, transmits through the (possibly lossy) driver, then
  retransmits whatever the receiver reports missing until the stream
  completes (NVFlare's resend-on-gap, pull-based) or retries exhaust.

Works with every streamer/receiver pair unchanged — resilience is a
transport concern, invisible to the container/file layers above
(the SFM layering claim of the paper). The simulator wire composes these
end-to-end: set ``chunk_drop_prob``/``chunk_dup_prob``/
``chunk_reorder_window`` on :class:`~repro.fl.simulator.SimulationConfig`
and every hop runs through LossyDriver + ReliableTransfer, with
retransmitted chunks counted into the true wire bytes that drive the
async runtime's simulated transfer time.
"""
from __future__ import annotations

import random
from collections.abc import Callable
from typing import Optional

from repro.core import streaming as sm


class LossyDriver(sm.Driver):
    """Randomly drops, duplicates and reorders chunks (seeded)."""

    def __init__(
        self,
        inner: sm.Driver,
        *,
        drop_prob: float = 0.0,
        dup_prob: float = 0.0,
        reorder_window: int = 0,
        seed: int = 0,
    ) -> None:
        self.inner = inner
        self.drop_prob = drop_prob
        self.dup_prob = dup_prob
        self.reorder_window = reorder_window
        self._rng = random.Random(seed)
        self._pending: list[sm.Chunk] = []

    def connect(self, on_chunk: Callable[[sm.Chunk], None]) -> None:
        self.inner.connect(on_chunk)

    def _emit(self, chunk: sm.Chunk) -> None:
        if self._rng.random() < self.drop_prob:
            return
        self.inner.send(chunk)
        if self._rng.random() < self.dup_prob:
            self.inner.send(chunk)

    def send(self, chunk: sm.Chunk) -> None:
        if self.reorder_window > 0:
            self._pending.append(chunk)
            if len(self._pending) >= self.reorder_window:
                self._rng.shuffle(self._pending)
                for c in self._pending:
                    self._emit(c)
                self._pending.clear()
        else:
            self._emit(chunk)

    def flush(self) -> None:
        for c in self._pending:
            self._emit(c)
        self._pending.clear()
        if hasattr(self.inner, "flush"):
            self.inner.flush()

    def close(self) -> None:
        self.flush()
        self.inner.close()


class OrderedDeliveryBuffer:
    """Dedup + in-order release to the wrapped receiver callback."""

    def __init__(self, on_chunk: Callable[[sm.Chunk], None]) -> None:
        self._on_chunk = on_chunk
        self._buffer: dict[int, sm.Chunk] = {}
        self._next_seq = 0
        self._eof_seq: Optional[int] = None
        self.complete = False

    def on_chunk(self, chunk: sm.Chunk) -> None:
        if chunk.seq < self._next_seq or chunk.seq in self._buffer:
            return  # duplicate
        self._buffer[chunk.seq] = chunk
        if chunk.eof:
            self._eof_seq = chunk.seq
        while self._next_seq in self._buffer:
            c = self._buffer.pop(self._next_seq)
            self._on_chunk(c)
            self._next_seq += 1
        if self._eof_seq is not None and self._next_seq > self._eof_seq:
            self.complete = True

    def missing(self) -> set[int]:
        """Known gaps below the highest seq seen (or below eof)."""
        high = self._eof_seq if self._eof_seq is not None else (
            max(self._buffer) if self._buffer else self._next_seq - 1
        )
        return {
            s for s in range(self._next_seq, high + 1) if s not in self._buffer
        }


class ReliableTransfer:
    """Record-and-repair send of one container/blob/file stream."""

    def __init__(self, driver: sm.Driver, chunk_size: int = sm.DEFAULT_CHUNK_SIZE) -> None:
        self.driver = driver
        self.chunk_size = chunk_size
        self.retransmits = 0

    def _send(self, stream_fn: Callable[[sm.Driver], None], receiver, max_rounds: int) -> bool:
        """Stream through a recording wrapper, then repair gaps the
        receiver-side :class:`OrderedDeliveryBuffer` reports until the
        stream completes or ``max_rounds`` retransmission rounds pass.
        Returns True when the receiver's stream completed."""
        sent: dict[int, sm.Chunk] = {}
        buffer = OrderedDeliveryBuffer(receiver.on_chunk)

        class _Recording(sm.Driver):
            def __init__(self, inner: sm.Driver) -> None:
                self.inner = inner

            def connect(self, cb):  # pragma: no cover - wired below
                self.inner.connect(cb)

            def send(self, chunk: sm.Chunk) -> None:
                sent[chunk.seq] = chunk
                self.inner.send(chunk)

        self.driver.connect(buffer.on_chunk)
        stream_fn(_Recording(self.driver))
        if hasattr(self.driver, "flush"):
            self.driver.flush()

        rounds = 0
        while not buffer.complete and rounds < max_rounds:
            gaps = buffer.missing()
            if not gaps and buffer._eof_seq is None:
                # eof itself was lost: resend the tail
                gaps = {max(sent)}
            for seq in sorted(gaps):
                self.driver.send(sent[seq])
                self.retransmits += 1
            if hasattr(self.driver, "flush"):
                self.driver.flush()
            rounds += 1
        return buffer.complete

    def send_container(
        self,
        sd,
        receiver,
        *,
        mode: str = "container",
        max_rounds: int = 20,
    ) -> bool:
        """Returns True when the receiver's stream completed."""
        if mode == "container":
            fn = lambda d: sm.ContainerStreamer(d, self.chunk_size).send_container(sd)
        else:
            fn = lambda d: sm.ObjectStreamer(d, self.chunk_size).send_container(sd)
        return self._send(fn, receiver, max_rounds)

    def send_items(self, items, total: int, receiver, *, max_rounds: int = 20) -> bool:
        """Container-mode send of pre-encoded (name, bytes) items — the
        wire-pipeline path: stage transforms ran upstream, per item."""
        return self._send(
            lambda d: sm.ContainerStreamer(d, self.chunk_size).send_items(items, total),
            receiver, max_rounds,
        )

    def send_blob(self, blob: bytes, receiver, *, max_rounds: int = 20) -> bool:
        """Regular-mode send of one pre-encoded blob."""
        return self._send(
            lambda d: sm.ObjectStreamer(d, self.chunk_size).send_blob(blob),
            receiver, max_rounds,
        )
