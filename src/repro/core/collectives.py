"""Quantized / bucketed cross-pod collectives — the paper's two techniques

mapped onto the TPU mesh (DESIGN.md §3):

* **message quantization -> low-precision collectives**: model updates are
  blockwise-int8 quantized *before* crossing the ``pod`` (federation)
  axis; each pod dequantizes and aggregates at fp32 — exactly the paper's
  two-way scheme (quantize on egress, dequantize on ingress, aggregate at
  original precision). For P pods, the ICI wire cost of the round drops
  from 2*4*N*(P-1)/P bytes/device (fp32 ring all-reduce) to
  ~N*(P-1) bytes/device (int8 all-gather + local reduce): 4x at P=2,
  plus a 1/1024 absmax overhead.

* **streaming -> bucketed collectives**: the flattened update is processed
  in fixed-size buckets so the live communication buffer is bounded by
  the bucket size, not the model size — the container-streaming analogue.

These run inside ``jax.shard_map`` over the ``pod`` axis; the inner
(data/model) axes stay under GSPMD via ``auto``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as KREF

BLOCK = KREF.BLOCK8


def _flatten_tree(tree: Any) -> tuple[jnp.ndarray, Any, list]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (treedef, [l.shape for l in leaves], [l.dtype for l in leaves]), sizes


def _unflatten_tree(flat: jnp.ndarray, meta: Any, sizes: list) -> Any:
    treedef, shapes, dtypes = meta
    leaves = []
    off = 0
    for shape, dtype, size in zip(shapes, dtypes, sizes):
        leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _quantize_flat(flat: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    n = flat.shape[0]
    padded = int(np.ceil(n / BLOCK)) * BLOCK
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return KREF.quantize_blockwise8(flat.reshape(-1, BLOCK))


def quantized_pod_mean(flat: jnp.ndarray, axis_name: str = "pod") -> jnp.ndarray:
    """Mean of a flat fp32 vector across the pod axis with int8 wire format.

    Egress: blockwise-int8 quantize. Wire: all_gather of (codes, absmax).
    Ingress: dequantize each pod's payload and average at fp32 (paper's
    aggregation-at-original-precision).
    """
    n = flat.shape[0]
    q, absmax = _quantize_flat(flat)
    q_all = jax.lax.all_gather(q, axis_name)            # (P, nblocks, BLOCK) int8
    am_all = jax.lax.all_gather(absmax, axis_name)      # (P, nblocks)
    P = q_all.shape[0]
    w = jnp.full((P,), 1.0 / P, jnp.float32)
    out = KREF.dequant_accumulate8(q_all, am_all, w)    # fused dequant+avg
    return out.reshape(-1)[:n]


def bucketed_quantized_pod_mean(
    flat: jnp.ndarray, *, bucket_bytes: int = 64 << 20, axis_name: str = "pod"
) -> jnp.ndarray:
    """Streaming variant: quantize+gather+reduce one bucket at a time, so

    the live int8 gather buffer is bounded by bucket_bytes * P (the
    container-streaming analogue of paper §III). Uses lax.scan over equal
    buckets -> one compiled bucket program regardless of model size.
    """
    n = flat.shape[0]
    bucket_elems = max(BLOCK, (bucket_bytes // 4) // BLOCK * BLOCK)
    padded = int(np.ceil(n / bucket_elems)) * bucket_elems
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    nb = padded // bucket_elems
    buckets = flat.reshape(nb, bucket_elems)

    def one(carry, bucket):
        return carry, quantized_pod_mean(bucket, axis_name)

    _, out = jax.lax.scan(one, 0, buckets)
    return out.reshape(-1)[:n]


def quantized_fedavg_tree(
    tree: Any,
    *,
    axis_name: str = "pod",
    bucket_bytes: Optional[int] = None,
) -> Any:
    """FedAvg a pytree of updates across the pod axis (int8 wire)."""
    flat, meta, sizes = _flatten_tree(tree)
    if bucket_bytes:
        out = bucketed_quantized_pod_mean(flat, bucket_bytes=bucket_bytes, axis_name=axis_name)
    else:
        out = quantized_pod_mean(flat, axis_name)
    return _unflatten_tree(out, meta, sizes)


def fp32_fedavg_tree(tree: Any, *, axis_name: str = "pod") -> Any:
    """Paper-faithful fp32 baseline: plain pmean across pods."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_name).astype(x.dtype), tree
    )
