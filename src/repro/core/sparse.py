"""Top-k sparsification wire type (paper §V "sparsification ... based on
network conditions", Shahid et al.'s gradient-sparsification family).

:class:`SparseTensor` is the wire form of a magnitude-pruned tensor:
flat indices of the surviving entries plus their values, with the
original shape/dtype to rebuild a dense array on decode. It crosses the
wire through :mod:`repro.core.serialization` exactly like
:class:`~repro.core.quantization.QuantizedTensor`, and the ``topk``
pipeline stage produces/consumes it per item inside the streaming loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class SparseTensor:
    """Wire format for one top-k-sparsified tensor."""

    indices: np.ndarray                  # int32/int64 flat indices, sorted
    values: np.ndarray                   # surviving entries, original dtype
    orig_shape: tuple[int, ...]
    orig_dtype: Any

    @property
    def total_bytes(self) -> int:
        return int(self.indices.nbytes) + int(self.values.nbytes)

    @property
    def density(self) -> float:
        n = int(np.prod(self.orig_shape)) if self.orig_shape else 1
        return len(self.values) / max(1, n)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(int(np.prod(self.orig_shape)) if self.orig_shape else 1,
                       dtype=np.dtype(self.orig_dtype))
        out[self.indices] = self.values
        return out.reshape(self.orig_shape)


def topk_sparsify(arr: np.ndarray, fraction: float) -> SparseTensor:
    """Keep the ``ceil(fraction * n)`` largest-magnitude entries.

    Selection is deterministic: ties resolve toward the lower flat index
    (stable argsort), so the same tensor always sparsifies to the same
    wire bytes.
    """
    if not 0.0 < fraction <= 1.0:
        raise ValueError(f"topk fraction must be in (0, 1], got {fraction}")
    flat = np.asarray(arr).reshape(-1)
    k = max(1, int(np.ceil(fraction * flat.size)))
    order = np.argsort(-np.abs(flat), kind="stable")[:k]
    idx = np.sort(order).astype(np.int64 if flat.size > np.iinfo(np.int32).max
                                else np.int32)
    # fancy indexing already materializes a fresh values array — a
    # defensive .copy() here would be a second, redundant copy per item
    return SparseTensor(idx, flat[idx], tuple(np.asarray(arr).shape),
                        np.asarray(arr).dtype)
