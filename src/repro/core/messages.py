"""FL message types: 'Task Data' (server -> clients) and 'Task Result'

(clients -> server), the two payloads of one federated round (paper §II-A).
A message's ``payload`` is a flat state dict of arrays — or of
:class:`~repro.core.quantization.QuantizedTensor` once a quantize filter
has run. ``headers`` carry workflow metadata (round number, client name,
sample counts, timing) and are never quantized.
"""
from __future__ import annotations

import dataclasses
import enum
from collections.abc import Mapping
from typing import Any

from repro.utils.trees import tree_bytes


class MessageKind(enum.Enum):
    TASK_DATA = "task_data"       # server -> client (global weights)
    TASK_RESULT = "task_result"   # client -> server (local update)


@dataclasses.dataclass
class Message:
    kind: MessageKind
    payload: dict[str, Any]
    headers: dict[str, Any] = dataclasses.field(default_factory=dict)

    def payload_bytes(self) -> int:
        """Logical tensor-payload size: raw array/QuantizedTensor bytes
        only. This is **not** bytes-on-wire — it excludes item framing,
        pipeline envelopes, chunk headers and the transmitted message
        headers; the simulator's
        :class:`~repro.fl.simulator.TrafficStats` counts those at the
        driver, which is where true wire totals come from."""
        total = 0
        for v in self.payload.values():
            if hasattr(v, "total_bytes"):
                total += v.total_bytes  # QuantizedTensor
            else:
                total += tree_bytes(v)
        return total

    def replace_payload(self, payload: Mapping[str, Any]) -> Message:
        return Message(self.kind, dict(payload), dict(self.headers))
