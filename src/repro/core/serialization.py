"""Framed binary serialization for FL messages (FOBS analogue).

NVFlare serializes messages with FOBS; we implement a small deterministic
framed format so that message sizes are byte-exact and auditable:

    item  := header_len (u32 LE) | header (utf-8 JSON) | payload bytes
    blob  := n_items (u32 LE) | item*

The header carries name/shape/dtype plus quantization metadata for
:class:`~repro.core.quantization.QuantizedTensor` items. Payload bytes are
the raw array buffer (C-order). No pickling — wire format is portable and
safe to parse from untrusted peers.

Zero-copy discipline: the hot path works in **buffer views**, not joined
byte strings. :func:`serialize_item_views` emits an ordered list of
bytes-like segments (iovec-style) whose concatenation *is* the item's
wire bytes — array payloads stay ``memoryview``s over the tensors'
own buffers, so encoding an item costs one small header allocation and
zero payload copies. :func:`deserialize_item` accepts any buffer
(``bytes``/``bytearray``/``memoryview``) and returns ``frombuffer``
array views into it, so decoding copies nothing either. The joined-bytes
functions (:func:`serialize_item`, :func:`serialize_container`) remain
as the convenience/compat surface and are defined as "join the views".

This module is the *inner* codec only. When a
:class:`~repro.core.pipeline.WirePipeline` carries per-item transforms
(quantize, compress, checksum), each item here becomes the body of a
self-describing pipeline **envelope** whose header records the stage
stack and per-stage metadata — see ``repro.core.pipeline`` for that
outer framing.
"""
from __future__ import annotations

import json
import struct
from collections.abc import Iterator, Mapping, Sequence
from typing import Any, Union

import numpy as np

from repro.core.quantization import QuantizedTensor
from repro.core.sparse import SparseTensor
from repro.peft.lowrank import LowRankDelta
from repro.utils import mem

_U32 = struct.Struct("<I")

#: one wire item as an ordered list of buffer segments (iovec); the
#: item's wire bytes are the concatenation of the segments
Views = list[Union[bytes, memoryview]]
#: what streamers accept per item: pre-joined bytes or a view list
ViewsLike = Union[bytes, bytearray, memoryview, Sequence[Union[bytes, memoryview]]]


def _as_view(a: Any) -> Union[bytes, memoryview]:
    """Flat byte view over an array's buffer — zero-copy when the array
    is already C-contiguous (``ascontiguousarray`` is then a no-op);
    falls back to ``tobytes`` for dtypes without buffer-protocol support
    (that copy is recorded with the meter). The view is exported
    **read-only**: on a zero-copy hop (loopback) it may reach the
    receiving decoder directly, and nothing downstream may scribble on
    the sender's tensors through it."""
    src = np.asarray(a)
    arr = np.ascontiguousarray(src)
    if not np.shares_memory(arr, src):
        mem.record_copy(arr.nbytes)  # non-contiguous input: real memcpy
    try:
        return memoryview(arr).toreadonly().cast("B")
    except (TypeError, ValueError, NotImplementedError):
        out = arr.tobytes()
        mem.record_copy(len(out))
        return out


def views_nbytes(views: ViewsLike) -> int:
    """Total wire length of one item, joined or scattered."""
    if isinstance(views, (bytes, bytearray, memoryview)):
        return len(views)
    return sum(v.nbytes if isinstance(v, memoryview) else len(v) for v in views)


def join_views(views: ViewsLike) -> bytes:
    """Materialize one item's wire bytes (records the copy). This is the
    only place view-mode items become contiguous — drivers call it at
    the real transport boundary, nowhere earlier."""
    if isinstance(views, bytes):
        return views
    if isinstance(views, (bytearray, memoryview)):
        mem.record_copy(len(views))
        return bytes(views)
    out = b"".join(views)
    mem.record_copy(len(out))
    return out


def iter_view_segments(views: ViewsLike) -> Iterator[memoryview]:
    """Normalize an item to flat memoryview segments (zero-copy)."""
    if isinstance(views, (bytes, bytearray, memoryview)):
        views = (views,)
    for v in views:
        mv = v if isinstance(v, memoryview) else memoryview(v)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        if mv.nbytes:
            yield mv


class SegmentCursor:
    """Zero-copy reader over an ordered list of buffer segments.

    Receive-side counterpart of :data:`Views`: a single-chunk item
    arrives from a scatter-gather hop as the sender's unjoined segments
    (header bytes, then payload views), and the cursor reads fields
    straight out of them — a read that falls inside one segment returns
    a read-only ``memoryview`` slice (zero-copy), and only a read that
    crosses a segment boundary joins those bytes (recording the copy).
    Over the loopback driver the segments *are* the encode-side views,
    so header-from-segment-0 / ``frombuffer``-segment-1 decoding makes
    small-item receive fully zero-copy.
    """

    __slots__ = ("_segs", "_i", "_off", "consumed")

    def __init__(self, segments: Sequence[Any]) -> None:
        self._segs = [mv.toreadonly() for mv in iter_view_segments(list(segments))]
        self._i = 0
        self._off = 0
        self.consumed = 0

    @property
    def remaining(self) -> int:
        if self._i >= len(self._segs):
            return 0
        return (self._segs[self._i].nbytes - self._off) + sum(
            s.nbytes for s in self._segs[self._i + 1:]
        )

    def read_views(self, n: int) -> Views:
        """The next ``n`` bytes as zero-copy segment slices."""
        out: Views = []
        need = n
        while need > 0:
            if self._i >= len(self._segs):
                raise ValueError(
                    f"segmented item truncated: wanted {n} more bytes, "
                    f"had {n - need}"
                )
            seg = self._segs[self._i]
            take = min(need, seg.nbytes - self._off)
            out.append(
                seg if take == seg.nbytes and self._off == 0
                else seg[self._off:self._off + take]
            )
            self._off += take
            need -= take
            if self._off == seg.nbytes:
                self._i += 1
                self._off = 0
        self.consumed += n
        return out

    def read(self, n: int) -> Union[bytes, memoryview]:
        """The next ``n`` bytes, contiguous: a zero-copy view when they
        lie within one segment, a joined copy (recorded) otherwise."""
        views = self.read_views(n)
        if len(views) == 1:
            return views[0]
        out = b"".join(views)
        mem.record_copy(len(out))
        return out


def serialize_item_views(name: str, value: Any) -> Views:
    """One state-dict item -> ordered wire segments (header, then the
    payload buffers as zero-copy views). ``b"".join`` of the result is
    byte-identical to :func:`serialize_item`."""
    if isinstance(value, SparseTensor):
        idx = _as_view(value.indices)
        vals = _as_view(value.values)
        header = {
            "kind": "sparse",
            "name": name,
            "k": int(value.values.size),
            "idx_dtype": str(np.asarray(value.indices).dtype),
            "val_dtype": str(np.asarray(value.values).dtype),
            "orig_shape": list(value.orig_shape),
            "orig_dtype": str(np.dtype(value.orig_dtype)),
        }
        hbytes = json.dumps(header, sort_keys=True).encode()
        return [_U32.pack(len(hbytes)) + hbytes, idx, vals]
    if isinstance(value, LowRankDelta):
        a = _as_view(value.a)
        b = _as_view(value.b)
        header = {
            "kind": "lowrank",
            "name": name,
            "a_shape": list(np.asarray(value.a).shape),
            "a_dtype": str(np.asarray(value.a).dtype),
            "b_shape": list(np.asarray(value.b).shape),
            "b_dtype": str(np.asarray(value.b).dtype),
            "alpha": float(value.alpha),
            "rank": int(value.rank),
            "orig_shape": list(value.orig_shape),
            "orig_dtype": str(np.dtype(value.orig_dtype)),
        }
        hbytes = json.dumps(header, sort_keys=True).encode()
        return [_U32.pack(len(hbytes)) + hbytes, a, b]
    if isinstance(value, QuantizedTensor):
        payload = _as_view(value.payload)
        absmax = _as_view(value.absmax) if value.absmax is not None else b""
        header = {
            "kind": "qtensor",
            "name": name,
            "fmt": value.fmt,
            "payload_shape": list(value.payload.shape),
            "payload_dtype": str(np.asarray(value.payload).dtype),
            "absmax_len": views_nbytes([absmax]),
            "absmax_shape": list(value.absmax.shape) if value.absmax is not None else [],
            "orig_shape": list(value.orig_shape),
            "orig_dtype": str(np.dtype(value.orig_dtype)),
        }
        hbytes = json.dumps(header, sort_keys=True).encode()
        views: Views = [_U32.pack(len(hbytes)) + hbytes, payload]
        if views_nbytes([absmax]):
            views.append(absmax)
        return views
    arr = np.asarray(value)
    header = {
        "kind": "array",
        "name": name,
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
    }
    hbytes = json.dumps(header, sort_keys=True).encode()
    return [_U32.pack(len(hbytes)) + hbytes, _as_view(arr)]


def serialize_item(name: str, value: Any) -> bytes:
    """Serialize one state-dict item (array, QuantizedTensor,
    SparseTensor or LowRankDelta) to contiguous bytes — the views,
    joined."""
    return join_views(serialize_item_views(name, value))


def declared_item_nbytes(buf: Union[bytes, bytearray, memoryview]) -> int | None:
    """Total wire length of the item at the head of ``buf``, parsed from
    its header alone — what a receiver preallocates its reassembly
    buffer from. Returns None while ``buf`` is still shorter than the
    header, or for unknown header kinds."""
    mv = memoryview(buf)
    if mv.nbytes < 4:
        return None
    (hlen,) = _U32.unpack_from(mv, 0)
    if mv.nbytes < 4 + hlen:
        return None
    try:
        header = json.loads(bytes(mv[4:4 + hlen]))
    except (ValueError, UnicodeDecodeError):
        return None
    kind = header.get("kind")
    try:
        if kind in ("wire", "meta"):
            body = int(header["n"])
        elif kind == "array":
            shape = tuple(header["shape"])
            body = int(np.prod(shape)) * np.dtype(header["dtype"]).itemsize if shape \
                else np.dtype(header["dtype"]).itemsize
        elif kind == "qtensor":
            pshape = tuple(header["payload_shape"])
            pdtype = np.dtype(header["payload_dtype"])
            body = (int(np.prod(pshape)) if pshape else 1) * pdtype.itemsize
            body += int(header["absmax_len"])
        elif kind == "sparse":
            k = int(header["k"])
            body = k * (np.dtype(header["idx_dtype"]).itemsize
                        + np.dtype(header["val_dtype"]).itemsize)
        elif kind == "lowrank":
            a_shape = tuple(header["a_shape"])
            b_shape = tuple(header["b_shape"])
            body = (int(np.prod(a_shape)) * np.dtype(header["a_dtype"]).itemsize
                    + int(np.prod(b_shape)) * np.dtype(header["b_dtype"]).itemsize)
        else:
            return None
    except (KeyError, TypeError, ValueError):
        return None
    return 4 + hlen + body


def deserialize_item(buf: Union[bytes, bytearray, memoryview, Sequence]) -> tuple[str, Any, int]:
    """Parse one item from the head of ``buf``; returns (name, value,
    consumed). Arrays are ``frombuffer`` views into ``buf`` — no payload
    copy; the caller keeps the buffer alive as long as the values.
    Decoded arrays are **read-only** (exactly like the pre-views wire,
    which decoded from immutable ``bytes``): consumers that need to
    mutate copy first, and a zero-copy loopback hop can never write
    back into the sender's buffers.

    ``buf`` may also be a **list/tuple of segments** (an unjoined
    scatter-gather item, as a zero-copy receiver holds it): the header
    is read from the leading segment and each payload field is a
    ``frombuffer`` view over its own segment, so a single-chunk item
    whose segments mirror :func:`serialize_item_views` decodes with
    zero copies; only fields that straddle a segment boundary join."""
    if isinstance(buf, (list, tuple)):
        return _deserialize_item_segments(SegmentCursor(buf))
    mv = (buf if isinstance(buf, memoryview) else memoryview(buf)).toreadonly()
    (hlen,) = _U32.unpack_from(mv, 0)
    header = json.loads(bytes(mv[4:4 + hlen]))
    off = 4 + hlen
    if header["kind"] == "sparse":
        k = int(header["k"])
        idx_dtype = np.dtype(header["idx_dtype"])
        val_dtype = np.dtype(header["val_dtype"])
        indices = np.frombuffer(mv, idx_dtype, count=k, offset=off)
        off += k * idx_dtype.itemsize
        values = np.frombuffer(mv, val_dtype, count=k, offset=off)
        off += k * val_dtype.itemsize
        sp = SparseTensor(indices, values, tuple(header["orig_shape"]),
                          np.dtype(header["orig_dtype"]))
        return header["name"], sp, off
    if header["kind"] == "lowrank":
        a_shape = tuple(header["a_shape"])
        b_shape = tuple(header["b_shape"])
        a_dtype = np.dtype(header["a_dtype"])
        b_dtype = np.dtype(header["b_dtype"])
        a = np.frombuffer(mv, a_dtype, count=int(np.prod(a_shape)),
                          offset=off).reshape(a_shape)
        off += int(np.prod(a_shape)) * a_dtype.itemsize
        b = np.frombuffer(mv, b_dtype, count=int(np.prod(b_shape)),
                          offset=off).reshape(b_shape)
        off += int(np.prod(b_shape)) * b_dtype.itemsize
        lr = LowRankDelta(a, b, float(header["alpha"]), int(header["rank"]),
                          tuple(header["orig_shape"]),
                          np.dtype(header["orig_dtype"]))
        return header["name"], lr, off
    if header["kind"] == "qtensor":
        pshape = tuple(header["payload_shape"])
        pdtype = np.dtype(header["payload_dtype"])
        pbytes = int(np.prod(pshape)) * pdtype.itemsize if pshape else pdtype.itemsize
        payload = np.frombuffer(mv, pdtype, count=int(np.prod(pshape)), offset=off).reshape(pshape)
        off += pbytes
        absmax = None
        if header["absmax_len"]:
            ashape = tuple(header["absmax_shape"])
            absmax = np.frombuffer(
                mv, np.float32, count=int(np.prod(ashape)), offset=off
            ).reshape(ashape)
            off += header["absmax_len"]
        value: Any = QuantizedTensor(
            payload, absmax, header["fmt"], tuple(header["orig_shape"]),
            np.dtype(header["orig_dtype"]),
        )
        return header["name"], value, off
    shape = tuple(header["shape"])
    dtype = np.dtype(header["dtype"])
    count = int(np.prod(shape)) if shape else 1
    arr = np.frombuffer(mv, dtype, count=count, offset=off).reshape(shape)
    return header["name"], arr, off + count * dtype.itemsize


def _deserialize_item_segments(cur: SegmentCursor) -> tuple[str, Any, int]:
    """Segment-aware :func:`deserialize_item` body: header from the
    leading segment, each payload field ``frombuffer``'d out of its own
    segment(s) via the cursor (copying only on boundary straddles)."""
    (hlen,) = _U32.unpack(bytes(cur.read(4)))
    header = json.loads(bytes(cur.read(hlen)))
    if header["kind"] == "sparse":
        k = int(header["k"])
        idx_dtype = np.dtype(header["idx_dtype"])
        val_dtype = np.dtype(header["val_dtype"])
        indices = np.frombuffer(cur.read(k * idx_dtype.itemsize), idx_dtype, count=k)
        values = np.frombuffer(cur.read(k * val_dtype.itemsize), val_dtype, count=k)
        sp = SparseTensor(indices, values, tuple(header["orig_shape"]),
                          np.dtype(header["orig_dtype"]))
        return header["name"], sp, cur.consumed
    if header["kind"] == "lowrank":
        a_shape = tuple(header["a_shape"])
        b_shape = tuple(header["b_shape"])
        a_dtype = np.dtype(header["a_dtype"])
        b_dtype = np.dtype(header["b_dtype"])
        a_count = int(np.prod(a_shape))
        b_count = int(np.prod(b_shape))
        a = np.frombuffer(cur.read(a_count * a_dtype.itemsize), a_dtype,
                          count=a_count).reshape(a_shape)
        b = np.frombuffer(cur.read(b_count * b_dtype.itemsize), b_dtype,
                          count=b_count).reshape(b_shape)
        lr = LowRankDelta(a, b, float(header["alpha"]), int(header["rank"]),
                          tuple(header["orig_shape"]),
                          np.dtype(header["orig_dtype"]))
        return header["name"], lr, cur.consumed
    if header["kind"] == "qtensor":
        pshape = tuple(header["payload_shape"])
        pdtype = np.dtype(header["payload_dtype"])
        pcount = int(np.prod(pshape)) if pshape else 1
        payload = np.frombuffer(
            cur.read(pcount * pdtype.itemsize), pdtype, count=pcount
        ).reshape(pshape)
        absmax = None
        if header["absmax_len"]:
            ashape = tuple(header["absmax_shape"])
            absmax = np.frombuffer(
                cur.read(int(header["absmax_len"])), np.float32,
                count=int(np.prod(ashape)),
            ).reshape(ashape)
        value: Any = QuantizedTensor(
            payload, absmax, header["fmt"], tuple(header["orig_shape"]),
            np.dtype(header["orig_dtype"]),
        )
        return header["name"], value, cur.consumed
    shape = tuple(header["shape"])
    dtype = np.dtype(header["dtype"])
    count = int(np.prod(shape)) if shape else 1
    arr = np.frombuffer(
        cur.read(count * dtype.itemsize), dtype, count=count
    ).reshape(shape)
    return header["name"], arr, cur.consumed


def serialize_container(sd: Mapping[str, Any]) -> bytes:
    """Whole-message serialization (the *regular transmission* path —

    materializes the full blob in one join; registers it with the
    MemoryMeter)."""
    parts: Views = [_U32.pack(len(sd))]
    for name, value in sd.items():
        parts.extend(serialize_item_views(name, value))
    blob = b"".join(parts)
    mem.record_copy(len(blob))
    mem.record_alloc(len(blob))
    return blob


def deserialize_container(blob: Union[bytes, bytearray, memoryview]) -> dict[str, Any]:
    mv = blob if isinstance(blob, memoryview) else memoryview(blob)
    (n,) = _U32.unpack_from(mv, 0)
    out: dict[str, Any] = {}
    off = 4
    for _ in range(n):
        name, value, consumed = deserialize_item(mv[off:])
        out[name] = value
        off += consumed
    return out


def iter_serialized_items(sd: Mapping[str, Any]) -> Iterator[tuple[str, Views]]:
    """Container-streaming producer: yields one item's wire segments at a
    time (peak live bytes = largest single item, the paper's §III claim;
    the segments are zero-copy views over the tensors themselves)."""
    for name, value in sd.items():
        views = serialize_item_views(name, value)
        with mem.record_hold(views_nbytes(views)):
            yield name, views
