"""Framed binary serialization for FL messages (FOBS analogue).

NVFlare serializes messages with FOBS; we implement a small deterministic
framed format so that message sizes are byte-exact and auditable:

    item  := header_len (u32 LE) | header (utf-8 JSON) | payload bytes
    blob  := n_items (u32 LE) | item*

The header carries name/shape/dtype plus quantization metadata for
:class:`~repro.core.quantization.QuantizedTensor` items. Payload bytes are
the raw array buffer (C-order). No pickling — wire format is portable and
safe to parse from untrusted peers.

This module is the *inner* codec only. When a
:class:`~repro.core.pipeline.WirePipeline` carries per-item transforms
(quantize, compress, checksum), each item here becomes the body of a
self-describing pipeline **envelope** whose header records the stage
stack and per-stage metadata — see ``repro.core.pipeline`` for that
outer framing.
"""
from __future__ import annotations

import json
import struct
from collections.abc import Iterator, Mapping
from typing import Any

import numpy as np

from repro.core.quantization import QuantizedTensor
from repro.core.sparse import SparseTensor
from repro.utils import mem

_U32 = struct.Struct("<I")


def _arr_bytes(a: Any) -> bytes:
    return np.ascontiguousarray(np.asarray(a)).tobytes()


def serialize_item(name: str, value: Any) -> bytes:
    """Serialize one state-dict item (array, QuantizedTensor or
    SparseTensor)."""
    if isinstance(value, SparseTensor):
        idx = _arr_bytes(value.indices)
        vals = _arr_bytes(value.values)
        header = {
            "kind": "sparse",
            "name": name,
            "k": int(value.values.size),
            "idx_dtype": str(np.asarray(value.indices).dtype),
            "val_dtype": str(np.asarray(value.values).dtype),
            "orig_shape": list(value.orig_shape),
            "orig_dtype": str(np.dtype(value.orig_dtype)),
        }
        body = idx + vals
        hbytes = json.dumps(header, sort_keys=True).encode()
        return _U32.pack(len(hbytes)) + hbytes + body
    if isinstance(value, QuantizedTensor):
        payload = _arr_bytes(value.payload)
        absmax = _arr_bytes(value.absmax) if value.absmax is not None else b""
        header = {
            "kind": "qtensor",
            "name": name,
            "fmt": value.fmt,
            "payload_shape": list(value.payload.shape),
            "payload_dtype": str(np.asarray(value.payload).dtype),
            "absmax_len": len(absmax),
            "absmax_shape": list(value.absmax.shape) if value.absmax is not None else [],
            "orig_shape": list(value.orig_shape),
            "orig_dtype": str(np.dtype(value.orig_dtype)),
        }
        body = payload + absmax
    else:
        arr = np.asarray(value)
        header = {
            "kind": "array",
            "name": name,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        body = _arr_bytes(arr)
    hbytes = json.dumps(header, sort_keys=True).encode()
    return _U32.pack(len(hbytes)) + hbytes + body


def deserialize_item(buf: bytes) -> tuple[str, Any, int]:
    """Parse one item from the head of ``buf``; returns (name, value, consumed)."""
    (hlen,) = _U32.unpack_from(buf, 0)
    header = json.loads(buf[4 : 4 + hlen].decode())
    off = 4 + hlen
    if header["kind"] == "sparse":
        k = int(header["k"])
        idx_dtype = np.dtype(header["idx_dtype"])
        val_dtype = np.dtype(header["val_dtype"])
        indices = np.frombuffer(buf, idx_dtype, count=k, offset=off)
        off += k * idx_dtype.itemsize
        values = np.frombuffer(buf, val_dtype, count=k, offset=off)
        off += k * val_dtype.itemsize
        sp = SparseTensor(indices, values, tuple(header["orig_shape"]),
                          np.dtype(header["orig_dtype"]))
        return header["name"], sp, off
    if header["kind"] == "qtensor":
        pshape = tuple(header["payload_shape"])
        pdtype = np.dtype(header["payload_dtype"])
        pbytes = int(np.prod(pshape)) * pdtype.itemsize if pshape else pdtype.itemsize
        payload = np.frombuffer(buf, pdtype, count=int(np.prod(pshape)), offset=off).reshape(pshape)
        off += pbytes
        absmax = None
        if header["absmax_len"]:
            ashape = tuple(header["absmax_shape"])
            absmax = np.frombuffer(
                buf, np.float32, count=int(np.prod(ashape)), offset=off
            ).reshape(ashape)
            off += header["absmax_len"]
        value: Any = QuantizedTensor(
            payload, absmax, header["fmt"], tuple(header["orig_shape"]),
            np.dtype(header["orig_dtype"]),
        )
        return header["name"], value, off
    shape = tuple(header["shape"])
    dtype = np.dtype(header["dtype"])
    count = int(np.prod(shape)) if shape else 1
    arr = np.frombuffer(buf, dtype, count=count, offset=off).reshape(shape)
    return header["name"], arr, off + count * dtype.itemsize


def serialize_container(sd: Mapping[str, Any]) -> bytes:
    """Whole-message serialization (the *regular transmission* path —

    materializes the full blob; registers it with the MemoryMeter)."""
    parts = [_U32.pack(len(sd))]
    parts.extend(serialize_item(name, value) for name, value in sd.items())
    blob = b"".join(parts)
    mem.record_alloc(len(blob))
    return blob


def deserialize_container(blob: bytes) -> dict[str, Any]:
    (n,) = _U32.unpack_from(blob, 0)
    out: dict[str, Any] = {}
    off = 4
    for _ in range(n):
        name, value, consumed = deserialize_item(blob[off:])
        out[name] = value
        off += consumed
    return out


def iter_serialized_items(sd: Mapping[str, Any]) -> Iterator[tuple[str, bytes]]:
    """Container-streaming producer: yields one serialized item at a time

    (peak live bytes = largest single item, the paper's §III claim)."""
    for name, value in sd.items():
        item = serialize_item(name, value)
        with mem.record_hold(len(item)):
            yield name, item
