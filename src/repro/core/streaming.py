"""SFM-style streaming layer (paper §I Fig. 1 and §III).

Layering (mirrors NVFlare):

* **Frames** — :class:`Chunk`: fixed-size (default 1 MiB) framed slices of
  a logical stream, carrying (stream_id, seq, eof) headers.
* **Drivers** — transport plugins, looked up by name through
  :func:`register_driver`/:func:`make_driver` so third-party transports
  plug in without touching core. Upper layers never see the transport
  (paper: "switch between gRPC, TCP, HTTP ... without any changes"):
  :class:`LoopbackDriver` (in-process queue), :class:`FileSpoolDriver`
  (spools frames to disk — models a store-and-forward relay),
  :class:`TCPDriver` (real localhost sockets).
* **Streamers** — three transmission modes with distinct peak-memory
  envelopes (paper Fig. 3):

  - :class:`ObjectStreamer` (*regular*): one pre-encoded blob lives in
    memory (peak ~ model size).
  - :class:`ContainerStreamer`: one encoded dict item at a time (peak ~
    largest item).
  - :class:`FileStreamer`: stream a file chunk-by-chunk (peak ~ chunk).

* **ObjectRetriever** — pull-mode API: the holder registers an object, the
  peer retrieves it over any streamer; eases integration with existing
  workflows (paper contribution 2).

Streamers and receivers are codec-agnostic: how an item becomes bytes is
pluggable (``ContainerStreamer.send_items`` / the receivers'
``decode_item``/``decode_container`` hooks), and the default codec is
plain :mod:`repro.core.serialization`. The
:class:`~repro.core.pipeline.WirePipeline` plugs its per-item transforms
(quantize, compress, checksum, ...) into exactly these seams, so stage
execution happens *inside* the streaming loop and the container-mode
peak stays ~one item even with a full transform stack enabled.

The whole layer is **zero-copy**: items arrive as ordered buffer views
(iovec-style, :data:`repro.core.serialization.Views`), chunkers slice
the views, and :class:`Chunk` payloads may be tuples of segments that
drivers forward unjoined — contiguity is restored only at a real
transport boundary (``Chunk.encode()`` for spooling to disk; the TCP
driver gathers segments with ``sendmsg`` and coalesces only small
writes). Receivers reassemble each item into one preallocated buffer
sized from the item's own header (:class:`_ItemAssembler`), so a
transferred byte is copied at most once end to end.

Every buffer the layer holds live registers with the active
:class:`~repro.utils.mem.MemoryMeter`, which is how the Table III
benchmark measures the three envelopes deterministically.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import socket
import struct
import threading
import time
import uuid
from collections.abc import Callable, Iterable, Iterator, Mapping
from typing import Any, Optional, Union

from repro.core import serialization as ser
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils import mem

DEFAULT_CHUNK_SIZE = 1 << 20  # 1 MiB, the paper's default

_HDR = struct.Struct("<16sIIB")  # stream_id, seq, payload_len, flags
FLAG_EOF = 1
FLAG_ITEM_END = 2  # container streaming: item boundary marker


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One framed slice of a logical stream.

    ``payload`` is bytes-like **or a tuple of bytes-like segments**
    (scatter-gather: the chunk's wire bytes are the segments'
    concatenation, but nothing is joined until a real transport boundary
    needs contiguity — ``encode()``/``payload_bytes()``). Loopback
    delivery hands the segments to the receiver as-is, so an in-process
    hop moves tensor bytes with zero copies.
    """

    stream_id: bytes          # 16-byte uuid
    seq: int
    payload: Any              # bytes | memoryview | tuple of those
    flags: int = 0

    @property
    def segments(self) -> tuple:
        """The payload as a tuple of bytes-like segments."""
        p = self.payload
        return p if isinstance(p, tuple) else (p,)

    @property
    def nbytes(self) -> int:
        p = self.payload
        if isinstance(p, tuple):
            return sum(len(s) for s in p)
        return len(p)

    def payload_bytes(self) -> bytes:
        """Contiguous payload bytes (joins — records the copy)."""
        p = self.payload
        if isinstance(p, tuple):
            return ser.join_views(list(p))
        if isinstance(p, memoryview):
            mem.record_copy(len(p))
            return bytes(p)
        return bytes(p)

    def encode(self) -> bytes:
        return _HDR.pack(self.stream_id, self.seq, self.nbytes, self.flags) \
            + self.payload_bytes()

    @classmethod
    def decode(cls, buf: bytes) -> Chunk:
        sid, seq, plen, flags = _HDR.unpack_from(buf, 0)
        return cls(sid, seq, buf[_HDR.size : _HDR.size + plen], flags)

    @property
    def eof(self) -> bool:
        return bool(self.flags & FLAG_EOF)

    @property
    def item_end(self) -> bool:
        return bool(self.flags & FLAG_ITEM_END)


# ---------------------------------------------------------------------------
# Drivers (SFM transport plugins)
# ---------------------------------------------------------------------------

class Driver:
    """Transport interface: push chunks, deliver to a registered callback."""

    def connect(self, on_chunk: Callable[[Chunk], None]) -> None:
        self._on_chunk = on_chunk

    def send(self, chunk: Chunk) -> None:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - default no-op
        pass


_DRIVERS: dict[str, Callable[..., Driver]] = {}


def register_driver(name: str) -> Callable[[Callable[..., Driver]], Callable[..., Driver]]:
    """Class/factory decorator: bind ``name`` to a transport so job specs
    and :class:`~repro.fl.simulator.SimulationConfig` can select it by
    string — the same registry pattern as
    :func:`repro.core.pipeline.register_stage`."""

    def deco(factory: Callable[..., Driver]) -> Callable[..., Driver]:
        if name in _DRIVERS:
            raise ValueError(f"driver name {name!r} already registered ({_DRIVERS[name]})")
        _DRIVERS[name] = factory
        return factory

    return deco


def registered_drivers() -> tuple[str, ...]:
    return tuple(sorted(_DRIVERS))


def make_driver(name: str, **kwargs: Any) -> Driver:
    try:
        factory = _DRIVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown driver {name!r}; registered: {registered_drivers()}"
        ) from None
    return factory(**kwargs)


@register_driver("loopback")
class LoopbackDriver(Driver):
    """Synchronous in-process delivery (the simulator default)."""

    def send(self, chunk: Chunk) -> None:
        self._on_chunk(chunk)


@register_driver("spool")
class FileSpoolDriver(Driver):
    """Spools every frame to a directory, then replays on ``flush()``.

    Models a store-and-forward relay; also exercises frame encode/decode.
    Frame filenames carry a per-driver unique prefix, so concurrent
    drivers (the async scheduler runs many round trips at once) can
    share one spool directory without clobbering each other's frames.
    """

    def __init__(self, spool_dir: str) -> None:
        self.spool_dir = spool_dir
        os.makedirs(spool_dir, exist_ok=True)
        self._uid = uuid.uuid4().hex
        self._count = 0

    def _path(self, i: int) -> str:
        return os.path.join(self.spool_dir, f"{self._uid}-{i:08d}.frame")

    def send(self, chunk: Chunk) -> None:
        with open(self._path(self._count), "wb") as fh:
            fh.write(chunk.encode())
        self._count += 1

    def flush(self) -> None:
        for i in range(self._count):
            path = self._path(i)
            with open(path, "rb") as fh:
                self._on_chunk(Chunk.decode(fh.read()))
            os.unlink(path)
        self._count = 0


@register_driver("tcp")
class TCPDriver(Driver):
    """Real localhost sockets: sender connects to a receiver thread.

    Demonstrates SFM's driver-swap claim — the streamers run unchanged
    over TCP instead of loopback.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self._srv = socket.create_server((host, port))
        self.address = self._srv.getsockname()
        self._sock: Optional[socket.socket] = None
        self._thread: Optional[threading.Thread] = None
        self._done = threading.Event()

    def connect(self, on_chunk: Callable[[Chunk], None]) -> None:
        super().connect(on_chunk)

        def serve() -> None:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                # server socket closed before any sender connected —
                # a clean no-traffic shutdown, not an error
                self._done.set()
                return
            with conn:
                fh = conn.makefile("rb")
                while True:
                    hdr = fh.read(_HDR.size)
                    if len(hdr) < _HDR.size:
                        break
                    sid, seq, plen, flags = _HDR.unpack(hdr)
                    tr = obs_trace.ACTIVE
                    if tr is None:
                        payload = fh.read(plen)
                        chunk = Chunk(sid, seq, payload, flags)
                        self._on_chunk(chunk)
                    else:
                        with tr.span("tcp.recv", "net", nbytes=plen, seq=seq):
                            payload = fh.read(plen)
                            chunk = Chunk(sid, seq, payload, flags)
                            self._on_chunk(chunk)
                    if chunk.eof:
                        break
            self._done.set()

        self._thread = threading.Thread(target=serve, daemon=True)
        self._thread.start()

    #: below this many payload bytes a chunk is joined into one buffer
    #: before hitting the socket (small-write coalescing: one syscall and
    #: one TCP segment beat a scatter-gather call over tiny pieces).
    #: Per-socket senders raise this to the socket's actual SO_SNDBUF
    #: (see :func:`socket_coalesce_bytes`) — writes smaller than the
    #: kernel send buffer complete in one copy anyway, so gathering only
    #: pays off past it.
    COALESCE_BYTES = 1 << 13

    def send(self, chunk: Chunk) -> None:
        tr = obs_trace.ACTIVE
        if tr is None:
            self._send(chunk)
            return
        coalesce = self._coalesce or self.COALESCE_BYTES
        gather = chunk.nbytes >= coalesce and hasattr(socket.socket, "sendmsg")
        with tr.span("tcp.send", "net", nbytes=chunk.nbytes,
                     segments=len(chunk.segments), gather=gather):
            self._send(chunk)

    _coalesce: Optional[int] = None

    def _send(self, chunk: Chunk) -> None:
        if self._sock is None:
            self._sock = socket.create_connection(self.address)
            self._coalesce = socket_coalesce_bytes(self._sock)
        send_chunk(self._sock, chunk, self._coalesce)

    def close(self) -> None:
        """Idempotent shutdown: drains the receiver thread even when no
        sender ever connected (the concurrent scheduler closes drivers on
        every path, including dropped-out round trips)."""
        if self._sock is not None:
            self._sock.close()
            self._sock = None
            self._done.wait(timeout=30)
        elif self._thread is not None and not self._done.is_set():
            # no sender ever connected: wake the blocked accept() with an
            # empty connection so the receiver thread can exit promptly
            try:
                socket.create_connection(self.address, timeout=1).close()
            except OSError:
                pass
            self._done.wait(timeout=5)
        self._srv.close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


# ---------------------------------------------------------------------------
# Real federation transport: shared frame I/O + the concurrent server plane
# ---------------------------------------------------------------------------

#: never coalesce past this, whatever SO_SNDBUF claims — joining a huge
#: chunk in user space just to hand the kernel one buffer wastes the
#: copy the scatter-gather path exists to avoid
COALESCE_CAP = 1 << 16


def socket_coalesce_bytes(sock: socket.socket) -> int:
    """SO_SNDBUF-aware small-write coalescing threshold for ``sock``.

    A write smaller than the kernel's send buffer is absorbed in one
    copy regardless, so scatter-gather only wins once a chunk outgrows
    it; below that, one joined ``sendall`` is one syscall and one TCP
    segment. Clamped to [``TCPDriver.COALESCE_BYTES``, ``COALESCE_CAP``]
    so a giant SO_SNDBUF can't reintroduce full-chunk user-space joins.
    """
    try:
        sndbuf = sock.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF)
    except OSError:  # pragma: no cover - exotic socket object
        return TCPDriver.COALESCE_BYTES
    return max(TCPDriver.COALESCE_BYTES, min(int(sndbuf), COALESCE_CAP))


def send_chunk(sock: socket.socket, chunk: Chunk,
               coalesce: Optional[int] = None) -> None:
    """Write one frame to ``sock``: header + payload segments.

    The single chunk-egress path shared by :class:`TCPDriver` and the
    federation server plane: small chunks are coalesced into one
    ``sendall`` (threshold from :func:`socket_coalesce_bytes`), large
    chunks go out as a kernel scatter-gather ``sendmsg`` over the
    payload views with partial-send resume — no user-space join of the
    tensor bytes, identical bytes on the wire either way.
    """
    if coalesce is None:
        coalesce = TCPDriver.COALESCE_BYTES
    hdr = _HDR.pack(chunk.stream_id, chunk.seq, chunk.nbytes, chunk.flags)
    if chunk.nbytes < coalesce or not hasattr(sock, "sendmsg"):
        # small-write coalescing — and the portable fallback where the
        # platform has no scatter-gather socket call (Windows)
        sock.sendall(hdr + chunk.payload_bytes())
        return
    bufs: list[Any] = [hdr, *chunk.segments]
    while bufs:
        sent = sock.sendmsg(bufs)
        while bufs and sent >= len(bufs[0]):
            sent -= len(bufs[0])
            bufs.pop(0)
        if sent and bufs:
            bufs[0] = memoryview(bufs[0])[sent:]


#: control frames are length-prefixed JSON; anything bigger than this is
#: a corrupted stream, not a plausible control message
CTRL_MAX_BYTES = 1 << 20

_CTRL = struct.Struct("<I")


class ProtocolError(ValueError):
    """A peer sent bytes that violate the federation wire protocol."""


class Connection:
    """One established federation socket, either end.

    Two frame vocabularies interleave on the stream, demarcated by
    protocol state (each control frame says what follows):

    * **control frames** — u32 LE length + JSON body (handshake, round
      control, grants);
    * **chunk streams** — raw :class:`Chunk` frames, byte-identical to
      the point-to-point :class:`TCPDriver` wire, ending at a
      ``FLAG_EOF`` chunk.

    Reads go through one buffered reader; writes serialize on a lock so
    a control frame can never tear through the middle of a chunk
    stream when helper threads share the connection. Chunk egress uses
    the same gather/coalesce path as :class:`TCPDriver`
    (:func:`send_chunk`), with the coalescing threshold adapted to this
    socket's ``SO_SNDBUF``.

    The reader is **timeout-safe**: bytes received before a socket
    timeout stay in the connection's own buffer, and the next read
    resumes at the exact byte position — unlike ``socket.makefile``,
    whose internal buffer is undefined after a timeout. The federation
    server leans on this to *drain* a straggler's late uplink after a
    grace deadline fired mid-frame: the drain picks up where the granted
    read stopped, so leftover bytes never desync the frame stream.
    """

    def __init__(self, sock: socket.socket,
                 peer: Optional[tuple] = None) -> None:
        self.sock = sock
        try:
            self.peer = peer or sock.getpeername()
        except OSError:  # pragma: no cover - already-dead socket
            self.peer = peer or ("?", 0)
        self._rbuf = bytearray()
        # frame-resumption state: a parsed-but-unsatisfied length prefix
        # (control) or chunk header survives a mid-payload timeout, so
        # the next read completes the *same* frame instead of parsing
        # payload bytes as a fresh header
        self._ctrl_pending: Optional[int] = None
        self._chunk_pending: Optional[tuple] = None
        self._coalesce = socket_coalesce_bytes(sock)
        self._wlock = threading.Lock()

    def settimeout(self, timeout: Optional[float]) -> None:
        self.sock.settimeout(timeout)

    # -- control frames -----------------------------------------------------
    def send_ctrl(self, obj: Mapping[str, Any]) -> None:
        body = json.dumps(obj, sort_keys=True).encode()
        with self._wlock:
            self.sock.sendall(_CTRL.pack(len(body)) + body)

    def recv_ctrl(self) -> dict[str, Any]:
        if self._ctrl_pending is None:
            (n,) = _CTRL.unpack(self._read_exact(_CTRL.size))
            if n > CTRL_MAX_BYTES:
                raise ProtocolError(
                    f"control frame declares {n} bytes (max {CTRL_MAX_BYTES}); "
                    "stream is corrupt or the peer speaks a different protocol"
                )
            self._ctrl_pending = n
        body = self._read_exact(self._ctrl_pending)
        self._ctrl_pending = None
        try:
            return json.loads(body)
        except json.JSONDecodeError as exc:
            raise ProtocolError(f"control frame is not JSON: {exc}") from None

    # -- chunk streams ------------------------------------------------------
    def send_chunk(self, chunk: Chunk) -> None:
        with self._wlock:
            send_chunk(self.sock, chunk, self._coalesce)

    def recv_chunk(self) -> Chunk:
        if self._chunk_pending is None:
            hdr = self._read_exact(_HDR.size)
            self._chunk_pending = _HDR.unpack(hdr)
        sid, seq, plen, flags = self._chunk_pending
        tr = obs_trace.ACTIVE
        if tr is None:
            payload = self._read_exact(plen)
        else:
            with tr.span("tcp.recv", "net", nbytes=plen, seq=seq):
                payload = self._read_exact(plen)
        self._chunk_pending = None
        return Chunk(sid, seq, payload, flags)

    def recv_stream(self, on_chunk: Callable[[Chunk], None]) -> int:
        """Receive chunk frames into ``on_chunk`` until a ``FLAG_EOF``
        chunk closes the stream; returns total wire bytes (headers
        included). Chunks are routed by their own ``stream_id``, so a
        multiplexing peer may interleave frames of several logical
        streams — this call returns when the *first-seen* stream ends
        (others keep routing through the same callback via
        :class:`StreamDemux` on the caller's side if needed)."""
        total = 0
        sid: Optional[bytes] = None
        while True:
            chunk = self.recv_chunk()
            total += _HDR.size + chunk.nbytes
            if sid is None:
                sid = chunk.stream_id
            on_chunk(chunk)
            if chunk.eof and chunk.stream_id == sid:
                return total

    def _read_exact(self, n: int) -> bytes:
        # a TimeoutError from recv propagates with every byte received so
        # far retained in _rbuf — the next call resumes mid-frame
        buf = self._rbuf
        while len(buf) < n:
            try:
                got = self.sock.recv(max(n - len(buf), 1 << 16))
            except InterruptedError:  # pragma: no cover - EINTR
                continue
            if not got:
                raise ConnectionError(
                    f"peer {self.peer} closed the connection mid-frame "
                    f"(wanted {n} bytes, got {len(buf)})"
                )
            buf += got
        out = bytes(memoryview(buf)[:n])
        del buf[:n]
        return out

    def close(self) -> None:
        # shutdown first: close() alone is deferred while another thread
        # blocks in recv on this socket (CPython keeps the fd referenced),
        # so dropping a client mid-read would neither wake our reader nor
        # send the peer a FIN until some timeout fired
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:  # pragma: no cover
            pass


class ConnectionDriver(Driver):
    """Send-side :class:`Driver` over an established :class:`Connection`,
    so the standard streamers (:class:`ContainerStreamer`, ...) run
    unchanged over a long-lived multiplexed federation socket instead of
    a per-transfer point-to-point one. Counts egress frame bytes like
    the simulator's CountingDriver (headers included)."""

    def __init__(self, conn: Connection) -> None:
        self.conn = conn
        self.bytes_sent = 0

    def send(self, chunk: Chunk) -> None:
        self.bytes_sent += _HDR.size + chunk.nbytes
        tr = obs_trace.ACTIVE
        if tr is None:
            self.conn.send_chunk(chunk)
            return
        with tr.span("tcp.send", "net", nbytes=chunk.nbytes,
                     segments=len(chunk.segments)):
            self.conn.send_chunk(chunk)

    def close(self) -> None:
        # the connection outlives one logical stream — never closed here
        pass


class StreamDemux:
    """Connection multiplexing: routes interleaved chunk frames to
    per-stream receivers keyed by the frame's own ``stream_id``.

    ``receiver_factory(stream_id)`` builds the receiver for a stream's
    first chunk; :meth:`route` feeds every chunk to its stream's
    receiver and returns the finished receiver when an EOF frame closes
    a stream (``None`` otherwise). One connection can therefore carry
    several logical transfers at once — the federation server's uplink
    plane and any future bidirectional traffic share this primitive.
    """

    def __init__(self, receiver_factory: Callable[[bytes], Any]) -> None:
        self._factory = receiver_factory
        self._live: dict[bytes, Any] = {}

    @property
    def open_streams(self) -> int:
        return len(self._live)

    def route(self, chunk: Chunk) -> Optional[Any]:
        recv = self._live.get(chunk.stream_id)
        if recv is None:
            recv = self._factory(chunk.stream_id)
            self._live[chunk.stream_id] = recv
        recv.on_chunk(chunk)
        if chunk.eof:
            return self._live.pop(chunk.stream_id)
        return None


class TCPServer:
    """Concurrent accept loop: the real-deployment listener grown from
    the point-to-point :class:`TCPDriver`.

    Every accepted socket becomes a :class:`Connection` handed to
    ``on_connection`` on its own daemon thread, so hundreds of clients
    can be in handshake or mid-stream simultaneously while the owner
    (the federation server) drives round logic. Frames, gather writes
    and coalescing are byte-identical to the driver wire — a client
    cannot tell which end it speaks to.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 backlog: int = 128) -> None:
        self._srv = socket.create_server((host, port), backlog=backlog)
        self.address = self._srv.getsockname()
        self._accept_thread: Optional[threading.Thread] = None
        self._conn_threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._closing = False
        self.accepted = 0

    def serve(self, on_connection: Callable[[Connection], None]) -> None:
        """Start accepting; each connection runs ``on_connection(conn)``
        on a dedicated thread. Idempotent close via :meth:`close`."""
        if self._accept_thread is not None:
            raise RuntimeError("serve() already called")

        def accept_loop() -> None:
            while True:
                try:
                    sock, peer = self._srv.accept()
                except OSError:
                    return  # listener closed — clean shutdown
                if self._closing:
                    sock.close()  # the close() wake-up self-connection
                    return
                conn = Connection(sock, peer)
                with self._lock:
                    self.accepted += 1
                    t = threading.Thread(
                        target=on_connection, args=(conn,), daemon=True,
                        name=f"fed-conn-{peer[1]}",
                    )
                    self._conn_threads.append(t)
                t.start()

        self._accept_thread = threading.Thread(
            target=accept_loop, daemon=True, name="fed-accept"
        )
        self._accept_thread.start()

    def close(self) -> None:
        # closing the listener fd does NOT wake a thread blocked in
        # accept() on Linux — it would sit out the whole join timeout.
        # shutdown() does; where a platform refuses shutdown on a
        # listener, a throwaway self-connection unblocks it instead.
        self._closing = True
        try:
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            try:
                socket.create_connection(self.address, timeout=1).close()
            except OSError:
                pass
        self._srv.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
        with self._lock:
            threads = list(self._conn_threads)
        for t in threads:
            t.join(timeout=5)


# ---------------------------------------------------------------------------
# Receivers (re-assembly with mode-specific memory envelopes)
# ---------------------------------------------------------------------------

class _ItemAssembler:
    """Reassembles one logical item from in-order chunk segments into a
    **single preallocated buffer**.

    The first segments are buffered (zero-copy references) only until
    the item's own header — u32 header length + JSON header — can be
    parsed; :func:`repro.core.serialization.declared_item_nbytes` then
    gives the item's total wire length and a ``bytearray`` of exactly
    that size is allocated once. Every further segment is copied
    straight into it at its offset, so a multi-chunk item costs one
    buffer and one copy instead of the old parts-list + ``b"".join``
    double copy. Single-segment items (item smaller than a chunk — the
    common case) are handed to the decoder as the received view, with
    no copy and no allocation at all.

    MemoryMeter accounting matches the single-buffer reality: one
    ``record_alloc`` for the assembled buffer (plus the transient
    pre-header segments), one ``record_free`` when the item is consumed.
    """

    __slots__ = ("_parts", "_parts_n", "_buf", "_filled", "_total")

    def __init__(self) -> None:
        self._parts: list = []
        self._parts_n = 0
        self._buf: Optional[bytearray] = None
        self._filled = 0
        self._total: Optional[int] = None

    @property
    def nbytes(self) -> int:
        """Live receive-buffer bytes held for the in-flight item."""
        return self._parts_n + (self._total or 0)

    def add(self, seg: Any, more_coming: bool = True) -> None:
        """One in-order segment of the current item. ``more_coming=False``
        marks segments of the item's final chunk: an item that completes
        before its header was ever parsed skips preallocation entirely —
        the common single-chunk item is handed to the decoder as the
        received view, zero-parse and zero-copy."""
        n = len(seg)
        if n == 0:
            return
        if self._buf is not None:
            if self._filled + n > self._total:
                raise ValueError(
                    f"item overflows its declared wire length {self._total} "
                    f"({self._filled + n} bytes received)"
                )
            self._buf[self._filled:self._filled + n] = seg
            mem.record_copy(n)
            self._filled += n
            return
        self._parts.append(seg)
        self._parts_n += n
        mem.record_alloc(n)
        if more_coming:
            self._try_prealloc()

    def _peek_prefix(self, n: int) -> bytes:
        out = bytearray()
        for p in self._parts:
            out += memoryview(p)[: n - len(out)]
            if len(out) >= n:
                break
        return bytes(out)

    def _try_prealloc(self) -> None:
        if self._parts_n < 4:
            return
        total = ser.declared_item_nbytes(
            self._parts[0] if len(self._parts) == 1
            else self._peek_prefix(min(self._parts_n, 4096))
        )
        if total is None or self._parts_n >= total:
            # header not parseable yet, or the item is already complete
            # in the buffered segments (no copy needed at all)
            return
        self._total = total
        self._buf = bytearray(total)
        mem.record_alloc(total)
        for p in self._parts:
            self._buf[self._filled:self._filled + len(p)] = p
            mem.record_copy(len(p))
            self._filled += len(p)
        mem.record_free(self._parts_n)
        self._parts.clear()
        self._parts_n = 0

    def complete(self) -> tuple[Any, int]:
        """Finish the item: returns ``(buffer, live_bytes)`` — the
        assembled bytes-like to decode from, and the metered bytes the
        caller must ``record_free`` once the decoded item is consumed."""
        if self._buf is not None:
            if self._filled != self._total:
                raise ValueError(
                    f"item ended at {self._filled} bytes but its header "
                    f"declared {self._total}"
                )
            out: Any = memoryview(self._buf)
            live = self._total
        elif len(self._parts) == 1:
            out, live = self._parts[0], self._parts_n
        elif self._parts:
            # unjoined scatter-gather parts: the decoders are
            # segment-aware (header from the leading segment,
            # ``frombuffer`` per payload segment), so a single-chunk
            # item keeps the sender's segment structure end to end —
            # no receive-side join, no copy
            out = list(self._parts)
            live = self._parts_n
        else:
            out, live = b"", 0
        self._parts = []
        self._parts_n = 0
        self._buf = None
        self._filled = 0
        self._total = None
        return out, live


class BlobReceiver:
    """Regular transmission receiver: accumulates the whole blob.

    Chunk segments are held by reference (zero-copy) and joined exactly
    once when EOF arrives — the single materialization the regular mode
    is defined by; there is no per-chunk copy and no second join.

    ``decode_container`` turns the reassembled blob into the result dict;
    the default is the plain serialization codec, and the wire pipeline
    substitutes its envelope-aware decoder.
    """

    def __init__(
        self,
        decode_container: Optional[Callable[[bytes], dict[str, Any]]] = None,
    ) -> None:
        self._parts: list = []
        self._size = 0
        self._decode = decode_container or ser.deserialize_container
        self.result: Optional[dict[str, Any]] = None

    def on_chunk(self, chunk: Chunk) -> None:
        self._parts.extend(chunk.segments)
        mem.record_alloc(chunk.nbytes)
        self._size += chunk.nbytes
        if chunk.eof:
            blob = b"".join(self._parts)
            mem.record_copy(len(blob))
            mem.record_alloc(len(blob))  # the one materialized copy
            self.result = self._decode(blob)
            mem.record_free(len(blob) + self._size)
            self._parts.clear()


class ContainerReceiver:
    """Container-streaming receiver: holds at most one item's bytes,
    reassembled into a single preallocated buffer (see
    :class:`_ItemAssembler`).

    ``consume`` receives each (name, value) as soon as its item completes
    — enabling *incremental* downstream processing (e.g. streaming FedAvg)
    without ever materializing the full dict. If ``consume`` is omitted the
    items are collected into ``result`` (arrays themselves must live
    somewhere; the *transmission* overhead stays one item).

    ``decode_item`` turns one reassembled item's buffer into ``(name,
    value, consumed)``; the default is the plain serialization codec, and
    the wire pipeline substitutes its envelope-aware decoder — stage
    decode then runs here, inside the streaming loop. Decoded arrays are
    ``frombuffer`` views into the assembled buffer (no decode copy).
    """

    def __init__(
        self,
        consume: Optional[Callable[[str, Any], None]] = None,
        decode_item: Optional[Callable[[bytes], tuple[str, Any, int]]] = None,
    ) -> None:
        self._asm = _ItemAssembler()
        self._consume = consume
        self._decode = decode_item or ser.deserialize_item
        self.result: dict[str, Any] = {}
        self.done = False

    def on_chunk(self, chunk: Chunk) -> None:
        for seg in chunk.segments:
            self._asm.add(seg, more_coming=not chunk.item_end)
        if chunk.item_end:
            buf, live = self._asm.complete()
            name, value, _ = self._decode(buf)
            if self._consume is not None:
                self._consume(name, value)
            else:
                self.result[name] = value
            mem.record_free(live)
        if chunk.eof:
            self.done = True


class FileReceiver:
    """File-streaming receiver: writes each chunk straight to disk."""

    def __init__(self, out_path: str) -> None:
        self.out_path = out_path
        self._fh = open(out_path, "wb")
        self.done = False

    def on_chunk(self, chunk: Chunk) -> None:
        with mem.record_hold(chunk.nbytes):
            for seg in chunk.segments:
                self._fh.write(seg)
        if chunk.eof:
            self._fh.close()
            self.done = True


# ---------------------------------------------------------------------------
# Streamers (senders)
# ---------------------------------------------------------------------------

def _chunk_iter(blob: bytes, chunk_size: int) -> Iterator[tuple[Any, bool]]:
    """Slice a contiguous blob into chunk payloads — memoryview slices,
    so chunking copies nothing."""
    mv = memoryview(blob)
    for off in range(0, len(blob), chunk_size):
        part = mv[off : off + chunk_size]
        yield part, off + chunk_size >= len(blob)
    if not blob:
        yield b"", True


def _chunk_iter_views(item: ser.ViewsLike, chunk_size: int) -> Iterator[tuple[Any, bool]]:
    """Chunk one scatter-gather item into payloads of exactly
    ``chunk_size`` bytes (except the last) **without joining**: each
    chunk payload is a single view or a tuple of views sliced from the
    item's segments. Chunk boundaries are byte-identical to slicing the
    joined item, so the wire format is unchanged."""
    total = ser.views_nbytes(item)
    if total == 0:
        yield b"", True
        return
    cur: list = []
    cur_n = 0
    emitted = 0
    for seg in ser.iter_view_segments(item):
        off = 0
        n = seg.nbytes
        while off < n:
            take = min(chunk_size - cur_n, n - off)
            cur.append(seg if take == n and off == 0 else seg[off:off + take])
            cur_n += take
            off += take
            if cur_n == chunk_size:
                emitted += chunk_size
                yield (cur[0] if len(cur) == 1 else tuple(cur)), emitted >= total
                cur = []
                cur_n = 0
    if cur_n:
        yield (cur[0] if len(cur) == 1 else tuple(cur)), True


# ---------------------------------------------------------------------------
# Encode-ahead (compute/IO overlap)
# ---------------------------------------------------------------------------

#: default encode-ahead depth for senders on real-IO transports (TCP,
#: the live-federation connection). 0 disables lookahead entirely — the
#: classic fully-sequential encode->send loop. Override per process
#: with ``REPRO_WIRE_PREFETCH``.
DEFAULT_ENCODE_AHEAD = int(os.environ.get("REPRO_WIRE_PREFETCH", "2"))

#: adaptive ceiling: queue memory is ~depth encoded items, so unbounded
#: growth would trade the container envelope's O(item) peak for latency
MAX_ENCODE_AHEAD = 8

_EA_DONE = object()


class AdaptiveEncodeAhead:
    """Adaptive depth controller for :func:`iter_encode_ahead`.

    Starts at :data:`DEFAULT_ENCODE_AHEAD` and grows by one — never past
    ``max_depth``, never below the default — each time a completed
    transfer's observed sender stall fraction (the ``wire.encode_wait_us``
    time the send loop spent starved, over the transfer's wall time)
    exceeds ``grow_threshold``: the encoder, not the socket, is the
    bottleneck, so a deeper lookahead buys real overlap. When the sender
    never starves the depth stays put — lookahead memory is ~depth
    encoded items and there is nothing to win.

    Depth only changes *between* transfers (each ``send_items`` reads it
    once), and every depth produces bitwise-identical wire bytes, so
    adaptation is invisible to the receiver. Thread-safe: one controller
    may be shared by several sender threads.
    """

    def __init__(self, depth: Optional[int] = None,
                 max_depth: int = MAX_ENCODE_AHEAD,
                 grow_threshold: float = 0.10) -> None:
        self._depth = DEFAULT_ENCODE_AHEAD if depth is None else int(depth)
        self.max_depth = int(max_depth)
        self.grow_threshold = float(grow_threshold)
        self.grown = 0
        self._lock = threading.Lock()

    @property
    def depth(self) -> int:
        with self._lock:
            return self._depth

    def observe(self, stall_s: float, wall_s: float) -> None:
        """Feed one completed transfer's total sender stall + wall time."""
        if wall_s <= 0.0:
            return
        with self._lock:
            if (stall_s / wall_s > self.grow_threshold
                    and self._depth < self.max_depth):
                self._depth += 1
                self.grown += 1
                depth = self._depth
            else:
                return
        reg = obs_metrics.ACTIVE
        if reg is not None:
            reg.gauge("wire.encode_ahead_depth").max(depth)


def iter_encode_ahead(
    items: Iterable[tuple[str, ser.ViewsLike]], depth: int,
    stall_sink: Optional[Callable[[float], None]] = None,
) -> Iterator[tuple[str, ser.ViewsLike]]:
    """Bounded-depth encode-ahead over a ``(name, item)`` encode iterator.

    A background thread drives the underlying iterator **strictly in
    order** — stateful stages (``delta``, ``crc32``, error-feedback
    quantize) observe items exactly as they would without lookahead —
    at most ``depth`` items ahead of the consumer. While the sender
    blocks in ``sendmsg`` for item k (a syscall that releases the GIL),
    the worker encodes item k+1, and any quantize it dispatched keeps
    computing on XLA's own threadpool. The same items flow to the
    consumer in the same order, so wire bytes are bitwise-identical to
    the sequential loop (pinned by the golden-hash suite).

    Queued items register with the active :class:`~repro.utils.mem.
    MemoryMeter` — they *are* live bytes — so the container envelope
    honestly reports the ~(1 + depth)-item peak the lookahead trades
    for overlap. Worker exceptions re-raise at the consumer; abandoning
    the iterator stops the worker promptly.

    Telemetry (when active): a ``wire.encode_wait_us`` histogram of
    sender stall time per item, a ``wire.encode_ahead_depth`` gauge,
    and ``wire.encode_ahead`` / ``wire.encode_wait`` spans on the
    worker / sender threads so a Perfetto trace shows encode-of-k+1
    overlapping tcp.send-of-k. ``stall_sink`` receives the same
    per-item sender-stall seconds the histogram observes, with no
    registry required — :class:`AdaptiveEncodeAhead` feeds on it.
    """
    if depth <= 0:
        yield from items
        return
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    err: list[BaseException] = []

    def _put(entry: Any) -> bool:
        while not stop.is_set():
            try:
                q.put(entry, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def pump() -> None:
        it = iter(items)
        try:
            while True:
                tr = obs_trace.ACTIVE
                if tr is None:
                    got = next(it, _EA_DONE)
                else:
                    with tr.span("wire.encode_ahead", "wire"):
                        got = next(it, _EA_DONE)
                if got is _EA_DONE:
                    return
                name, item = got
                nbytes = ser.views_nbytes(item)
                mem.record_alloc(nbytes)
                if not _put((name, item, nbytes)):
                    mem.record_free(nbytes)
                    return
        except BaseException as exc:  # noqa: BLE001 — re-raised at the consumer
            err.append(exc)
        finally:
            _put(_EA_DONE)

    worker = threading.Thread(target=pump, daemon=True,
                              name="wire-encode-ahead")
    worker.start()
    reg = obs_metrics.ACTIVE
    if reg is not None:
        reg.gauge("wire.encode_ahead_depth").max(depth)
    try:
        while True:
            tr = obs_trace.ACTIVE
            t0 = time.perf_counter()
            if tr is None:
                got = q.get()
            else:
                with tr.span("wire.encode_wait", "wire"):
                    got = q.get()
            if got is _EA_DONE:
                break
            wait_s = time.perf_counter() - t0
            reg = obs_metrics.ACTIVE
            if reg is not None:
                reg.histogram("wire.encode_wait_us").observe(wait_s * 1e6)
            if stall_sink is not None:
                stall_sink(wait_s)
            name, item, nbytes = got
            try:
                yield name, item
            finally:
                mem.record_free(nbytes)
    finally:
        stop.set()
        # join before draining: a put already in flight when the stop
        # flag was set may still land an item in the queue (the worker
        # re-checks stop only between put attempts), and items drained
        # must stop arriving before the drain runs or their metered
        # bytes leak
        worker.join(timeout=10.0)
        try:
            while True:
                got = q.get_nowait()
                if got is not _EA_DONE:
                    mem.record_free(got[2])
        except queue.Empty:
            pass
        if err:
            raise err[0]


class ObjectStreamer:
    """Regular transmission: whole container encoded, then chunked."""

    def __init__(self, driver: Driver, chunk_size: int = DEFAULT_CHUNK_SIZE) -> None:
        self.driver = driver
        self.chunk_size = chunk_size

    def send_blob(self, blob: bytes) -> bytes:
        """Chunk out an already-encoded blob (the caller registered its
        allocation; the streamer frees it once fully sent)."""
        sid = uuid.uuid4().bytes
        seq = 0
        for part, last in _chunk_iter(blob, self.chunk_size):
            self.driver.send(Chunk(sid, seq, part, FLAG_EOF if last else 0))
            seq += 1
        mem.record_free(len(blob))
        return sid

    def send_container(self, sd: Mapping[str, Any]) -> bytes:
        return self.send_blob(ser.serialize_container(sd))  # registers full-blob alloc


class ContainerStreamer:
    """Paper §III: transmit **one parameter-dict item at a time**.

    ``prefetch`` enables bounded-depth encode-ahead
    (:func:`iter_encode_ahead`): a worker thread encodes up to that many
    items past the one currently on the wire, overlapping quantize
    dispatch with socket writes. 0 (the default) keeps the classic
    fully-sequential loop — in-process loopback delivery has no IO to
    overlap, so only real-transport senders (the TCP driver, the live
    federation plane) opt in, typically at
    :data:`DEFAULT_ENCODE_AHEAD`. Passing an
    :class:`AdaptiveEncodeAhead` controller instead of an int reads the
    depth per transfer and feeds the observed sender stalls back, so
    repeated sends (the federation round loop) deepen the lookahead
    only when the encoder is the measured bottleneck.
    """

    def __init__(self, driver: Driver, chunk_size: int = DEFAULT_CHUNK_SIZE,
                 prefetch: Union[int, "AdaptiveEncodeAhead"] = 0) -> None:
        self.driver = driver
        self.chunk_size = chunk_size
        self.prefetch = prefetch

    def send_items(self, items: Iterable[tuple[str, ser.ViewsLike]], total: int) -> bytes:
        """Stream ``total`` pre-encoded items, framing item boundaries.

        The item source is any (name, item) iterator — the plain
        serialization codec or a wire pipeline's envelope encoder — and
        is consumed lazily, so peak live bytes stays ~one encoded item
        (~1 + ``prefetch`` items with encode-ahead on). Each item may be
        contiguous bytes or a scatter-gather view list
        (:data:`repro.core.serialization.Views`); views flow through to
        the driver unjoined.
        """
        adaptive = (self.prefetch
                    if isinstance(self.prefetch, AdaptiveEncodeAhead) else None)
        depth = adaptive.depth if adaptive is not None else self.prefetch
        stall = [0.0]
        if depth > 0:
            sink = None
            if adaptive is not None:
                def sink(s: float, _acc=stall) -> None:
                    _acc[0] += s
            items = iter_encode_ahead(items, depth, stall_sink=sink)
        t0 = time.perf_counter() if adaptive is not None else 0.0
        sid = uuid.uuid4().bytes
        seq = 0
        for i, (_name, item) in enumerate(items):
            last_item = i == total - 1
            for part, item_last in _chunk_iter_views(item, self.chunk_size):
                flags = 0
                if item_last:
                    flags |= FLAG_ITEM_END
                    if last_item:
                        flags |= FLAG_EOF
                self.driver.send(Chunk(sid, seq, part, flags))
                seq += 1
        if adaptive is not None:
            adaptive.observe(stall[0], time.perf_counter() - t0)
        return sid

    def send_container(self, sd: Mapping[str, Any]) -> bytes:
        return self.send_items(ser.iter_serialized_items(sd), len(sd))


class FileStreamer:
    """Paper §III: stream a file chunk-by-chunk (peak memory = chunk)."""

    def __init__(self, driver: Driver, chunk_size: int = DEFAULT_CHUNK_SIZE) -> None:
        self.driver = driver
        self.chunk_size = chunk_size

    def send_file(self, path: str) -> bytes:
        sid = uuid.uuid4().bytes
        size = os.path.getsize(path)
        seq = 0
        sent = 0
        with open(path, "rb") as fh:
            while True:
                part = fh.read(self.chunk_size)
                sent += len(part)
                last = sent >= size or not part
                with mem.record_hold(len(part)):
                    self.driver.send(Chunk(sid, seq, part, FLAG_EOF if last else 0))
                seq += 1
                if last:
                    break
        return sid


# ---------------------------------------------------------------------------
# ObjectRetriever (pull-mode, paper contribution 2)
# ---------------------------------------------------------------------------

class _ConsumeSink:
    """Adapts a plain ``consume(name, value)`` callback onto the
    streaming-sink protocol the wire decoder drives."""

    def __init__(self, consume: Callable[[str, Any], None]) -> None:
        self._consume = consume

    def begin(self, meta: Mapping[str, Any]) -> float:
        return float(meta.get("num_samples", 1))

    def accept_item(self, name: str, value: Any, weight: float) -> None:
        self._consume(name, value)


class ObjectRetriever:
    """Holder registers objects; peers retrieve them by id over a chosen

    streaming mode. This is the integration surface existing workflows use
    without restructuring their code around push-streaming callbacks.

    Pull-mode transfers take the same transform stack as the push wire:
    pass a :class:`~repro.core.pipeline.WirePipeline` (at construction or
    per ``retrieve``) and every container item runs the stage encode
    hooks on the holder side and the stage decode hooks on the retriever
    side, *inside* the streaming loop — a quantized+compressed pull peaks
    at ~one item, exactly like the push path. ``consume`` (incremental
    per-item delivery) and ``sink`` (the streaming-aggregator
    ``begin``/``accept_item`` protocol) both compose with a pipeline.
    """

    def __init__(self, chunk_size: int = DEFAULT_CHUNK_SIZE,
                 pipeline: Optional[Any] = None) -> None:
        self.chunk_size = chunk_size
        self.pipeline = pipeline
        self._registry: dict[str, tuple[str, Any]] = {}

    def register_container(self, obj_id: str, sd: Mapping[str, Any]) -> str:
        self._registry[obj_id] = ("container", sd)
        return obj_id

    def register_file(self, obj_id: str, path: str) -> str:
        self._registry[obj_id] = ("file", path)
        return obj_id

    def retrieve(
        self,
        obj_id: str,
        driver: Optional[Driver] = None,
        mode: str = "container",
        out_path: Optional[str] = None,
        consume: Optional[Callable[[str, Any], None]] = None,
        pipeline: Optional[Any] = None,
        sink: Optional[Any] = None,
    ) -> Any:
        kind, obj = self._registry[obj_id]
        driver = driver or LoopbackDriver()
        pipeline = pipeline if pipeline is not None else self.pipeline
        if consume is not None and sink is not None:
            raise ValueError("pass either consume= or sink=, not both")
        if kind == "file":
            if pipeline is not None:
                raise ValueError(
                    "file retrieval streams raw chunks; per-item pipeline "
                    "stages apply to container retrievals only"
                )
            assert out_path is not None, "file retrieval needs out_path"
            receiver: Any = FileReceiver(out_path)
            driver.connect(receiver.on_chunk)
            FileStreamer(driver, self.chunk_size).send_file(obj)
            driver.close()
            return out_path
        if pipeline is not None:
            return self._retrieve_pipelined(obj, driver, mode, pipeline, consume, sink)
        if mode != "container" and (consume is not None or sink is not None):
            raise ValueError(
                "regular (blob) retrieval reassembles the whole container; "
                "incremental consume=/sink= delivery needs mode='container'"
            )
        if sink is not None:
            consume = _SinkConsume(sink)
        if mode == "container":
            receiver = ContainerReceiver(consume=consume)
            driver.connect(receiver.on_chunk)
            ContainerStreamer(driver, self.chunk_size).send_container(obj)
            driver.close()
            return receiver.result if consume is None else None
        # regular one-shot
        receiver = BlobReceiver()
        driver.connect(receiver.on_chunk)
        ObjectStreamer(driver, self.chunk_size).send_container(obj)
        driver.close()
        return receiver.result

    def _retrieve_pipelined(self, sd: Mapping[str, Any], driver: Driver,
                            mode: str, pipeline: Any,
                            consume: Optional[Callable[[str, Any], None]],
                            sink: Optional[Any]) -> Any:
        # imported here, not at module level: streamers/receivers stay
        # codec-agnostic; only the pull-mode convenience surface knows
        # how to drive a pipeline end to end
        from repro.core.messages import Message, MessageKind

        if consume is not None:
            sink = _ConsumeSink(consume)
        msg = Message(MessageKind.TASK_DATA, dict(sd))
        enc, ctx = pipeline.begin_encode(msg)
        decoder = pipeline.decoder(sink=sink)
        if mode == "container":
            receiver: Any = ContainerReceiver(consume=decoder.on_item,
                                              decode_item=decoder.decode_item)
            driver.connect(receiver.on_chunk)
            ContainerStreamer(driver, self.chunk_size).send_items(
                pipeline.iter_encode_views(enc, ctx), pipeline.n_items(enc)
            )
        else:
            receiver = BlobReceiver(decode_container=decoder.decode_blob)
            driver.connect(receiver.on_chunk)
            ObjectStreamer(driver, self.chunk_size).send_blob(
                pipeline.encode_blob(enc, ctx)
            )
        driver.close()
        out = decoder.finish(msg.kind, pipeline.unsent_headers(enc))
        return out.payload if sink is None else None


class _SinkConsume:
    """Adapts a streaming sink onto the plain receiver ``consume``
    callback (pipeline-less pull path): opens the contribution on the
    first item with weight 1."""

    def __init__(self, sink: Any) -> None:
        self._sink = sink
        self._weight: Optional[float] = None

    def __call__(self, name: str, value: Any) -> None:
        if self._weight is None:
            self._weight = float(self._sink.begin({}))
        self._sink.accept_item(name, value, self._weight)
