"""Secure aggregation (paper §V: "explicitly demonstrate compatibility

with other privacy-preserving mechanisms").

Pairwise additive masking on an integer grid (Bonawitz et al. style, the
crypto exchanged out-of-band): each ordered client pair (i, j) derives a
shared mask stream from a common seed; client i adds it, client j
subtracts it, all arithmetic in int64 mod 2**32 over a fixed-point grid.
Individual Task Results are indistinguishable from noise at the server;
the *sum* telescopes exactly, so FedAvg over the unmasked grid values is
recovered bit-exactly.

Composition with the paper's stack: masking runs at TASK_RESULT_OUT
*after* any DP filter and *instead of* float quantization (SecAgg's grid
is itself an int representation — the wire carries int32, a 4x reduction
vs fp32, same as blockwise8). The server-side unmask+aggregate consumes
masked messages via :class:`SecureAggregator`.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Any

import numpy as np

from repro.core.filters import Filter
from repro.core.messages import Message

MOD = np.int64(1) << 32
SCALE = float(1 << 16)  # fixed-point: ~1.5e-5 resolution, +-32k range


def _pair_seed(base_seed: int, i: int, j: int, name: str, rnd: int) -> np.random.Generator:
    lo, hi = (i, j) if i < j else (j, i)
    return np.random.default_rng(
        abs(hash((base_seed, lo, hi, name, rnd))) % (2**63)
    )


def _to_grid(x: np.ndarray) -> np.ndarray:
    return np.round(np.asarray(x, np.float64) * SCALE).astype(np.int64) % MOD


def _from_grid(g: np.ndarray) -> np.ndarray:
    g = np.asarray(g, np.int64) % MOD
    g = np.where(g >= MOD // 2, g - MOD, g)  # recentre
    return (g.astype(np.float64) / SCALE).astype(np.float32)


class SecureMaskFilter(Filter):
    """Client-side: fixed-point encode + pairwise masks (mod 2^32)."""

    def __init__(self, client_index: int, all_clients: Sequence[int], base_seed: int = 0) -> None:
        self.client_index = client_index
        self.all_clients = list(all_clients)
        self.base_seed = base_seed

    def process(self, message: Message) -> Message:
        rnd = int(message.headers.get("round", 0))
        out: dict[str, Any] = {}
        for name, value in message.payload.items():
            arr = np.asarray(value)
            if not np.issubdtype(arr.dtype, np.floating):
                out[name] = value
                continue
            g = _to_grid(arr)
            for other in self.all_clients:
                if other == self.client_index:
                    continue
                mask = _pair_seed(self.base_seed, self.client_index, other, name, rnd).integers(
                    0, int(MOD), size=arr.shape, dtype=np.int64
                )
                if self.client_index < other:
                    g = (g + mask) % MOD
                else:
                    g = (g - mask) % MOD
            out[name] = g.astype(np.uint32)  # int32 wire (4 B/param)
        msg = message.replace_payload(out)
        msg.headers["secure_masked"] = True
        return msg


class SecureAggregator:
    """Server-side: sums masked grids (masks telescope to zero) and

    decodes the mean. Requires every configured client to report —
    the standard SecAgg liveness assumption."""

    def __init__(self, num_clients: int) -> None:
        self.num_clients = num_clients
        self._sum: dict[str, np.ndarray] = {}
        self._weights: list[float] = []
        self._extra: dict[str, Any] = {}

    def accept(self, result: Message) -> None:
        assert result.headers.get("secure_masked"), "SecureAggregator needs masked results"
        for name, value in result.payload.items():
            arr = np.asarray(value)
            if arr.dtype == np.uint32:
                g = arr.astype(np.int64)
                if name in self._sum:
                    self._sum[name] = (self._sum[name] + g) % MOD
                else:
                    self._sum[name] = g % MOD
            else:
                self._extra[name] = value
        self._weights.append(float(result.headers.get("num_samples", 1)))

    def finish(self) -> dict[str, np.ndarray]:
        if len(self._weights) != self.num_clients:
            raise RuntimeError(
                f"SecAgg needs all {self.num_clients} clients, got {len(self._weights)}"
            )
        out = {
            name: _from_grid(total) / self.num_clients
            for name, total in self._sum.items()
        }
        out.update(self._extra)
        self._sum = {}
        self._weights = []
        self._extra = {}
        return out
