"""Message quantization codecs — the paper's §II contribution.

A :class:`QuantizedTensor` is the wire representation of one parameter
tensor; :func:`quantize` / :func:`dequantize` convert arrays, and
:func:`quantize_state_dict` / :func:`dequantize_state_dict` convert whole
FL messages. Formats and their metadata layout follow bitsandbytes as
used by NVFlare 2.6 (paper Table II):

=============  ==========  =====================  ====================
format         payload     meta                   fp32 size
=============  ==========  =====================  ====================
fp16 / bf16    16-bit      —                      50.00 %
blockwise8     int8        fp32 absmax / 4096     25.03 %
fp4 / nf4      4-bit x2/B  fp32 absmax / 64       14.06 %
=============  ==========  =====================  ====================

Compute is delegated to ``repro.kernels.ops`` (Pallas on TPU, jnp ref on
CPU). Training/aggregation always run at original precision — codecs are
applied only at the four filter points (see ``repro.core.filters``).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

FORMATS = ("fp32", "fp16", "bf16", "blockwise8", "fp4", "nf4")
_CAST = {"fp16": jnp.float16, "bf16": jnp.bfloat16}
_BLOCKED = {"blockwise8", "fp4", "nf4"}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Wire format for one tensor: payload + quantization metadata."""

    payload: jnp.ndarray                 # int8 / uint8(packed) / fp16 / bf16 / fp32
    absmax: Optional[jnp.ndarray]        # per-block absmax (blocked formats)
    fmt: str
    orig_shape: tuple[int, ...]
    orig_dtype: Any

    # -- pytree protocol (so messages can cross jit/shard_map) -------------
    def tree_flatten(self):
        children = (self.payload, self.absmax)
        aux = (self.fmt, self.orig_shape, str(np.dtype(self.orig_dtype)))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape, dtype = aux
        return cls(children[0], children[1], fmt, tuple(shape), np.dtype(dtype))

    # -- accounting (paper Table II) ---------------------------------------
    @property
    def payload_bytes(self) -> int:
        return int(self.payload.size) * np.dtype(self.payload.dtype).itemsize

    @property
    def meta_bytes(self) -> int:
        if self.absmax is None:
            return 0
        return int(self.absmax.size) * np.dtype(self.absmax.dtype).itemsize

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.meta_bytes


def quantize(x: jnp.ndarray, fmt: str) -> QuantizedTensor:
    if fmt not in FORMATS:
        raise ValueError(f"unknown quantization format {fmt!r}; valid: {FORMATS}")
    shape, dtype = tuple(x.shape), x.dtype
    if fmt == "fp32":
        return QuantizedTensor(x.astype(jnp.float32), None, fmt, shape, dtype)
    if fmt in _CAST:
        # direct crop-and-cast (paper §II-D)
        return QuantizedTensor(x.astype(_CAST[fmt]), None, fmt, shape, dtype)
    if fmt == "blockwise8":
        q, absmax = ops.quantize_blockwise8(x)
        return QuantizedTensor(q, absmax, fmt, shape, dtype)
    # fp4 / nf4
    packed, absmax = ops.quantize_4bit(x, fmt)
    return QuantizedTensor(packed, absmax, fmt, shape, dtype)


def dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    fmt = qt.fmt
    if fmt == "fp32" or fmt in _CAST:
        return qt.payload.astype(qt.orig_dtype).reshape(qt.orig_shape)
    if fmt == "blockwise8":
        return ops.dequantize_blockwise8(qt.payload, qt.absmax, qt.orig_shape, qt.orig_dtype)
    return ops.dequantize_4bit(qt.payload, qt.absmax, fmt, qt.orig_shape, qt.orig_dtype)


# ---------------------------------------------------------------------------
# state-dict level (what the FL filters actually transform)
# ---------------------------------------------------------------------------

def quantize_state_dict(sd: Mapping[str, jnp.ndarray], fmt: str) -> dict[str, QuantizedTensor]:
    return {name: quantize(arr, fmt) for name, arr in sd.items()}


def dequantize_state_dict(qsd: Mapping[str, QuantizedTensor]) -> dict[str, jnp.ndarray]:
    return {name: dequantize(qt) for name, qt in qsd.items()}


def message_size_report(sd: Mapping[str, jnp.ndarray], fmt: str) -> dict[str, float]:
    """Byte accounting for one message under ``fmt`` **without** running

    the quantizer — pure arithmetic over shapes, used by the Table II
    benchmark and by the bandwidth planner. Matches the padded sizes the
    real codecs produce to within block-padding (<1 block per tensor).
    """
    mb = 1024.0 * 1024.0
    n_params = sum(int(np.prod(a.shape)) for a in sd.values())
    fp32_bytes = 4.0 * n_params
    if fmt == "fp32":
        payload, meta = fp32_bytes, 0.0
    elif fmt in ("fp16", "bf16"):
        payload, meta = 2.0 * n_params, 0.0
    elif fmt == "blockwise8":
        payload = 1.0 * n_params
        # absmax per 4096-block + bitsandbytes' per-tensor 256-entry fp32
        # dynamic code map (1 KiB) — included so Table II reproduces the
        # paper's 1.54 MB meta for the 147-layer Llama-3.2-1B dict.
        meta = 4.0 * sum(int(np.ceil(np.prod(a.shape) / 4096)) for a in sd.values())
        meta += 1024.0 * len(sd)
    elif fmt in ("fp4", "nf4"):
        payload = 0.5 * n_params
        meta = 4.0 * sum(int(np.ceil(np.prod(a.shape) / 64)) for a in sd.values())
    else:
        raise ValueError(fmt)
    return {
        "format": fmt,
        "model_mb": payload / mb,
        "meta_mb": meta / mb,
        "total_mb": (payload + meta) / mb,
        "fp32_pct": 100.0 * (payload + meta) / fp32_bytes,
    }
