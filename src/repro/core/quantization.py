"""Message quantization codecs — the paper's §II contribution.

A :class:`QuantizedTensor` is the wire representation of one parameter
tensor; :func:`quantize` / :func:`dequantize` convert arrays, and
:func:`quantize_state_dict` / :func:`dequantize_state_dict` convert whole
FL messages. Formats and their metadata layout follow bitsandbytes as
used by NVFlare 2.6 (paper Table II):

=============  ==========  =====================  ====================
format         payload     meta                   fp32 size
=============  ==========  =====================  ====================
fp16 / bf16    16-bit      —                      50.00 %
blockwise8     int8        fp32 absmax / 4096     25.03 %
fp4 / nf4      4-bit x2/B  fp32 absmax / 64       14.06 %
=============  ==========  =====================  ====================

Compute is delegated to ``repro.kernels.ops`` (Pallas on TPU, jnp ref on
CPU). Training/aggregation always run at original precision — codecs are
applied only at the four filter points (see ``repro.core.filters``).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.obs import trace as obs_trace

FORMATS = ("fp32", "fp16", "bf16", "blockwise8", "fp4", "nf4")
_CAST = {"fp16": jnp.float16, "bf16": jnp.bfloat16}
_BLOCKED = {"blockwise8", "fp4", "nf4"}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Wire format for one tensor: payload + quantization metadata."""

    payload: jnp.ndarray                 # int8 / uint8(packed) / fp16 / bf16 / fp32
    absmax: Optional[jnp.ndarray]        # per-block absmax (blocked formats)
    fmt: str
    orig_shape: tuple[int, ...]
    orig_dtype: Any

    # -- pytree protocol (so messages can cross jit/shard_map) -------------
    def tree_flatten(self):
        children = (self.payload, self.absmax)
        aux = (self.fmt, self.orig_shape, str(np.dtype(self.orig_dtype)))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape, dtype = aux
        return cls(children[0], children[1], fmt, tuple(shape), np.dtype(dtype))

    # -- accounting (paper Table II) ---------------------------------------
    @property
    def payload_bytes(self) -> int:
        return int(self.payload.size) * np.dtype(self.payload.dtype).itemsize

    @property
    def meta_bytes(self) -> int:
        if self.absmax is None:
            return 0
        return int(self.absmax.size) * np.dtype(self.absmax.dtype).itemsize

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.meta_bytes


def quantize(x: jnp.ndarray, fmt: str) -> QuantizedTensor:
    if fmt not in FORMATS:
        raise ValueError(f"unknown quantization format {fmt!r}; valid: {FORMATS}")
    shape, dtype = tuple(x.shape), x.dtype
    if fmt == "fp32":
        return QuantizedTensor(x.astype(jnp.float32), None, fmt, shape, dtype)
    if fmt in _CAST:
        # direct crop-and-cast (paper §II-D)
        return QuantizedTensor(x.astype(_CAST[fmt]), None, fmt, shape, dtype)
    if fmt == "blockwise8":
        q, absmax = ops.quantize_blockwise8(x)
        return QuantizedTensor(q, absmax, fmt, shape, dtype)
    # fp4 / nf4
    packed, absmax = ops.quantize_4bit(x, fmt)
    return QuantizedTensor(packed, absmax, fmt, shape, dtype)


def dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    fmt = qt.fmt
    if fmt == "fp32" or fmt in _CAST:
        return qt.payload.astype(qt.orig_dtype).reshape(qt.orig_shape)
    if fmt == "blockwise8":
        return ops.dequantize_blockwise8(qt.payload, qt.absmax, qt.orig_shape, qt.orig_dtype)
    return ops.dequantize_4bit(qt.payload, qt.absmax, fmt, qt.orig_shape, qt.orig_dtype)


# ---------------------------------------------------------------------------
# state-dict level (what the FL filters actually transform)
# ---------------------------------------------------------------------------

def quantize_state_dict(sd: Mapping[str, jnp.ndarray], fmt: str) -> dict[str, QuantizedTensor]:
    return {name: quantize(arr, fmt) for name, arr in sd.items()}


_BLOCK_OF = {"blockwise8": 4096, "fp4": 64, "nf4": 64}


def _fused_quantize_group(
    items: Mapping[str, Any], names: list[str], fmt: str
) -> dict[str, QuantizedTensor]:
    """One kernel dispatch for a whole format group: every tensor is
    padded to whole quant blocks (exactly the per-tensor wire layout)
    and laid back to back in one fp32 buffer, the blocked kernel runs
    once over all of it, and each tensor's payload/absmax are row
    slices of the single result. Block boundaries never span tensors,
    so the sliced payloads are bitwise-identical to quantizing each
    tensor alone.

    The concat buffer is O(group) *compute scratch* on the sender —
    the same order as the fp32 message the sender already holds, and
    deliberately outside the MemoryMeter, which tracks transmission
    buffers (those stay O(item) under container streaming)."""
    block = _BLOCK_OF[fmt]
    spans: list[tuple[str, Any, int, int]] = []   # name, arr, start, nblocks
    total = 0
    for name in names:
        arr = np.asarray(items[name])
        nb = int(np.ceil(arr.size / block))
        spans.append((name, arr, total, nb))
        total += nb
    big = np.zeros(total * block, np.float32)
    for _name, arr, start, _nb in spans:
        flat = np.ascontiguousarray(arr).reshape(-1)
        big[start * block: start * block + flat.size] = flat
    if fmt == "blockwise8":
        q, am = ops.quantize_blockwise8(big)
    else:
        q, am = ops.quantize_4bit(big, fmt)
    q_np, am_np = np.asarray(q), np.asarray(am)   # the one sync point
    return {
        name: QuantizedTensor(q_np[start:start + nb], am_np[start:start + nb],
                              fmt, tuple(arr.shape), arr.dtype)
        for name, arr, start, nb in spans
    }


def quantize_batch(
    items: Mapping[str, Any], fmt_for: Mapping[str, str]
) -> dict[str, QuantizedTensor]:
    """Whole-message quantization: one kernel dispatch **per format
    group** (all same-format tensors concatenated block-aligned), one
    device sync per message.

    This is the wire hot path's replacement for per-tensor
    dispatch-then-sync inside the streamer loop: serializing item k
    forced a device sync before item k+1 could even dispatch, so the
    host alternated between Python framing work and kernel waits — at
    LLM layer counts the dispatch overhead dominated the quantization
    compute several times over. ``fmt_for`` maps item name -> format;
    items absent from it pass through untouched. Results are
    bitwise-identical to calling :func:`quantize` per item — only the
    dispatch schedule changes (asserted by the golden-bytes suite).
    """
    out: dict[str, QuantizedTensor] = {}
    groups: dict[str, list[str]] = {}
    for name, value in items.items():
        fmt = fmt_for.get(name)
        if fmt is None:
            continue
        if fmt in _BLOCK_OF:
            groups.setdefault(fmt, []).append(name)
        else:  # fp32/fp16/bf16 casts: cheap host-side per-tensor work
            out[name] = quantize(np.asarray(value), fmt)
    tr = obs_trace.ACTIVE
    for fmt, names in groups.items():
        if tr is None:
            out.update(_fused_quantize_group(items, names, fmt))
        else:
            with tr.span("kernel.quantize_batch", "kernel", fmt=fmt,
                         items=len(names)):
                out.update(_fused_quantize_group(items, names, fmt))
    ops.block_until_ready([(qt.payload, qt.absmax) for qt in out.values()])
    return out


def _fused_dequantize_group(
    items: Mapping[str, Any], names: list[str], fmt: str
) -> dict[str, np.ndarray]:
    """Inverse of :func:`_fused_quantize_group`: payload/absmax rows of
    every same-format tensor are laid back to back and the blocked
    kernel runs once over the whole group. Block boundaries never span
    tensors, so the per-tensor slices are element-wise identical to
    dequantizing each tensor alone."""
    block = _BLOCK_OF[fmt]
    spans: list[tuple[str, QuantizedTensor, int, int]] = []  # name, qt, start, nblocks
    total = 0
    for name in names:
        qt = items[name]
        nb = int(qt.absmax.shape[0])
        spans.append((name, qt, total, nb))
        total += nb
    q_cat = np.concatenate([np.asarray(qt.payload) for _n, qt, _s, _nb in spans])
    am_cat = np.concatenate([np.asarray(qt.absmax) for _n, qt, _s, _nb in spans])
    if fmt == "blockwise8":
        flat = ops.dequantize_blockwise8(q_cat, am_cat, (total * block,), np.float32)
    else:
        flat = ops.dequantize_4bit(q_cat, am_cat, fmt, (total * block,), np.float32)
    flat_np = np.asarray(flat)   # the one sync point
    out: dict[str, np.ndarray] = {}
    for name, qt, start, _nb in spans:
        size = int(np.prod(qt.orig_shape)) if qt.orig_shape else 1
        out[name] = (
            flat_np[start * block: start * block + size]
            .reshape(qt.orig_shape)
            .astype(np.dtype(qt.orig_dtype), copy=False)
        )
    return out


def dequantize_batch(items: Mapping[str, Any]) -> dict[str, Any]:
    """Whole-message dequantization: one kernel dispatch **per format
    group**, one device sync per group — the receive-side mirror of
    :func:`quantize_batch`. Items that are not :class:`QuantizedTensor`
    (dense arrays, other wire kinds) pass through untouched; cast
    formats (fp32/fp16/bf16) are cheap per-tensor host work. Results
    are bitwise-identical to calling :func:`dequantize` per item —
    only the dispatch schedule changes."""
    out: dict[str, Any] = {}
    groups: dict[str, list[str]] = {}
    for name, value in items.items():
        if isinstance(value, QuantizedTensor) and value.fmt in _BLOCK_OF:
            groups.setdefault(value.fmt, []).append(name)
            out[name] = None   # placeholder keeps payload ordering stable
        elif isinstance(value, QuantizedTensor):
            out[name] = np.asarray(dequantize(value))
        else:
            out[name] = value
    tr = obs_trace.ACTIVE
    for fmt, names in groups.items():
        if tr is None:
            out.update(_fused_dequantize_group(items, names, fmt))
        else:
            with tr.span("kernel.dequantize_batch", "kernel", fmt=fmt,
                         items=len(names)):
                out.update(_fused_dequantize_group(items, names, fmt))
    return out


def dequantize_state_dict(qsd: Mapping[str, QuantizedTensor]) -> dict[str, jnp.ndarray]:
    return {name: dequantize(qt) for name, qt in qsd.items()}


def message_size_report(sd: Mapping[str, jnp.ndarray], fmt: str) -> dict[str, float]:
    """Byte accounting for one message under ``fmt`` **without** running

    the quantizer — pure arithmetic over shapes, used by the Table II
    benchmark and by the bandwidth planner. Matches the padded sizes the
    real codecs produce to within block-padding (<1 block per tensor).
    """
    mb = 1024.0 * 1024.0
    n_params = sum(int(np.prod(a.shape)) for a in sd.values())
    fp32_bytes = 4.0 * n_params
    if fmt == "fp32":
        payload, meta = fp32_bytes, 0.0
    elif fmt in ("fp16", "bf16"):
        payload, meta = 2.0 * n_params, 0.0
    elif fmt == "blockwise8":
        payload = 1.0 * n_params
        # absmax per 4096-block + bitsandbytes' per-tensor 256-entry fp32
        # dynamic code map (1 KiB) — included so Table II reproduces the
        # paper's 1.54 MB meta for the 147-layer Llama-3.2-1B dict.
        meta = 4.0 * sum(int(np.ceil(np.prod(a.shape) / 4096)) for a in sd.values())
        meta += 1024.0 * len(sd)
    elif fmt in ("fp4", "nf4"):
        payload = 0.5 * n_params
        meta = 4.0 * sum(int(np.ceil(np.prod(a.shape) / 64)) for a in sd.values())
    else:
        raise ValueError(fmt)
    return {
        "format": fmt,
        "model_mb": payload / mb,
        "meta_mb": meta / mb,
        "total_mb": (payload + meta) / mb,
        "fp32_pct": 100.0 * (payload + meta) / fp32_bytes,
    }
