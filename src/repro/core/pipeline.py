"""Unified wire pipeline: registry-driven, streaming-aware message transforms.

This module is the message plane's single composition surface. A
:class:`WirePipeline` is an ordered stack of :class:`Stage` objects that
executes **inside** the streaming loop, so a container-streamed,
NF4-quantized, zlib-compressed upload peaks at ~one item of transmission
memory instead of one model — the composition of the paper's two
contributions (§II-C quantization x §III streaming) that the legacy
``Filter``/``FilterChain`` layering could not express (filters
materialize the whole transformed payload before the streamer sees it).

Stage hooks, by granularity:

* **whole-message** — ``begin_encode`` (sender, before any item is
  serialized: stamp headers, pick a per-message format, or — legacy
  adapter only — replace the payload wholesale) and ``end_decode``
  (receiver, after the payload is reassembled).
* **per-item, value level** — ``encode_item`` / ``decode_item`` run on
  each payload tensor around the serialization boundary (quantize /
  dequantize, DP noise, secure-agg masking).
* **per-item, byte level** — ``encode_item_bytes`` / ``decode_item_bytes``
  run on each item's serialized bytes (compression, checksums); each
  application records a small metadata dict that travels in the item's
  wire envelope.

Wire format: when a pipeline has any per-item stage, each item is framed
as a self-describing **envelope**::

    envelope := hlen (u32 LE) | header (utf-8 JSON) | body
    header   := {"kind": "wire", "name": ..., "n": len(body),
                 "v": [value-stage names...],
                 "b": [[byte-stage name, meta], ...]}

so a receiver can undo the byte stages and (by default) the value stages
from the envelope alone, resolving stage names through the registry when
it has no pipeline instance of its own. A pipeline with no stages frames
items exactly like :func:`repro.core.serialization.serialize_item` —
byte-for-byte compatible with the pre-pipeline wire. Message headers
cross the wire as a leading ``meta`` item, so byte accounting includes
them.

Registry: ``@register_stage("quantize")`` binds a stage class to a spec
name; :func:`build_pipeline` turns declarative specs like
``["quantize:nf4", "zlib", "crc32"]`` into a pipeline, which is how
``fl/job.py`` job specs declare per-direction wire stacks and how
third-party stages plug in without touching core. The same pattern
registers transport drivers (``repro.core.streaming.register_driver``)
and scheduling policies (``repro.runtime.async_agg.register_policy``).

Legacy interop: :func:`legacy_wire_pipelines` adapts the deprecated
``Filter``/``FilterChain`` four-point configuration onto per-hop
pipelines via whole-message adapter stages; results are bitwise
identical to the old path, but the whole transformed payload is
materialized (and metered) before streaming — new code should use
registered stages instead.
"""
from __future__ import annotations

import json
import struct
import threading
import zlib as _zlib
from collections.abc import Callable, Iterator, Mapping
from typing import Any, Optional, Union

import numpy as np

from repro.core import serialization as ser
from repro.core import secure_agg as sa
from repro.core.filters import AdaptiveQuantizeFilter, Filter, FilterChain, FilterPoint
from repro.core.messages import Message, MessageKind
from repro.core.quantization import (
    QuantizedTensor,
    dequantize,
    quantize,
    quantize_batch,
)
from repro.core.sparse import SparseTensor, topk_sparsify
from repro.obs import trace as obs_trace
from repro.peft.lowrank import LowRankDelta
from repro.utils import mem

try:  # optional dependency: the zstd stage registers only when importable
    import zstandard as _zstd_mod
except ImportError:  # pragma: no cover - environment-dependent
    _zstd_mod = None

_U32 = struct.Struct("<I")

#: reserved item name carrying message kind + headers across the wire
META_ITEM = "__meta__"


class WireIntegrityError(ValueError):
    """A checksum stage rejected an item (corrupted bytes on the wire)."""


class WireContext:
    """Per-message state shared by every stage hook of one transfer.

    ``headers`` is the live header dict of the message being encoded (or
    the transmitted headers on the decode side); ``state`` is stage
    scratch space (e.g. the adaptive stage parks its per-message format
    choice); ``decode_values`` mirrors the owning pipeline's setting so
    value stages know whether their decode hook will run. ``vmeta`` is
    the *current item's* per-stage metadata dict: a value stage may write
    wire-visible keys into it during ``encode_item`` (the pipeline swaps
    in a fresh dict per stage per item) and reads the transmitted dict
    back during ``decode_item`` — how e.g. the ``delta`` stage keeps both
    ends of its residual stream in verified lockstep.
    """

    __slots__ = ("headers", "state", "decode_values", "vmeta")

    def __init__(self, headers: dict[str, Any], decode_values: bool = True) -> None:
        self.headers = headers
        self.state: dict[str, Any] = {}
        self.decode_values = decode_values
        self.vmeta: dict[str, Any] = {}


# ---------------------------------------------------------------------------
# Stage base + registry
# ---------------------------------------------------------------------------

class Stage:
    """One wire transform. Subclass and override any subset of hooks.

    ``name`` is the registry key (set by :func:`register_stage`) and what
    the wire envelope records, so it must be stable across versions.
    ``stateful`` stages (RNG streams, error-feedback residuals) are
    serialized under the simulator's filter lock when round trips run
    concurrently.
    """

    name: str = "stage"
    stateful: bool = False

    # -- whole-message hooks ------------------------------------------------
    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        return message

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        return message

    # -- per-item hooks, value level ----------------------------------------
    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return value

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return value

    # -- per-item hooks, byte level -----------------------------------------
    def encode_item_bytes(
        self, name: str, blob: bytes, meta: dict[str, Any], ctx: WireContext
    ) -> bytes:
        return blob

    def decode_item_bytes(
        self, name: str, blob: bytes, meta: Mapping[str, Any], ctx: WireContext
    ) -> bytes:
        return blob

    def encode_item_views(
        self, name: str, views: list, meta: dict[str, Any], ctx: WireContext
    ) -> list:
        """Scatter-gather form of ``encode_item_bytes``: transform an
        ordered list of buffer segments whose concatenation is the item's
        serialized bytes. The default joins only when the subclass
        actually overrides the bytes hook (compat for third-party
        stages); stages that can stream over the segments (checksums)
        override this and never join. Output bytes must equal what
        ``encode_item_bytes`` would produce on the joined input — the
        wire format does not know how the sender held its buffers."""
        if _overrides(self, "encode_item_bytes"):
            return [self.encode_item_bytes(name, ser.join_views(views), meta, ctx)]
        return views

    # -- spec support -------------------------------------------------------
    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> Stage:
        """Build from a job-spec entry; ``arg`` is the ``name:arg`` suffix."""
        if arg is not None:
            raise ValueError(f"stage {cls.name!r} takes no ':arg' (got {arg!r})")
        return cls(**kwargs)

    @classmethod
    def for_decode(cls) -> Stage:
        """A decode-capable instance for receivers that only know the
        stage *name* from a wire envelope (registry fallback). Override
        when ``__init__`` needs encode-side configuration the decode
        hooks don't use."""
        return cls.from_spec(None)


_STAGES: dict[str, type[Stage]] = {}


def register_stage(name: str) -> Callable[[type[Stage]], type[Stage]]:
    """Class decorator: bind ``name`` to a Stage class in the registry."""

    def deco(cls: type[Stage]) -> type[Stage]:
        if name in _STAGES:
            raise ValueError(f"stage name {name!r} already registered ({_STAGES[name]})")
        cls.name = name
        _STAGES[name] = cls
        return cls

    return deco


def registered_stages() -> tuple[str, ...]:
    return tuple(sorted(_STAGES))


StageSpec = Union[str, Mapping[str, Any], Stage]


def build_stage(spec: StageSpec) -> Stage:
    """``"quantize:nf4"`` | ``{"stage": "zlib", "level": 9}`` | Stage."""
    if isinstance(spec, Stage):
        return spec
    if isinstance(spec, str):
        name, _, arg = spec.partition(":")
        cls = _lookup(name)
        return cls.from_spec(arg or None)
    if isinstance(spec, Mapping):
        kwargs = dict(spec)
        name = kwargs.pop("stage")
        cls = _lookup(name)
        return cls.from_spec(kwargs.pop("arg", None), **kwargs)
    raise TypeError(f"bad stage spec {spec!r}")


def _lookup(name: str) -> type[Stage]:
    try:
        return _STAGES[name]
    except KeyError:
        raise ValueError(
            f"unknown stage {name!r}; registered: {registered_stages()}"
        ) from None


# ---------------------------------------------------------------------------
# Registered stages
# ---------------------------------------------------------------------------

def _is_quantizable(value: Any, min_params: int) -> bool:
    # already-wire-form containers pass through quantize untouched (their
    # factor/index payloads still compress under the byte stages)
    if isinstance(value, (QuantizedTensor, SparseTensor, LowRankDelta)):
        return False
    arr = np.asarray(value)
    return bool(
        np.issubdtype(arr.dtype, np.floating) and int(np.prod(arr.shape)) >= min_params
    )


def _prequantize(stage: Stage, message: Message, ctx: WireContext,
                 fmt_for_name: Callable[[str], Optional[str]],
                 min_params: int) -> None:
    """Batched quantize dispatch (the wire hot path): when ``stage`` is
    the pipeline's first value stage — i.e. its ``encode_item`` inputs
    are exactly the payload items visible here — quantize the whole
    message now, dispatching every tensor's kernel asynchronously and
    blocking once, and park the results for ``encode_item`` to pick up.
    Results are bitwise-identical to the per-item path; only the
    dispatch schedule changes. Falls back silently (per-item quantize in
    the streamer loop) whenever an earlier stage could rewrite items.
    """
    if ctx.state.get("vstage0") is not stage:
        return
    fmt_for = {
        name: fmt for name, value in message.payload.items()
        if (fmt := fmt_for_name(name)) is not None
        and _is_quantizable(value, min_params)
    }
    if not fmt_for:
        return
    pre = quantize_batch(message.payload, fmt_for)
    # keyed by (source value identity): a later whole-message stage may
    # swap the payload, in which case the parked results must not match
    ctx.state[("prequant", id(stage))] = {
        name: (message.payload[name], qt) for name, qt in pre.items()
    }


def _pop_prequant(stage: Stage, name: str, value: Any,
                  ctx: WireContext) -> Optional[QuantizedTensor]:
    pre = ctx.state.get(("prequant", id(stage)))
    if pre is None:
        return None
    ent = pre.get(name)
    if ent is not None and ent[0] is value:
        del pre[name]
        return ent[1]
    return None


@register_stage("quantize")
class QuantizeStage(Stage):
    """Per-item two-way quantization (paper §II-C) — spec ``quantize:nf4``.

    Encode quantizes each float tensor to ``fmt`` as it enters the
    streamer loop; decode recovers original precision item-by-item, so
    neither side ever holds a whole quantized model for transmission.
    Small/integer tensors pass through (same skip rule as the legacy
    :class:`~repro.core.filters.QuantizeFilter`).

    Per-layer precision (the :class:`~repro.core.filters.
    SelectiveQuantizeFilter` policy as a stage): ``rules`` is an ordered
    list of ``(substring, fmt)`` pairs — first matching rule decides the
    tensor's format, ``fmt`` covers the rest, and a rule format of
    ``None`` keeps the tensor at original precision. Spec forms::

        "quantize:nf4"                           # uniform
        "quantize:norm=fp16,embed=keep,nf4"      # rules + default
        {"stage": "quantize", "rules": [["norm", "fp16"], ["embed", null]],
         "fmt": "nf4"}

    (string rules: ``pattern=fmt`` entries, ``=keep``/empty fmt keeps
    original precision, a bare trailing token is the default format).
    """

    def __init__(self, fmt: Optional[str] = None, min_params: int = 0,
                 rules: Optional[list] = None) -> None:
        if not fmt and not rules:
            raise ValueError(
                'quantize stage needs a format and/or rules, e.g. "quantize:nf4"'
            )
        self.fmt = fmt
        self.min_params = min_params
        self.rules: list[tuple[str, Optional[str]]] = [
            (str(pat), f) for pat, f in (rules or [])
        ]

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> QuantizeStage:
        if arg and "=" in arg:
            rules: list[list[Optional[str]]] = []
            default: Optional[str] = None
            for part in arg.split(","):
                pat, eq, f = part.partition("=")
                if eq:
                    rules.append([pat, None if f in ("", "keep") else f])
                elif default is not None:
                    raise ValueError(
                        f"quantize rules spec {arg!r} names two default "
                        f"formats ({default!r} and {pat!r}); use pattern=fmt "
                        "entries plus at most one bare default"
                    )
                else:
                    default = pat or None
            kwargs.setdefault("fmt", default)
            kwargs.setdefault("rules", rules)
        elif arg:
            kwargs.setdefault("fmt", arg)
        return cls(**kwargs)

    @classmethod
    def for_decode(cls) -> QuantizeStage:
        # decode reads each QuantizedTensor's own fmt; the encode-side
        # format is irrelevant on the receiving end
        return cls("nf4")

    def _fmt_for(self, name: str) -> Optional[str]:
        for pat, fmt in self.rules:
            if pat in name:
                return fmt
        return self.fmt

    def _fmt_label(self) -> str:
        if not self.rules:
            return str(self.fmt)
        fmts = {f for _, f in self.rules if f}
        if self.fmt:
            fmts.add(self.fmt)
        return "mixed:" + ",".join(sorted(fmts))

    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        ctx.headers["quantized_fmt"] = self._fmt_label()
        _prequantize(self, message, ctx, self._fmt_for, self.min_params)
        return message

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        if ctx.decode_values:
            message.headers.pop("quantized_fmt", None)
        return message

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        pre = _pop_prequant(self, name, value, ctx)
        if pre is not None:
            return pre
        fmt = self._fmt_for(name)
        if fmt is None or not _is_quantizable(value, self.min_params):
            return value
        return quantize(np.asarray(value), fmt)

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return dequantize(value) if isinstance(value, QuantizedTensor) else value


@register_stage("ef-quantize")
class ErrorFeedbackQuantizeStage(Stage):
    """Quantize with error feedback (EF-SGD/EF21): transmits
    ``Q(x_t + e_{t-1})`` and keeps the residual per (client, tensor
    name) — one stage instance serves a whole hop direction, and the
    ``client`` header keeps each site's error stream independent.
    Stateful.
    """

    stateful = True

    def __init__(self, fmt: str, min_params: int = 0) -> None:
        self.fmt = fmt
        self.min_params = min_params
        self._residual: dict[tuple[str, str], np.ndarray] = {}

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> ErrorFeedbackQuantizeStage:
        fmt = arg or kwargs.pop("fmt", None)
        if not fmt:
            raise ValueError('ef-quantize stage needs a format, e.g. "ef-quantize:nf4"')
        return cls(fmt, **kwargs)

    @classmethod
    def for_decode(cls) -> ErrorFeedbackQuantizeStage:
        return cls("nf4")  # decode reads the wire tensor's own fmt

    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        ctx.headers["quantized_fmt"] = self.fmt
        ctx.headers["error_feedback"] = True
        return message

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        if ctx.decode_values:
            message.headers.pop("quantized_fmt", None)
        return message

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if not _is_quantizable(value, self.min_params):
            return value
        key = (str(ctx.headers.get("client", "")), name)
        arr = np.asarray(value, np.float32)
        corrected = arr + self._residual.get(key, 0.0)
        qt = quantize(corrected, self.fmt)
        self._residual[key] = corrected - np.asarray(dequantize(qt), np.float32)
        return qt

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return dequantize(value) if isinstance(value, QuantizedTensor) else value


@register_stage("adaptive")
class AdaptiveQuantizeStage(Stage):
    """Bandwidth-adaptive precision as a pipeline stage: the format is
    chosen once per message in ``begin_encode`` (from the ``client``
    header and the bound per-client link model), then applied item by
    item inside the streamer loop. The decision logic is shared with the
    legacy :class:`~repro.core.filters.AdaptiveQuantizeFilter`.
    """

    def __init__(
        self,
        bandwidth_bps: Optional[float] = None,
        budget_s: float = 1.0,
        min_params: int = 0,
        link_fn: Optional[Callable[[str], float]] = None,
    ) -> None:
        self._decider = AdaptiveQuantizeFilter(
            bandwidth_bps=bandwidth_bps, budget_s=budget_s,
            min_params=min_params, link_fn=link_fn,
        )
        self.min_params = min_params

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> AdaptiveQuantizeStage:
        kwargs.setdefault("bandwidth_bps", float(arg) if arg else 80e6)  # wifi-class
        return cls(**kwargs)

    def bind_network(self, network: Any) -> None:
        self._decider.bind_network(network)

    @property
    def last_fmt_by_client(self) -> dict[str, str]:
        return self._decider.last_fmt_by_client

    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        fmt = self._decider.fmt_for(message)
        self._decider.last_fmt = fmt
        self._decider.last_fmt_by_client[str(ctx.headers.get("client", ""))] = fmt
        ctx.state["adaptive_fmt"] = fmt
        if fmt != "fp32":
            ctx.headers["quantized_fmt"] = fmt
            _prequantize(self, message, ctx, lambda _name: fmt, self.min_params)
        return message

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        if ctx.decode_values:
            message.headers.pop("quantized_fmt", None)
        return message

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        pre = _pop_prequant(self, name, value, ctx)
        if pre is not None:
            return pre
        fmt = ctx.state.get("adaptive_fmt", "fp32")
        if fmt == "fp32" or not _is_quantizable(value, self.min_params):
            return value
        return quantize(np.asarray(value), fmt)

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return dequantize(value) if isinstance(value, QuantizedTensor) else value


@register_stage("dp-noise")
class DPNoiseStage(Stage):
    """Gaussian-mechanism DP noise, per item, at full precision — stack
    it *before* a quantize stage so noise is added pre-quantization.
    Decode is the identity (noise is the point). Stateful (RNG stream).
    """

    stateful = True

    def __init__(self, sigma: float, seed: int = 0) -> None:
        self.sigma = sigma
        self._rng = np.random.default_rng(seed)

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> DPNoiseStage:
        if arg is not None:
            kwargs.setdefault("sigma", float(arg))
        return cls(**kwargs)

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if isinstance(value, QuantizedTensor):
            return value
        arr = np.asarray(value)
        if not np.issubdtype(arr.dtype, np.floating):
            return value
        return arr + self._rng.normal(0.0, self.sigma, arr.shape).astype(arr.dtype)


@register_stage("secure-mask")
class SecureMaskStage(Stage):
    """Pairwise additive masking (Bonawitz-style), per item: fixed-point
    encode + per-pair mask streams keyed by the ``round`` header. Decode
    is the identity — the server's :class:`~repro.core.secure_agg.
    SecureAggregator` unmasks by summation, never per client.
    """

    def __init__(self, client_index: int, all_clients: list[int], base_seed: int = 0) -> None:
        self.client_index = client_index
        self.all_clients = list(all_clients)
        self.base_seed = base_seed

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> SecureMaskStage:
        if arg is not None:
            raise ValueError("secure-mask is configured per client; use dict spec kwargs")
        return cls(**kwargs)

    @classmethod
    def for_decode(cls) -> SecureMaskStage:
        return cls(0, [])  # decode is the identity: masked grids stay masked

    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        ctx.headers["secure_masked"] = True
        return message

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        arr = np.asarray(value)
        if isinstance(value, QuantizedTensor) or not np.issubdtype(arr.dtype, np.floating):
            return value
        rnd = int(ctx.headers.get("round", 0))
        g = sa._to_grid(arr)
        for other in self.all_clients:
            if other == self.client_index:
                continue
            mask = sa._pair_seed(self.base_seed, self.client_index, other, name, rnd).integers(
                0, int(sa.MOD), size=arr.shape, dtype=np.int64
            )
            g = (g + mask) % sa.MOD if self.client_index < other else (g - mask) % sa.MOD
        return g.astype(np.uint32)


@register_stage("zlib")
class ZlibStage(Stage):
    """Byte-level DEFLATE compression of each serialized item — spec
    ``zlib`` or ``zlib:9``. Composes after quantization (quantized
    payloads still compress: absmax metadata and repeated codes)."""

    def __init__(self, level: int = 6) -> None:
        self.level = level

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> ZlibStage:
        if arg is not None:
            kwargs.setdefault("level", int(arg))
        return cls(**kwargs)

    def encode_item_bytes(
        self, name: str, blob: bytes, meta: dict[str, Any], ctx: WireContext
    ) -> bytes:
        meta["n"] = len(blob)
        return _zlib.compress(blob, self.level)

    def encode_item_views(
        self, name: str, views: list, meta: dict[str, Any], ctx: WireContext
    ) -> list:
        # stream the deflate over the segments: bitwise-identical output
        # to one-shot zlib.compress (one zlib stream, one final flush),
        # without first joining the item
        meta["n"] = ser.views_nbytes(views)
        c = _zlib.compressobj(self.level)
        out = [c.compress(seg) for seg in ser.iter_view_segments(views)]
        out.append(c.flush())
        return [b"".join(out)]

    def decode_item_bytes(
        self, name: str, blob: bytes, meta: Mapping[str, Any], ctx: WireContext
    ) -> bytes:
        # the envelope-declared original length bounds decompression, so a
        # corrupted/hostile stream cannot expand past what it declared
        # (receivers can also inspect meta["n"] for policy before decode)
        n = meta.get("n")
        if n is None:
            return _zlib.decompress(blob)
        d = _zlib.decompressobj()
        out = d.decompress(blob, int(n))
        if not d.eof or d.unconsumed_tail or len(out) != int(n):
            raise WireIntegrityError(
                f"zlib stream for item {name!r} does not match its declared "
                f"length {n} (got {len(out)} bytes, eof={d.eof})"
            )
        return out


@register_stage("crc32")
class Crc32Stage(Stage):
    """Byte-level integrity check: stamps each item's CRC-32 into the
    envelope metadata; decode recomputes and raises
    :class:`WireIntegrityError` on mismatch."""

    def encode_item_bytes(
        self, name: str, blob: bytes, meta: dict[str, Any], ctx: WireContext
    ) -> bytes:
        meta["crc"] = _zlib.crc32(blob)
        return blob

    def encode_item_views(
        self, name: str, views: list, meta: dict[str, Any], ctx: WireContext
    ) -> list:
        # crc32 streams over the segments incrementally; the item's
        # buffers pass through untouched (the zero-copy integrity path)
        crc = 0
        for seg in ser.iter_view_segments(views):
            crc = _zlib.crc32(seg, crc)
        meta["crc"] = crc
        return views

    def decode_item_bytes(
        self, name: str, blob: bytes, meta: Mapping[str, Any], ctx: WireContext
    ) -> bytes:
        crc = _zlib.crc32(blob)
        if crc != meta.get("crc"):
            raise WireIntegrityError(
                f"crc32 mismatch on item {name!r}: wire carried {meta.get('crc')}, "
                f"received bytes hash to {crc}"
            )
        return blob


def _is_plain_float(value: Any) -> bool:
    if isinstance(value, (QuantizedTensor, SparseTensor, LowRankDelta)):
        return False
    return bool(np.issubdtype(np.asarray(value).dtype, np.floating))


@register_stage("delta")
class DeltaStage(Stage):
    """Residual (delta) encoding against the previous round's payload,
    keyed per (client, tensor): transmits ``x_t - x_{t-1}`` so a
    near-converged federation ships near-zero tensors — stack ``zlib``
    (or ``zstd``) after it and the wire cost collapses. Both ends are
    stateful: the encoder keeps the last value it transmitted per key,
    the decoder the last reconstruction — and when one instance serves
    both ends (the in-process wire) the two collapse to **one canonical
    snapshot object** per (client, tensor); the envelope's per-item
    ``vmeta`` records the stream position (``d``) and whether the item is
    a full snapshot (``full``, the first transmission per key or a shape
    change), so a desynchronized receiver raises
    :class:`WireIntegrityError` instead of reconstructing garbage.

    Compose with *lossless* downstream stages; after a lossy stage
    (``quantize``) the decoder's reconstruction drifts over rounds — use
    ``ef-quantize`` in that regime. Stateful (serialized under the
    simulator's filter lock; not usable on the async scheduler's
    streaming-aggregation path, which encodes every uplink twice).
    """

    stateful = True

    def __init__(self) -> None:
        self._prev_enc: dict[tuple[str, str], np.ndarray] = {}
        self._prev_dec: dict[tuple[str, str], np.ndarray] = {}
        self._seq_enc: dict[tuple[str, str], int] = {}
        self._seq_dec: dict[tuple[str, str], int] = {}

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if not _is_plain_float(value):
            return value
        key = (str(ctx.headers.get("client", "")), name)
        arr = np.asarray(value, np.float32)
        base = self._prev_enc.get(key)
        seq = self._seq_enc.get(key, 0)
        self._seq_enc[key] = seq + 1
        ctx.vmeta["d"] = seq
        if base is None or base.shape != arr.shape:
            ctx.vmeta["full"] = 1
            # snapshot by reference, not by copy: payload tensors are
            # immutable once handed to the wire (nothing in the encode
            # path writes into them), so a defensive copy per item only
            # doubled the snapshot memory
            self._prev_enc[key] = arr
            return arr
        delta = arr - base
        # track the *decoder's* reconstruction, not the raw stream: both
        # ends stay bit-identical forever and the per-round float32
        # rounding error never accumulates across rounds
        self._prev_enc[key] = base + delta
        return delta

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if not _is_plain_float(value):
            return value
        key = (str(ctx.headers.get("client", "")), name)
        seq = self._seq_dec.get(key, 0)
        pos = ctx.vmeta.get("d")
        if pos is None or int(pos) != seq:
            raise WireIntegrityError(
                f"delta stream for item {name!r} (client {key[0]!r}) is out "
                f"of sync: wire position {pos}, local position {seq}"
            )
        self._seq_dec[key] = seq + 1
        if ctx.vmeta.get("full"):
            full = np.asarray(value, np.float32)
        else:
            base = self._prev_dec.get(key)
            if base is None:
                raise WireIntegrityError(
                    f"delta stream for item {name!r} (client {key[0]!r}) "
                    "carries a residual but no base reconstruction exists "
                    "(missing 'full' snapshot)"
                )
            full = np.asarray(value, np.float32) + base
        # one canonical snapshot per (client, tensor): when this same
        # stage instance just encoded this stream position (the
        # in-process wire runs encode and decode through one object)
        # and the stream below delta was lossless, the encoder's
        # tracked reconstruction is bitwise-equal to ``full`` — adopt
        # it instead of keeping a second array alive. The equality
        # check matters: after a lossy downstream stage (quantize) the
        # two differ, and the decoder must keep its own reconstruction
        # so a shared instance behaves exactly like split endpoints.
        # Split encode/decode instances land in the else branch.
        enc = self._prev_enc.get(key)
        if (enc is not None and self._seq_enc.get(key) == seq + 1
                and enc.shape == full.shape and np.array_equal(enc, full)):
            self._prev_dec[key] = enc
        else:
            self._prev_dec[key] = full
        return full


@register_stage("topk")
class TopKStage(Stage):
    """Top-k magnitude sparsification — spec ``topk:0.05`` keeps the 5%
    largest-|x| entries of each float tensor and ships them as a
    :class:`~repro.core.sparse.SparseTensor` (indices + values); decode
    densifies with zeros elsewhere. Small tensors (< ``min_params``)
    pass through dense — sparsifying a bias vector costs more in indices
    than it saves. The per-item ``vmeta`` records kept/total counts for
    wire observability.
    """

    def __init__(self, fraction: float = 0.1, min_params: int = 256) -> None:
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"topk fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self.min_params = min_params

    @classmethod
    def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> TopKStage:
        if arg is not None:
            kwargs.setdefault("fraction", float(arg))
        return cls(**kwargs)

    def encode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        if not _is_plain_float(value):
            return value
        arr = np.asarray(value)
        if int(np.prod(arr.shape)) < self.min_params:
            return value
        sp = topk_sparsify(arr, self.fraction)
        ctx.vmeta["k"] = int(sp.values.size)
        ctx.vmeta["n"] = int(np.prod(sp.orig_shape))
        return sp

    def decode_item(self, name: str, value: Any, ctx: WireContext) -> Any:
        return value.to_dense() if isinstance(value, SparseTensor) else value


if _zstd_mod is not None:
    @register_stage("zstd")
    class ZstdStage(Stage):
        """Byte-level Zstandard compression of each serialized item —
        spec ``zstd`` or ``zstd:9``. Registered only when the
        ``zstandard`` package imports (the registry never advertises a
        stage the environment cannot decode). Same bounded-decompression
        discipline as :class:`ZlibStage`: the envelope-declared original
        length caps expansion and any mismatch raises
        :class:`WireIntegrityError`."""

        def __init__(self, level: int = 3) -> None:
            self.level = level
            # zstd contexts are not thread-safe and cost real setup time;
            # one stage instance serves concurrent transfers, so cache
            # one compressor/decompressor per thread instead of per item
            self._local = threading.local()

        def _ctxs(self) -> tuple[Any, Any]:
            if not hasattr(self._local, "c"):
                self._local.c = _zstd_mod.ZstdCompressor(level=self.level)
                self._local.d = _zstd_mod.ZstdDecompressor()
            return self._local.c, self._local.d

        @classmethod
        def from_spec(cls, arg: Optional[str] = None, **kwargs: Any) -> ZstdStage:
            if arg is not None:
                kwargs.setdefault("level", int(arg))
            return cls(**kwargs)

        def encode_item_bytes(
            self, name: str, blob: bytes, meta: dict[str, Any], ctx: WireContext
        ) -> bytes:
            meta["n"] = len(blob)
            return self._ctxs()[0].compress(blob)

        def decode_item_bytes(
            self, name: str, blob: bytes, meta: Mapping[str, Any], ctx: WireContext
        ) -> bytes:
            n = meta.get("n")
            if n is None:
                return self._ctxs()[1].decompress(blob)
            try:
                out = self._ctxs()[1].decompress(blob, max_output_size=int(n))
            except _zstd_mod.ZstdError as exc:
                # oversize (or otherwise malformed) streams surface as the
                # same wire-integrity fault undersize ones do
                raise WireIntegrityError(
                    f"zstd stream for item {name!r} does not decompress to "
                    f"its declared length {n}: {exc}"
                ) from exc
            if len(out) != int(n):
                raise WireIntegrityError(
                    f"zstd stream for item {name!r} does not match its "
                    f"declared length {n} (got {len(out)} bytes)"
                )
            return out


# ---------------------------------------------------------------------------
# Legacy Filter/FilterChain adapters (deprecated surface)
# ---------------------------------------------------------------------------

def _filter_is_stateful(filt: Filter) -> bool:
    """Whether a legacy filter needs the simulator's filter lock.

    Honors an explicit ``stateful`` attribute on the filter; the known
    stateless built-ins stream concurrently (pure per-message math), and
    unknown third-party filters default to stateful — the conservative
    choice the legacy simulator always made.
    """
    from repro.core import filters as _f
    from repro.core import secure_agg as _sa

    explicit = getattr(filt, "stateful", None)
    if explicit is not None:
        return bool(explicit)
    return not isinstance(
        filt,
        (_f.QuantizeFilter, _f.DequantizeFilter, _f.SelectiveQuantizeFilter,
         _f.AdaptiveQuantizeFilter, _sa.SecureMaskFilter),
    )


class FilterStage(Stage):
    """Adapter: run a legacy egress :class:`~repro.core.filters.Filter`
    as a whole-message hook.

    .. deprecated:: the whole transformed payload is materialized (and
       charged to the :class:`~repro.utils.mem.MemoryMeter`) before the
       streamer sees it — exactly the peak-memory envelope the pipeline
       exists to avoid. Use a registered per-item stage instead.
    """

    def __init__(self, filt: Filter) -> None:
        self.filter = filt
        self.name = f"filter:{type(filt).__name__}"
        self.stateful = _filter_is_stateful(filt)

    def begin_encode(self, message: Message, ctx: WireContext) -> Message:
        return self.filter.process(message)


class IngressFilterStage(Stage):
    """Adapter: run a legacy ingress Filter (e.g. ``DequantizeFilter``)
    after the payload is reassembled. Same deprecation note as
    :class:`FilterStage`."""

    def __init__(self, filt: Filter) -> None:
        self.filter = filt
        self.name = f"filter:{type(filt).__name__}"
        self.stateful = _filter_is_stateful(filt)

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        return self.filter.process(message)


def legacy_wire_pipelines(
    server_filters: Mapping[FilterPoint, FilterChain],
    client_filters: Mapping[FilterPoint, FilterChain],
) -> dict[str, WirePipeline]:
    """Map the deprecated four-point Filter configuration onto per-hop
    pipelines: each hop's egress chain becomes whole-message encode
    stages, the peer's ingress chain becomes whole-message decode
    stages (``end_decode`` hooks run in reverse pipeline order, so the
    ingress wrappers are appended reversed to preserve chain order).
    Results are bitwise identical to the legacy path.
    """

    def hop(egress: FilterChain, ingress: FilterChain) -> WirePipeline:
        stages: list[Stage] = [FilterStage(f) for f in egress.filters]
        stages += [IngressFilterStage(f) for f in reversed(ingress.filters)]
        return WirePipeline(stages)

    return {
        "task_data": hop(
            server_filters[FilterPoint.TASK_DATA_OUT],
            client_filters[FilterPoint.TASK_DATA_IN],
        ),
        "task_result": hop(
            client_filters[FilterPoint.TASK_RESULT_OUT],
            server_filters[FilterPoint.TASK_RESULT_IN],
        ),
    }


# ---------------------------------------------------------------------------
# WirePipeline
# ---------------------------------------------------------------------------

def _overrides(stage: Stage, hook: str) -> bool:
    return getattr(type(stage), hook) is not getattr(Stage, hook)


class WirePipeline:
    """An ordered stack of stages bound to one wire hop.

    Encode runs stages first-to-last; decode runs them last-to-first.
    ``decode_values=False`` leaves items in wire form (e.g. quantized
    server-side aggregation consumes :class:`QuantizedTensor` payloads
    directly); byte stages always decode — the items could not be parsed
    otherwise.
    """

    def __init__(self, stages: Optional[list[StageSpec]] = None, *,
                 decode_values: bool = True) -> None:
        self.stages: list[Stage] = [build_stage(s) for s in (stages or [])]
        self.decode_values = decode_values
        self._vstages = [s for s in self.stages if _overrides(s, "encode_item")
                         or _overrides(s, "decode_item")]
        self._bstages = [s for s in self.stages if _overrides(s, "encode_item_bytes")
                         or _overrides(s, "decode_item_bytes")
                         or _overrides(s, "encode_item_views")]
        self._by_name = {s.name: s for s in self.stages}

    @property
    def stateful(self) -> bool:
        return any(s.stateful for s in self.stages)

    def __repr__(self) -> str:
        return f"WirePipeline([{', '.join(s.name for s in self.stages)}])"

    # -- encode side --------------------------------------------------------
    def begin_encode(self, message: Message) -> tuple[Message, WireContext]:
        """Run whole-message hooks; returns the message to stream and the
        shared per-transfer context. ``ctx.state['held_bytes']`` is the
        payload size a legacy whole-message transform materialized (0 on
        the per-item path) — the wire charges it to the MemoryMeter for
        the duration of the transfer."""
        ctx = WireContext(message.headers, self.decode_values)
        original_payload = message.payload
        # the first value stage sees raw payload items, so it may batch
        # whole-message work (async quantize dispatch) in begin_encode
        ctx.state["vstage0"] = self._vstages[0] if self._vstages else None
        for s in self.stages:
            message = s.begin_encode(message, ctx)
            ctx.headers = message.headers
        ctx.state["held_bytes"] = (
            message.payload_bytes() if message.payload is not original_payload else 0
        )
        if META_ITEM in message.payload:
            raise ValueError(f"payload item name {META_ITEM!r} is reserved")
        return message, ctx

    def encode_wire_item_views(self, name: str, value: Any,
                               ctx: WireContext) -> ser.Views:
        """One payload item -> ordered envelope segments (the per-item
        hot path). Payload buffers stay zero-copy views end to end
        unless a byte stage rewrites them (compression)."""
        tr = obs_trace.ACTIVE
        if tr is None:
            vmetas: list[dict[str, Any]] = []
            for s in self._vstages:
                ctx.vmeta = {}
                value = s.encode_item(name, value, ctx)
                vmetas.append(ctx.vmeta)
            inner = ser.serialize_item_views(name, value)
            return self._wrap_views(name, inner, [s.name for s in self._vstages],
                                    ctx, vmetas=vmetas)
        with tr.span("wire.encode_item", "wire", item=name) as sp:
            vmetas = []
            for s in self._vstages:
                ctx.vmeta = {}
                with tr.span(f"stage.encode.{s.name}", "stage", item=name):
                    value = s.encode_item(name, value, ctx)
                vmetas.append(ctx.vmeta)
            inner = ser.serialize_item_views(name, value)
            views = self._wrap_views(name, inner, [s.name for s in self._vstages],
                                     ctx, vmetas=vmetas)
            sp.args["bytes_out"] = ser.views_nbytes(views)
            return views

    def encode_wire_item(self, name: str, value: Any, ctx: WireContext) -> bytes:
        """Joined-bytes form of :meth:`encode_wire_item_views` (compat /
        inspection surface; the streamers use the views directly)."""
        return ser.join_views(self.encode_wire_item_views(name, value, ctx))

    def _wrap_views(self, name: str, inner: ser.Views, vnames: list[str],
                    ctx: WireContext,
                    vmetas: Optional[list[dict[str, Any]]] = None) -> ser.Views:
        if not self._vstages and not self._bstages:
            return inner
        body = inner
        brecs: list[list[Any]] = []
        tr = obs_trace.ACTIVE
        for s in self._bstages:
            bmeta: dict[str, Any] = {}
            if tr is None:
                body = s.encode_item_views(name, body, bmeta, ctx)
            else:
                with tr.span(f"stage.encode.{s.name}", "stage", item=name,
                             bytes_in=ser.views_nbytes(body)) as sp:
                    body = s.encode_item_views(name, body, bmeta, ctx)
                    sp.args["bytes_out"] = ser.views_nbytes(body)
            brecs.append([s.name, bmeta])
        header = {"kind": "wire", "name": name, "n": ser.views_nbytes(body),
                  "v": vnames, "b": brecs}
        if vmetas and any(vmetas):
            # value-stage per-item metadata, aligned with "v"; omitted
            # entirely when no stage wrote any (keeps pre-existing
            # envelopes byte-identical)
            header["vm"] = vmetas
        hb = json.dumps(header, sort_keys=True).encode()
        return [_U32.pack(len(hb)) + hb, *body]

    def _encode_meta(self, message: Message, ctx: WireContext) -> ser.Views:
        body = json.dumps(
            {"kind": message.kind.value, "headers": _json_safe(message.headers)[0]},
            sort_keys=True,
        ).encode()
        header = json.dumps(
            {"kind": "meta", "name": META_ITEM, "n": len(body)}, sort_keys=True
        ).encode()
        inner = [_U32.pack(len(header)) + header + body]
        return self._wrap_views(META_ITEM, inner, [], ctx)

    def iter_encode_views(self, message: Message,
                          ctx: WireContext) -> Iterator[tuple[str, ser.Views]]:
        """Container-streaming producer (the hot path): the meta item,
        then one envelope per payload item, each as scatter-gather
        segments — peak live bytes stays ~one (encoded) item and tensor
        payloads cross the streamer without a single join."""
        views = self._encode_meta(message, ctx)
        with mem.record_hold(ser.views_nbytes(views)):
            yield META_ITEM, views
        for name, value in message.payload.items():
            views = self.encode_wire_item_views(name, value, ctx)
            with mem.record_hold(ser.views_nbytes(views)):
                yield name, views

    def iter_encode(self, message: Message,
                    ctx: WireContext) -> Iterator[tuple[str, bytes]]:
        """Joined-bytes form of :meth:`iter_encode_views` (compat /
        inspection surface — one envelope bytes object per item)."""
        for name, views in self.iter_encode_views(message, ctx):
            yield name, ser.join_views(views)

    def n_items(self, message: Message) -> int:
        return len(message.payload) + 1  # + meta item

    def encode_blob(self, message: Message, ctx: WireContext) -> bytes:
        """Regular-transmission producer: the whole wire message as one
        blob (peak ~ full payload; registered with the MemoryMeter).
        Joins exactly once, at the end, from the per-item segments."""
        parts: list[Any] = [_U32.pack(self.n_items(message))]
        for _, views in self.iter_encode_views(message, ctx):
            parts.extend(views)
        blob = b"".join(parts)
        mem.record_copy(len(blob))
        mem.record_alloc(len(blob))
        return blob

    def unsent_headers(self, message: Message) -> dict[str, Any]:
        """Headers that cannot cross the wire (not JSON-serializable);
        the in-process wire carries them around the transport."""
        return _json_safe(message.headers)[1]

    # -- decode side --------------------------------------------------------
    def decoder(self, sink: Optional[Any] = None) -> WireDecoder:
        """A per-transfer decoder; pass ``sink`` (the streaming-aggregator
        protocol: ``begin(meta) -> weight`` / ``accept_item(name, value,
        weight)``) to fold each decoded item downstream immediately
        instead of collecting a payload dict."""
        return WireDecoder(self, sink=sink)

    def _decode_stage(self, name: str) -> Stage:
        stage = self._by_name.get(name)
        if stage is None:  # receiver without the sender's pipeline: registry default
            stage = _lookup(name).for_decode()
            self._by_name[name] = stage
        return stage

    def decode_wire_item(self, buf: Any, ctx: WireContext) -> tuple[str, Any, int]:
        """Parse one envelope from the head of ``buf`` (any bytes-like —
        receivers hand in a memoryview over their single reassembly
        buffer — or a **list/tuple of segments**: an unjoined
        single-chunk item straight off a scatter-gather hop); returns
        ``(name, value, consumed)``. Body bytes are zero-copy slices and
        decoded arrays are ``frombuffer`` views — only the small JSON
        headers are materialized; a segmented item decodes with zero
        copies unless a field straddles a segment boundary. The meta
        item decodes to its header dict under the reserved name
        ``META_ITEM``."""
        tr = obs_trace.ACTIVE
        if tr is None:
            return self._decode_wire_item(buf, ctx)
        with tr.span("wire.decode_item", "wire") as sp:
            name, value, consumed = self._decode_wire_item(buf, ctx)
            sp.args["item"] = name
            sp.args["bytes_in"] = consumed
            return name, value, consumed

    def _decode_wire_item(self, buf: Any, ctx: WireContext) -> tuple[str, Any, int]:
        if isinstance(buf, (list, tuple)):
            return self._decode_wire_item_segments(buf, ctx)
        mv = buf if isinstance(buf, memoryview) else memoryview(buf)
        (hlen,) = _U32.unpack_from(mv, 0)
        header = json.loads(bytes(mv[4:4 + hlen]))
        kind = header.get("kind")
        if kind == "wire":
            n = header["n"]
            name = header["name"]
            body: Any = mv[4 + hlen:4 + hlen + n]
            name, value = self._decode_body(name, body, header, ctx)
            return name, value, 4 + hlen + n
        if kind == "meta":
            n = header["n"]
            return META_ITEM, json.loads(bytes(mv[4 + hlen:4 + hlen + n])), 4 + hlen + n
        return ser.deserialize_item(mv)

    def _decode_wire_item_segments(self, segs: Any,
                                   ctx: WireContext) -> tuple[str, Any, int]:
        """Segment-aware envelope parse: the header comes off the leading
        segment and the body stays an unjoined view list when no byte
        stage needs contiguity, so the inner decode is ``frombuffer``
        per segment — the zero-copy receive path."""
        cur = ser.SegmentCursor(segs)
        (hlen,) = _U32.unpack(bytes(cur.read(4)))
        header = json.loads(bytes(cur.read(hlen)))
        kind = header.get("kind")
        if kind == "wire":
            n = header["n"]
            name = header["name"]
            # byte stages (zlib, crc) consume contiguous bytes; without
            # them the body flows through as zero-copy segment views
            body: Any = cur.read(n) if header["b"] else cur.read_views(n)
            name, value = self._decode_body(name, body, header, ctx)
            return name, value, cur.consumed
        if kind == "meta":
            return META_ITEM, json.loads(bytes(cur.read(header["n"]))), cur.consumed
        return ser.deserialize_item(segs)

    def _decode_body(self, name: str, body: Any, header: Mapping[str, Any],
                     ctx: WireContext) -> tuple[str, Any]:
        """Undo byte stages, parse the inner item, undo value stages."""
        tr = obs_trace.ACTIVE
        for sname, bmeta in reversed(header["b"]):
            if tr is None:
                body = self._decode_stage(sname).decode_item_bytes(name, body, bmeta, ctx)
            else:
                with tr.span(f"stage.decode.{sname}", "stage", item=name):
                    body = self._decode_stage(sname).decode_item_bytes(name, body, bmeta, ctx)
        name, value = self._decode_inner(body, ctx)
        if self.decode_values:
            vmetas = header.get("vm") or [{}] * len(header["v"])
            for sname, vmeta in zip(reversed(header["v"]), reversed(vmetas)):
                ctx.vmeta = vmeta
                if tr is None:
                    value = self._decode_stage(sname).decode_item(name, value, ctx)
                else:
                    with tr.span(f"stage.decode.{sname}", "stage", item=name):
                        value = self._decode_stage(sname).decode_item(name, value, ctx)
        return name, value

    def _decode_inner(self, body: Any, ctx: WireContext) -> tuple[str, Any]:
        if isinstance(body, (list, tuple)):
            cur = ser.SegmentCursor(body)
            (hlen,) = _U32.unpack(bytes(cur.read(4)))
            header = json.loads(bytes(cur.read(hlen)))
            if header.get("kind") == "meta":
                return META_ITEM, json.loads(bytes(cur.read(header["n"])))
            name, value, _ = ser.deserialize_item(body)
            return name, value
        mv = body if isinstance(body, memoryview) else memoryview(body)
        (hlen,) = _U32.unpack_from(mv, 0)
        header = json.loads(bytes(mv[4:4 + hlen]))
        if header.get("kind") == "meta":
            n = header["n"]
            return META_ITEM, json.loads(bytes(mv[4 + hlen:4 + hlen + n]))
        name, value, _ = ser.deserialize_item(mv)
        return name, value

    def end_decode(self, message: Message, ctx: WireContext) -> Message:
        for s in reversed(self.stages):
            message = s.end_decode(message, ctx)
        return message


def _value_nbytes(value: Any) -> int:
    """Live bytes of one decoded payload value (QuantizedTensor /
    SparseTensor / array), for metering the streaming-fold hold."""
    total = getattr(value, "total_bytes", None)
    if total is not None:
        return int(total)
    try:
        return int(np.asarray(value).nbytes)
    except (TypeError, ValueError):
        return 0


class WireDecoder:
    """Receiver-side state for one transfer.

    Two consumption modes:

    * **collect** (default): payload items accumulate in ``self.payload``
      and ``finish`` assembles the full Message — the batch path.
    * **sink**: each decoded item is handed to ``sink.accept_item(name,
      value, weight)`` the moment it decodes, then dropped — the item is
      live (and metered) only for the duration of the fold. The leading
      meta item triggers ``sink.begin(headers) -> weight`` first, so the
      sink knows the contribution's sample weight before any tensor
      arrives. ``finish`` then returns a payload-less Message carrying
      the transmitted headers.
    """

    def __init__(self, pipeline: WirePipeline, sink: Optional[Any] = None) -> None:
        self.pipeline = pipeline
        self.ctx = WireContext({}, pipeline.decode_values)
        self.payload: dict[str, Any] = {}
        self.meta: Optional[dict[str, Any]] = None
        self._sink = sink
        self._sink_weight: Optional[float] = None

    # plugs into ContainerReceiver(decode_item=...); ``buf`` may be an
    # unjoined segment list (zero-copy single-chunk receive)
    def decode_item(self, buf: Any) -> tuple[str, Any, int]:
        return self.pipeline.decode_wire_item(buf, self.ctx)

    # plugs into ContainerReceiver(consume=...)
    def on_item(self, name: str, value: Any) -> None:
        if name == META_ITEM:
            self.meta = value
            self.ctx.headers.update(value.get("headers", {}))
            if self._sink is not None:
                self._sink_weight = float(
                    self._sink.begin(dict(value.get("headers", {})))
                )
        elif self._sink is not None:
            if self._sink_weight is None:
                # no meta item led the stream (bare pre-pipeline wire):
                # open the contribution with what headers we have
                self._sink_weight = float(self._sink.begin(dict(self.ctx.headers)))
            tr = obs_trace.ACTIVE
            if tr is None:
                with mem.record_hold(_value_nbytes(value)):
                    self._sink.accept_item(name, value, self._sink_weight)
            else:
                with tr.span("agg.accept_item", "agg", item=name,
                             nbytes=_value_nbytes(value)):
                    with mem.record_hold(_value_nbytes(value)):
                        self._sink.accept_item(name, value, self._sink_weight)
        else:
            self.payload[name] = value

    # plugs into BlobReceiver(decode_container=...)
    def decode_blob(self, blob: Any) -> dict[str, Any]:
        mv = blob if isinstance(blob, memoryview) else memoryview(blob)
        (n,) = _U32.unpack_from(mv, 0)
        off = 4
        for _ in range(n):
            name, value, consumed = self.decode_item(mv[off:])
            self.on_item(name, value)
            off += consumed
        return self.payload

    def finish(self, fallback_kind: MessageKind,
               local_headers: Optional[Mapping[str, Any]] = None) -> Message:
        """Assemble the received Message and run ``end_decode`` hooks.
        ``local_headers`` are non-wire-safe headers the in-process wire
        carries around the transport; transmitted headers win."""
        headers = dict(local_headers or {})
        kind = fallback_kind
        if self.meta is not None:
            headers.update(self.meta.get("headers", {}))
            kind = MessageKind(self.meta.get("kind", fallback_kind.value))
        msg = Message(kind, self.payload, headers)
        self.ctx.headers = msg.headers
        return self.pipeline.end_decode(msg, self.ctx)


def _json_safe(headers: Mapping[str, Any]) -> tuple[dict[str, Any], dict[str, Any]]:
    safe: dict[str, Any] = {}
    local: dict[str, Any] = {}
    for k, v in headers.items():
        try:
            json.dumps(v)
        except (TypeError, ValueError):
            local[k] = v
        else:
            safe[k] = v
    return safe, local


def build_pipeline(specs: Optional[list[StageSpec]], *, decode_values: bool = True) -> WirePipeline:
    """Declarative constructor: ``["quantize:nf4", "zlib", "crc32"]``."""
    return WirePipeline(list(specs or []), decode_values=decode_values)


# The lora stage lives in repro.peft (it carries model-plane semantics)
# but must register whenever the pipeline registry exists: both ends of a
# live federation fingerprint the *full* registry at the handshake, so a
# stage present on one side only would fail every connection. Imported at
# the bottom — the stage subclasses Stage and calls register_stage, both
# defined above; importing it at the top would close the cycle
# pipeline -> serialization -> peft.lowrank / peft.stage -> pipeline.
from repro.peft import stage as _peft_stage  # noqa: E402,F401
