"""Pallas TPU kernels for blockwise-int8 quantization (bitsandbytes style).

TPU adaptation (see DESIGN.md §3): bitsandbytes' CUDA kernels assign one
thread per element with a per-block reduction in shared memory. On TPU the
natural mapping is one VMEM tile of whole blocks per grid step: the input
is viewed as ``(nblocks, 4096)`` and each grid step loads a
``(ROWS, 4096)`` fp32 tile (128 KiB — comfortably inside the ~16 MiB VMEM
budget together with the int8 output tile), computes per-row absmax on the
VPU and writes the int8 codes. Block size 4096 is a multiple of the VPU
lane width (128), so rows map cleanly onto (8, 128) vregs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK8 = 4096
ROWS = 8  # blocks (rows) per grid step; (8, 4096) fp32 = 128 KiB VMEM


def _quantize_kernel(x_ref, q_ref, absmax_ref):
    x = x_ref[...].astype(jnp.float32)                      # (ROWS, BLOCK8)
    absmax = jnp.max(jnp.abs(x), axis=-1)                   # (ROWS,)
    scale = jnp.where(absmax > 0.0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(x * scale[:, None]), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    absmax_ref[...] = absmax.astype(jnp.float32)


def _dequantize_kernel(q_ref, absmax_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                      # (ROWS, BLOCK8)
    scale = absmax_ref[...].astype(jnp.float32) / 127.0     # (ROWS,)
    out_ref[...] = q * scale[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blockwise8_pallas(x2d: jnp.ndarray, *, interpret: bool = False):
    """x2d: (nblocks, BLOCK8) float; nblocks must be a multiple of ROWS."""
    nblocks = x2d.shape[0]
    assert x2d.shape[1] == BLOCK8 and nblocks % ROWS == 0, x2d.shape
    grid = (nblocks // ROWS,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, BLOCK8), jnp.int8),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_blockwise8_pallas(q: jnp.ndarray, absmax: jnp.ndarray, *, interpret: bool = False):
    nblocks = q.shape[0]
    assert q.shape[1] == BLOCK8 and nblocks % ROWS == 0, q.shape
    grid = (nblocks // ROWS,)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, BLOCK8), jnp.float32),
        interpret=interpret,
    )(q, absmax)
