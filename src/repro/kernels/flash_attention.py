"""Flash-attention Pallas TPU kernel (perf iteration 2 of §Perf pair 1).

The baseline HLO materializes the (S x S) fp32 score tensor through
mask/softmax — the dominant HBM-traffic term for every attention arch in
the dry-run roofline (EXPERIMENTS.md §Roofline). This kernel computes
attention with **online softmax over K/V tiles held in VMEM**: HBM
traffic drops from O(S^2) scores to O(S) q/k/v/out streams.

TPU adaptation: one grid step = one (batch, q-head, q-block). The
BlockSpec pins the q tile (block_q x hd) and the *whole* K/V stripe of
the matching KV head (S x hd — 8 MiB at S=32k, hd=128, bf16; within the
~16 MiB VMEM budget) and an inner ``fori_loop`` walks K/V in block_k
chunks carrying (m, l, acc) — the standard flash recurrence, with MXU
matmuls at (block_q x hd) x (hd x block_k). GQA maps q head h to KV head
h * KV // H in the index map. Causal and sliding-window masks are index
arithmetic, not materialized tensors.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
            window: Optional[int], sq: int, sk: int, block_q: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                    # (block_q, hd)
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    nk = sk // block_k

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], j * block_k, block_k).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], j * block_k, block_k).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # (block_q, block_k)
        k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (k_idx <= q_idx)
        if window is not None:
            mask = mask & (q_idx - k_idx < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd); H % KV == 0.

    Returns (B, H, Sq, hd) in q.dtype. Sq % block_q == 0, Sk % block_k == 0.
    """
    Bsz, H, sq, hd = q.shape
    KV, sk = k.shape[1], k.shape[2]
    assert H % KV == 0 and sq % block_q == 0 and sk % block_k == 0, (q.shape, k.shape)
    grid = (Bsz, H, sq // block_q)
    group = H // KV

    kernel = functools.partial(
        _kernel, block_k=block_k, causal=causal, window=window,
        sq=sq, sk=sk, block_q=block_q,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
