"""Public jit'd quantization ops: arbitrary-shape arrays in, blocked

payloads out, with backend dispatch (Pallas on TPU, Pallas-interpret for
kernel validation, pure-jnp ref elsewhere — same semantics everywhere,
enforced by tests/test_kernels_*.py).

Dispatch discipline (the wire hot path): each public op is a **single**
jitted computation covering flatten + pad + quantize, so one tensor
costs one XLA dispatch instead of a chain of eager reshape/astype/pad
dispatches followed by the kernel. ``jax.jit``'s compilation cache is
keyed by (shape, dtype) — the shape-bucketed cache: the first tensor of
a given shape compiles, every later layer of the same shape reuses the
executable. All ops dispatch **asynchronously**; callers that encode a
whole message batch their dispatches and block once via
:func:`block_until_ready` (see ``repro.core.quantization.
quantize_batch``) instead of syncing per tensor inside the streamer
loop.
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

# jitted ref-backend entry points (the ref functions build 15-compare /
# 16-select networks — uncompiled tracing per call would dominate on CPU)
_REF_Q8 = jax.jit(ref.quantize_blockwise8)
_REF_D8 = jax.jit(ref.dequantize_blockwise8)
_REF_Q4 = {
    fmt: jax.jit(functools.partial(ref.quantize_4bit, code=code))
    for fmt, code in (("fp4", ref.FP4_CODE), ("nf4", ref.NF4_CODE))
}
_REF_D4 = {
    fmt: jax.jit(functools.partial(ref.dequantize_4bit, code=code))
    for fmt, code in (("fp4", ref.FP4_CODE), ("nf4", ref.NF4_CODE))
}


def block_until_ready(values) -> None:
    """Barrier for a batch of async-dispatched op results (pytree of
    arrays; non-JAX leaves pass through untouched)."""
    jax.block_until_ready(values)


def _flat_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Traced flatten + fp32 cast + zero-pad to whole blocks (inside
    jit, so the whole chain is one fused executable per input shape)."""
    flat = jnp.asarray(x).reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = int(np.ceil(n / block)) * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // block, block)


# whole-op jitted entry points (ref backend): flatten/pad/quantize fused
_REF_Q8_FULL = jax.jit(lambda x: ref.quantize_blockwise8(_flat_blocks(x, ref.BLOCK8)))
_REF_Q4_FULL = {
    fmt: jax.jit(
        functools.partial(
            lambda x, code: ref.quantize_4bit(_flat_blocks(x, ref.BLOCK4), code),
            code=code,
        )
    )
    for fmt, code in (("fp4", ref.FP4_CODE), ("nf4", ref.NF4_CODE))
}


@functools.partial(jax.jit, static_argnames=("shape", "dtype"))
def _ref_d8_full(q, absmax, shape, dtype):
    out = ref.dequantize_blockwise8(q, absmax)
    n = int(np.prod(shape)) if shape else 1
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "shape", "dtype"))
def _ref_d4_full(packed, absmax, fmt, shape, dtype):
    code = ref.FP4_CODE if fmt == "fp4" else ref.NF4_CODE
    out = ref.dequantize_4bit(packed, absmax, code)
    n = int(np.prod(shape)) if shape else 1
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)
from repro.kernels.quant_blockwise8 import (
    BLOCK8,
    ROWS,
    dequantize_blockwise8_pallas,
    quantize_blockwise8_pallas,
)
from repro.kernels.quant_nf4 import (
    BLOCK4,
    ROWS4,
    dequantize_4bit_pallas,
    quantize_4bit_pallas,
)
from repro.kernels.fused_dequant_agg import (
    dequant_accumulate8_into_pallas,
    dequant_accumulate8_pallas,
)

#: valid backend selections (public: job specs validate against this)
BACKENDS = ("auto", "ref", "pallas", "pallas_interpret")
_BACKENDS = BACKENDS
_backend = os.environ.get("REPRO_KERNEL_BACKEND", "auto")


def set_backend(name: str) -> None:
    global _backend
    if name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {name!r}")
    _backend = name


def get_backend() -> str:
    if _backend != "auto":
        return _backend
    # Pallas compiled path on TPU; ref (identical semantics) on CPU hosts.
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@contextlib.contextmanager
def backend(name: str):
    """Scoped backend override: ``with ops.backend("pallas_interpret"):``.

    Restores the previous selection on exit, so tests and benchmarks can
    compare backends without mutating (and forgetting to restore) the
    module global."""
    global _backend
    prev = _backend
    set_backend(name)
    try:
        yield
    finally:
        _backend = prev


def _pad_to_blocks(flat: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    """Pad a flat fp32 vector to a whole number of quant blocks.

    Wire-format padding is one block max (<=16 KiB for int8, <=256 B for
    4-bit); the Pallas wrappers pad *rows* to their grid granularity
    internally and slice the result back, so grid alignment never inflates
    the transmitted message.
    """
    n = flat.shape[0]
    padded = int(np.ceil(n / block)) * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // block, block), n


def _pad_rows(x2d: jnp.ndarray, row_multiple: int) -> tuple[jnp.ndarray, int]:
    nblocks = x2d.shape[0]
    padded = int(np.ceil(nblocks / row_multiple)) * row_multiple
    if padded != nblocks:
        x2d = jnp.pad(x2d, ((0, padded - nblocks), (0, 0)))
    return x2d, nblocks


# ---------------------------------------------------------------------------
# blockwise int8
# ---------------------------------------------------------------------------

def quantize_blockwise8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Any-shape float array -> ((nblocks, 4096) int8, (nblocks,) absmax).

    One async jitted dispatch on the ref backend (flatten/pad/quantize
    fused; shape-bucketed by jit's compilation cache)."""
    backend = get_backend()
    if backend == "ref":
        return _REF_Q8_FULL(x)
    x2d, _ = _pad_to_blocks(jnp.asarray(x).reshape(-1).astype(jnp.float32), BLOCK8)
    nblocks = x2d.shape[0]
    x2d, _ = _pad_rows(x2d, ROWS)
    q, am = quantize_blockwise8_pallas(x2d, interpret=(backend == "pallas_interpret"))
    return q[:nblocks], am[:nblocks]


def dequantize_blockwise8(
    q: jnp.ndarray, absmax: jnp.ndarray, shape, dtype=jnp.float32
) -> jnp.ndarray:
    backend = get_backend()
    if backend == "ref":
        return _ref_d8_full(q, absmax, tuple(shape), np.dtype(dtype))
    nblocks = q.shape[0]
    q, _ = _pad_rows(q, ROWS)
    absmax = jnp.pad(absmax, (0, q.shape[0] - nblocks))
    out = dequantize_blockwise8_pallas(q, absmax, interpret=(backend == "pallas_interpret"))
    out = out[:nblocks]
    n = int(np.prod(shape))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# 4-bit (fp4 / nf4)
# ---------------------------------------------------------------------------

def quantize_4bit(x: jnp.ndarray, fmt: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Any-shape float array -> ((nblocks, 32) packed uint8, (nblocks,) absmax).

    One async jitted dispatch on the ref backend, like
    :func:`quantize_blockwise8`."""
    backend = get_backend()
    if backend == "ref":
        return _REF_Q4_FULL[fmt](x)
    x2d, _ = _pad_to_blocks(jnp.asarray(x).reshape(-1).astype(jnp.float32), BLOCK4)
    nblocks = x2d.shape[0]
    x2d, _ = _pad_rows(x2d, ROWS4)
    p, am = quantize_4bit_pallas(x2d, fmt=fmt, interpret=(backend == "pallas_interpret"))
    return p[:nblocks], am[:nblocks]


def dequantize_4bit(
    packed: jnp.ndarray, absmax: jnp.ndarray, fmt: str, shape, dtype=jnp.float32
) -> jnp.ndarray:
    backend = get_backend()
    if backend == "ref":
        return _ref_d4_full(packed, absmax, fmt, tuple(shape), np.dtype(dtype))
    nblocks = packed.shape[0]
    packed, _ = _pad_rows(packed, ROWS4)
    absmax = jnp.pad(absmax, (0, packed.shape[0] - nblocks))
    out = dequantize_4bit_pallas(
        packed, absmax, fmt=fmt, interpret=(backend == "pallas_interpret")
    )
    out = out[:nblocks]
    n = int(np.prod(shape))
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused server-side aggregation
# ---------------------------------------------------------------------------

def dequant_accumulate8(
    qs: jnp.ndarray, absmaxes: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    backend = get_backend()
    if backend == "ref":
        # On CPU the K-way einsum materializes a (K, nblocks, 4096) fp32
        # cast and benches *slower* than unfused (BENCH_5 speedup=0.22);
        # K donated in-place folds beat it and hold one fp32 buffer.
        qs = jnp.asarray(qs)
        absmaxes = jnp.asarray(absmaxes)
        weights = jnp.asarray(weights, jnp.float32)
        acc = jnp.zeros(qs.shape[1:], jnp.float32)
        for k in range(qs.shape[0]):
            acc = _REF_FOLD8(acc, qs[k], absmaxes[k], weights[k])
        return acc
    nblocks = qs.shape[1]
    padded = int(np.ceil(nblocks / ROWS)) * ROWS
    if padded != nblocks:
        qs = jnp.pad(qs, ((0, 0), (0, padded - nblocks), (0, 0)))
        absmaxes = jnp.pad(absmaxes, ((0, 0), (0, padded - nblocks)))
    out = dequant_accumulate8_pallas(
        qs, absmaxes, weights, interpret=(backend == "pallas_interpret")
    )
    return out[:nblocks]


# streaming fold: acc <- acc + w * dequant(q), accumulator donated so the
# fold never allocates (or leaves behind) an fp32 temporary per item
_REF_FOLD8 = jax.jit(
    lambda acc, q, absmax, w: acc
    + q.astype(jnp.float32) * ((absmax.astype(jnp.float32) / 127.0) * w)[:, None],
    donate_argnums=(0,),
)


def dequant_accumulate8_into(
    acc: jnp.ndarray | None, q: jnp.ndarray, absmax: jnp.ndarray, weight: float
) -> jnp.ndarray:
    """Fold one blockwise8 contribution into the running fp32 aggregate.

    ``acc`` is **donated**: the returned array reuses (aliases) its
    buffer, so a streaming aggregator's per-item fold is in-place — the
    dequantized contribution never materializes as a standalone fp32
    tensor. Pass ``acc=None`` to open the aggregate (returns
    ``weight * dequant(q)`` in a fresh buffer). ``q``: (nblocks, 4096)
    int8; ``absmax``: (nblocks,). The Pallas path may row-pad the
    accumulator; callers slice their flat view to the original element
    count (exactly like the other blocked ops).
    """
    backend = get_backend()
    if backend == "ref":
        if acc is None:
            acc = jnp.zeros(q.shape, jnp.float32)
        return _REF_FOLD8(acc, jnp.asarray(q), jnp.asarray(absmax),
                          jnp.float32(weight))
    nblocks = q.shape[0]
    q, _ = _pad_rows(q, ROWS)
    absmax = jnp.pad(absmax, (0, q.shape[0] - nblocks))
    if acc is None:
        acc = jnp.zeros(q.shape, jnp.float32)
    assert acc.shape == q.shape, (acc.shape, q.shape)
    return dequant_accumulate8_into_pallas(
        acc, q, absmax, jnp.float32(weight),
        interpret=(backend == "pallas_interpret"),
    )


# ---------------------------------------------------------------------------
# low-rank (LoRA) factorization
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("rank",))
def _ref_lowrank_decompose(x: jnp.ndarray, rank: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused truncated SVD: cast + decompose + truncate + canonicalize in
    one executable per input shape. The SVD's per-component sign is
    mathematically arbitrary; flipping each right-factor row so its
    largest-|x| entry is positive pins one canonical factorization, so
    the same tensor always decomposes to the same wire bytes."""
    u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    u, s, vt = u[:, :rank], s[:rank], vt[:rank, :]
    j = jnp.argmax(jnp.abs(vt), axis=1)
    signs = jnp.sign(vt[jnp.arange(rank), j])
    signs = jnp.where(signs == 0, jnp.float32(1.0), signs)
    a = u * (s * signs)[None, :]
    b = vt * signs[:, None]
    return a.astype(jnp.float32), b.astype(jnp.float32)


_REF_LOWRANK_MERGE = jax.jit(
    lambda a, b, scale: (a.astype(jnp.float32) @ b.astype(jnp.float32)) * scale
)


def low_rank_decompose(x: jnp.ndarray, rank: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(m, n)`` float array -> deterministic rank-``rank`` factors
    ``a (m, rank)``, ``b (rank, n)`` with ``a @ b`` the best (Eckart–
    Young) rank-``rank`` approximation of ``x``. Singular values are
    absorbed into ``a``; the factor signs are canonicalized so repeated
    calls on the same input are bitwise-identical (the wire's
    re-encode-equality contract).

    Backend note: every backend currently shares the fused ref jit —
    XLA has no Pallas-level SVD, so this entry point exists as the
    dispatch seam for a future randomized-subspace kernel, exactly like
    the quantize ops' ``backend == "ref"`` branches.
    """
    if rank < 1:
        raise ValueError(f"low-rank decompose needs rank >= 1, got {rank}")
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"low_rank_decompose takes a 2-D array, got shape {x.shape}")
    if rank > min(x.shape):
        raise ValueError(f"rank {rank} exceeds min dim of shape {x.shape}")
    return _ref_lowrank_decompose(x, int(rank))


def low_rank_merge(a: jnp.ndarray, b: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Merge a factor pair: ``scale * (a @ b)`` as one jitted fp32
    matmul dispatch (shape-bucketed like every other op here). Also the
    server-side fused aggregation primitive: concatenated factor blocks
    from K clients merge in one dispatch per tensor."""
    return _REF_LOWRANK_MERGE(jnp.asarray(a), jnp.asarray(b), jnp.float32(scale))
