"""Pallas TPU kernels for 4-bit codebook quantization (fp4 / nf4).

TPU adaptation (DESIGN.md §3): bitsandbytes' CUDA path binary-searches the
codebook per element and packs nibbles with warp shuffles. TPU has neither
fast per-element gathers in VREG nor warp shuffles, so:

* binning is a **branchless comparison network** — rank = sum over the 15
  sorted-codebook midpoints of (x > mid), then one gather over the
  16-entry permutation maps the rank to the original code index (the
  codebook is small enough to live in registers; the old 16-way
  ``jnp.where`` select chain cost ~4x more VPU passes for identical
  bits). All compares are full-width VPU ops.
* nibble packing uses an even/odd strided split of the code lane followed
  by ``hi << 4 | lo`` — a layout-friendly shuffle within a tile.

The input is viewed as ``(nblocks, 64)`` (4-bit block size 64). Each grid
step processes ``ROWS4 = 256`` blocks: a (256, 64) fp32 tile = 64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import FP4_CODE, NF4_CODE, _sorted_code_and_perm

BLOCK4 = 64
ROWS4 = 256  # blocks per grid step


def _make_quant_kernel(code: np.ndarray):
    sorted_code, _perm = _sorted_code_and_perm(code)
    mids = ((sorted_code[1:] + sorted_code[:-1]) / 2.0).tolist()

    def kernel(x_ref, perm_ref, packed_ref, absmax_ref):
        x = x_ref[...].astype(jnp.float32)                    # (R, 64)
        absmax = jnp.max(jnp.abs(x), axis=-1)                 # (R,)
        inv = jnp.where(absmax > 0.0, 1.0 / absmax, 0.0)
        xn = x * inv[:, None]
        rank = jnp.zeros(xn.shape, dtype=jnp.int32)
        for m in mids:                                        # 15 VPU compares
            rank = rank + (xn > m).astype(jnp.int32)
        # rank -> code index: one 16-entry LUT gather (bitwise == the old
        # 16-way select chain); the LUT rides in as a tiny kernel input
        # because Pallas kernels cannot capture array constants
        idx = perm_ref[...][rank]
        hi = idx[:, 0::2].astype(jnp.uint8)
        lo = idx[:, 1::2].astype(jnp.uint8)
        packed_ref[...] = (hi << 4) | lo
        absmax_ref[...] = absmax.astype(jnp.float32)

    return kernel


def _make_dequant_kernel():
    def kernel(packed_ref, absmax_ref, code_ref, out_ref):
        packed = packed_ref[...]                              # (R, 32) uint8
        hi = (packed >> 4).astype(jnp.int32)
        lo = (packed & 0xF).astype(jnp.int32)
        idx = jnp.stack([hi, lo], axis=-1).reshape(packed.shape[0], BLOCK4)
        # one 16-entry codebook gather (bitwise == the old select chain)
        vals = code_ref[...][idx]
        out_ref[...] = vals * absmax_ref[...].astype(jnp.float32)[:, None]

    return kernel


def _codebook(fmt: str) -> np.ndarray:
    if fmt == "fp4":
        return FP4_CODE
    if fmt == "nf4":
        return NF4_CODE
    raise ValueError(f"unknown 4-bit format: {fmt}")


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def quantize_4bit_pallas(x2d: jnp.ndarray, *, fmt: str, interpret: bool = False):
    """x2d: (nblocks, 64); nblocks must be a multiple of ROWS4."""
    nblocks = x2d.shape[0]
    assert x2d.shape[1] == BLOCK4 and nblocks % ROWS4 == 0, x2d.shape
    grid = (nblocks // ROWS4,)
    _, perm = _sorted_code_and_perm(_codebook(fmt))
    return pl.pallas_call(
        _make_quant_kernel(_codebook(fmt)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS4, BLOCK4), lambda i: (i, 0)),
            pl.BlockSpec((16,), lambda i: (0,)),  # rank->code LUT
        ],
        out_specs=[
            pl.BlockSpec((ROWS4, BLOCK4 // 2), lambda i: (i, 0)),
            pl.BlockSpec((ROWS4,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, BLOCK4 // 2), jnp.uint8),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, jnp.asarray(perm, dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def dequantize_4bit_pallas(
    packed: jnp.ndarray, absmax: jnp.ndarray, *, fmt: str, interpret: bool = False
):
    nblocks = packed.shape[0]
    assert packed.shape[1] == BLOCK4 // 2 and nblocks % ROWS4 == 0, packed.shape
    grid = (nblocks // ROWS4,)
    return pl.pallas_call(
        _make_dequant_kernel(),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS4, BLOCK4 // 2), lambda i: (i, 0)),
            pl.BlockSpec((ROWS4,), lambda i: (i,)),
            pl.BlockSpec((16,), lambda i: (0,)),  # codebook LUT
        ],
        out_specs=pl.BlockSpec((ROWS4, BLOCK4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, BLOCK4), jnp.float32),
        interpret=interpret,
    )(packed, absmax, jnp.asarray(_codebook(fmt), dtype=jnp.float32))
