"""Fused dequantize + weighted-accumulate Pallas kernel (server FedAvg).

Beyond-paper optimization (DESIGN.md §7): the paper dequantizes each
client's Task Result to fp32 *before* aggregation, so the server briefly
holds K fp32 copies. This kernel aggregates **directly from the int8
payloads**: each grid step loads the (K, ROWS, 4096) int8 tile of all K
clients (K * 32 KiB — tiny), folds the per-block absmax scales and FedAvg
weights into a (K, ROWS) scale matrix and contracts over K on the MXU.
Server-side peak memory drops from K x fp32-model to 1 x fp32-model, and
the dequantize pass fuses with the reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK8 = 4096
ROWS = 8


def _agg_kernel(q_ref, absmax_ref, w_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                       # (K, R, B)
    scale = absmax_ref[...].astype(jnp.float32) / 127.0      # (K, R)
    scale = scale * w_ref[...].astype(jnp.float32)[:, None]  # fold FedAvg w_k
    out_ref[...] = jnp.einsum(
        "krb,kr->rb", q, scale, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_accumulate8_pallas(
    qs: jnp.ndarray, absmaxes: jnp.ndarray, weights: jnp.ndarray, *, interpret: bool = False
):
    """qs: (K, nblocks, 4096) int8; absmaxes: (K, nblocks); weights: (K,).

    Returns (nblocks, 4096) fp32 = sum_k weights[k] * dequant(qs[k]).
    """
    K, nblocks, b = qs.shape
    assert b == BLOCK8 and nblocks % ROWS == 0, qs.shape
    grid = (nblocks // ROWS,)
    return pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, ROWS, BLOCK8), lambda i: (0, i, 0)),
            pl.BlockSpec((K, ROWS), lambda i: (0, i)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, BLOCK8), jnp.float32),
        interpret=interpret,
    )(qs, absmaxes, weights)


def _fold_kernel(acc_ref, q_ref, absmax_ref, w_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                       # (R, B)
    scale = absmax_ref[...].astype(jnp.float32) / 127.0      # (R,)
    scale = scale * w_ref[0].astype(jnp.float32)             # fold FedAvg w_k
    out_ref[...] = acc_ref[...] + q * scale[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def dequant_accumulate8_into_pallas(
    acc: jnp.ndarray, q: jnp.ndarray, absmax: jnp.ndarray, weight: jnp.ndarray,
    *, interpret: bool = False
):
    """Streaming fold: ``acc + weight * dequant(q)``, one contribution at
    a time, **into** the running fp32 accumulator.

    ``acc`` is donated and the output aliases it
    (``input_output_aliases={0: 0}``), so the per-item fold of the
    streaming aggregation plane updates the accumulator in place —
    no fp32 temporary of the dequantized contribution, no second
    accumulator allocation per fold. acc: (nblocks, 4096) fp32;
    q: (nblocks, 4096) int8; absmax: (nblocks,); weight: scalar.
    """
    nblocks, b = q.shape
    assert b == BLOCK8 and nblocks % ROWS == 0, q.shape
    assert acc.shape == q.shape, (acc.shape, q.shape)
    grid = (nblocks // ROWS,)
    w = jnp.reshape(weight, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        _fold_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROWS, BLOCK8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, BLOCK8), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc, q, absmax, w)
