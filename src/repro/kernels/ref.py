"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel's test sweeps
shapes/dtypes and asserts allclose against the function here. They are
also the fallback implementation on platforms where we don't run Pallas
(the codecs in ``repro.core.quantization`` call through ``ops.py`` which
dispatches pallas-vs-ref).

Quantization semantics follow bitsandbytes as used by the paper:

* ``blockwise8``  — symmetric linear int8 over absmax blocks of 4096
  (paper Table II: meta = 4 B absmax per 4096 params -> 1.54 MB for 1.5 G
  params).
* ``fp4`` / ``nf4`` — 4-bit codebook quantization over absmax blocks of 64,
  two codes packed per byte (paper Table II: meta = 4 B per 64 params ->
  89.33 MB).

All block math happens on a 2-D ``(num_blocks, block_size)`` view; callers
(ops.py) handle flattening/padding of arbitrary shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOCK8 = 4096  # blockwise-int8 block size (bitsandbytes default)
BLOCK4 = 64    # 4-bit block size (bitsandbytes / QLoRA default)

# bitsandbytes FP4 (E2M1-style) codebook, normalized to [-1, 1].
FP4_CODE = np.array(
    [
        0.0, 0.0052083333, 0.6666666667, 1.0,
        0.3333333333, 0.5, 0.1666666667, 0.25,
        -0.0, -0.0052083333, -0.6666666667, -1.0,
        -0.3333333333, -0.5, -0.1666666667, -0.25,
    ],
    dtype=np.float32,
)

# QLoRA NF4 codebook (information-theoretically optimal for N(0,1)).
NF4_CODE = np.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.7229568362236023, 1.0,
    ],
    dtype=np.float32,
)


def _sorted_code_and_perm(code: np.ndarray):
    """Sorted codebook + permutation mapping sorted-rank -> code index."""
    order = np.argsort(code, kind="stable")
    return code[order].astype(np.float32), order.astype(np.int32)


# ---------------------------------------------------------------------------
# blockwise int8
# ---------------------------------------------------------------------------

def quantize_blockwise8(x2d: jnp.ndarray):
    """x2d: (nblocks, BLOCK8) float -> (int8 codes, fp32 absmax per block)."""
    x2d = x2d.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(x2d * scale), -127, 127).astype(jnp.int8)
    return q, absmax[..., 0].astype(jnp.float32)


def dequantize_blockwise8(q: jnp.ndarray, absmax: jnp.ndarray) -> jnp.ndarray:
    """(nblocks, BLOCK8) int8 + (nblocks,) absmax -> fp32."""
    scale = absmax[..., None].astype(jnp.float32) / 127.0
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# 4-bit codebook (fp4 / nf4)
# ---------------------------------------------------------------------------

def _bin_codes(xnorm: jnp.ndarray, code: np.ndarray) -> jnp.ndarray:
    """Nearest-codebook-entry index (uint8 values 0..15) via midpoints.

    Branchless: rank = sum(x > midpoint_i), then a 16-entry gather maps
    the sorted rank back to the original codebook index. Same comparison
    network as the Pallas kernel.
    """
    sorted_code, perm = _sorted_code_and_perm(code)
    mids = (sorted_code[1:] + sorted_code[:-1]) / 2.0  # (15,)
    rank = jnp.zeros(xnorm.shape, dtype=jnp.int32)
    for m in mids.tolist():
        rank = rank + (xnorm > m).astype(jnp.int32)
    # map sorted-rank back to code index: one gather instead of a 16-way
    # select chain (bitwise-identical; perm[rank] == select(rank == r, p))
    idx = jnp.asarray(perm)[rank]
    return idx.astype(jnp.uint8)


def quantize_4bit(x2d: jnp.ndarray, code: np.ndarray):
    """x2d: (nblocks, BLOCK4) -> (packed uint8 (nblocks, BLOCK4//2), absmax)."""
    x2d = x2d.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    inv = jnp.where(absmax > 0, 1.0 / absmax, 0.0)
    xnorm = x2d * inv
    idx = _bin_codes(xnorm, code)
    hi = idx[..., 0::2]
    lo = idx[..., 1::2]
    packed = (hi.astype(jnp.uint8) << 4) | lo.astype(jnp.uint8)
    return packed, absmax[..., 0].astype(jnp.float32)


def dequantize_4bit(packed: jnp.ndarray, absmax: jnp.ndarray, code: np.ndarray) -> jnp.ndarray:
    """(nblocks, BLOCK4//2) packed + absmax -> (nblocks, BLOCK4) fp32."""
    hi = (packed >> 4).astype(jnp.int32)
    lo = (packed & 0xF).astype(jnp.int32)
    nb, half = packed.shape
    idx = jnp.stack([hi, lo], axis=-1).reshape(nb, half * 2)
    # vectorized codebook lookup: one 16-entry gather (bitwise-identical
    # to the old 16-way select chain, ~4x fewer VPU passes)
    vals = jnp.asarray(code, dtype=jnp.float32)[idx]
    return vals * absmax[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# fused dequantize + weighted accumulate (server-side FedAvg on quantized
# payloads; "beyond-paper": aggregation reads int8 directly, never
# materializing K fp32 copies)
# ---------------------------------------------------------------------------

def dequant_accumulate8(
    qs: jnp.ndarray, absmaxes: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """qs: (K, nblocks, BLOCK8) int8, absmaxes: (K, nblocks), weights: (K,)

    -> (nblocks, BLOCK8) fp32 = sum_k w_k * dequant(qs[k]).
    """
    scale = (absmaxes / 127.0) * weights[:, None]          # (K, nblocks)
    return jnp.einsum(
        "kbe,kb->be", qs.astype(jnp.float32), scale.astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# attention oracle (for the flash-attention kernel)
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal=True, window=None):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd); plain softmax attention."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32)) / np.sqrt(hd)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (qi - ki < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
