"""Fused sLSTM time-scan Pallas kernel (§Perf pair 2, final iteration).

The HLO form of the sLSTM recurrence round-trips every timestep's state
through HBM (4096 tiny fusions per layer — the dominant memory term of
xlstm-125m train_4k even after input-projection hoisting). The xLSTM
paper fuses the whole recurrence into one CUDA kernel; the TPU analogue
is this Pallas kernel:

* grid = (batch, seq_chunks) with the seq dimension **sequential**; the
  (c, n, h, m) state lives in VMEM scratch across grid steps (reset at
  chunk 0 of each batch row).
* each grid step streams one (chunk x 4 x D) slice of the hoisted gate
  pre-activations from HBM, runs `chunk` recurrence steps entirely in
  VMEM/VREGs (per-head (hd x hd) recurrent matmuls on the MXU), and
  streams the (chunk x D) hidden states out.

HBM traffic per layer drops from O(S x state x passes) round-trips to a
single gx read + h write: ~(4+1) x S x D x 4 B.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import pallas_tpu_compiler_params

DEFAULT_CHUNK = 256


def _kernel(gx_ref, r_ref, h_out_ref, c_s, n_s, h_s, m_s, *, chunk: int, H: int, hd: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _reset():
        c_s[...] = jnp.zeros_like(c_s)
        n_s[...] = jnp.zeros_like(n_s)
        h_s[...] = jnp.zeros_like(h_s)
        m_s[...] = jnp.full_like(m_s, -1e30)

    gx = gx_ref[0].astype(jnp.float32)          # (chunk, 4, H*hd)
    r = r_ref[...].astype(jnp.float32)          # (4, H, hd, hd)

    def step(t, carry):
        c, n, h, m = carry                      # each (H, hd)
        g_t = gx[t].reshape(4, H, hd)
        # recurrent part: per-head (1, hd_in) @ (hd_in, 4*hd_out) on the MXU
        rr = r.transpose(1, 2, 0, 3).reshape(H, hd, 4 * hd)  # (H, hd_in, gate*hd_out)
        gh = jax.lax.dot_general(
            h[:, None, :], rr,
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                        # (H, 1, 4*hd)
        gh = gh.reshape(H, 4, hd).transpose(1, 0, 2)  # (4, H, hd)
        z_in, i_in, f_in, o_in = g_t[0] + gh[0], g_t[1] + gh[1], g_t[2] + gh[2], g_t[3] + gh[3]
        z = jnp.tanh(z_in)
        o = jax.nn.sigmoid(o_in)
        logi = i_in
        logf = jax.nn.log_sigmoid(f_in)
        m_new = jnp.maximum(logf + m, logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        h_out_ref[0, t] = h_new.reshape(H * hd).astype(h_out_ref.dtype)
        return c_new, n_new, h_new, m_new

    init = (c_s[...], n_s[...], h_s[...], m_s[...])
    c, n, h, m = jax.lax.fori_loop(0, chunk, step, init)
    c_s[...] = c
    n_s[...] = n
    h_s[...] = h
    m_s[...] = m


@functools.partial(jax.jit, static_argnames=("num_heads", "chunk", "interpret"))
def slstm_scan_pallas(
    gx: jnp.ndarray,
    r: jnp.ndarray,
    *,
    num_heads: int,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """gx: (B, S, 4, D) hoisted gate pre-activations (gate order z,i,f,o);

    r: (4, H, hd, hd) recurrent weights. Returns hidden states (B, S, D)
    fp32. S % chunk == 0.
    """
    Bsz, S, four, D = gx.shape
    assert four == 4 and S % chunk == 0, gx.shape
    H = num_heads
    hd = D // H
    grid = (Bsz, S // chunk)
    kernel = functools.partial(_kernel, chunk=chunk, H=H, hd=hd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 4, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((4, H, hd, hd), lambda b, s: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((H, hd), jnp.float32),  # c
            pltpu.VMEM((H, hd), jnp.float32),  # n
            pltpu.VMEM((H, hd), jnp.float32),  # h
            pltpu.VMEM((H, hd), jnp.float32),  # m
        ],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(gx, r)
