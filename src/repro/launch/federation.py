"""Real multi-process federation over TCP — the live deployment plane.

The simulator proves the paper's quantization + streaming claims on a
simulated clock; this module proves them on a real one. One server
process opens a :class:`~repro.core.streaming.TCPServer` accept loop,
``N`` client subprocesses (``python -m repro.launch.federation
--client-index i --connect host:port``) connect, and real wall-clock
rounds run over the exact wire format, stage pipelines, and streaming
aggregators the simulator uses — driven by the *same* declarative job
spec ``run_job`` takes.

Equivalence guarantee
    With the default ``ordered`` uplink, the server grants uplinks in
    roster order and folds each client's decoded items into one live
    aggregator (``WireDecoder(sink=...)`` — O(item) server memory, never
    K models), executing **identical arithmetic in identical order** to
    the sequential simulator. Deterministic data partitioning + seeds
    make the client subprocesses compute the same local updates, so the
    final weights are **bitwise-equal** to ``run_job`` on the same spec
    (``--verify-sim`` asserts this; the ``live-smoke`` CI job runs it on
    every push). ``--uplink concurrent`` folds all uplinks at once from
    per-connection threads — maximum throughput, order-free arithmetic,
    so equality weakens to numerical closeness.

Protocol (PROTO 1)
    JSON control frames and raw chunk streams interleave on one socket
    (:class:`~repro.core.streaming.Connection`). A client opens with
    ``hello`` (name, round epoch, pipeline fingerprint); the server
    answers ``welcome`` or ``reject`` — a mismatched stage stack or a
    stale epoch fails fast at the handshake instead of corrupting a
    fold. Rounds then alternate ``task`` + downlink stream and ``grant``
    / ``result`` + uplink stream, ending with ``done``.

Crash/rejoin semantics
    A client dying mid-uplink must not register phantom weight: its
    ``begin`` already counted sample weight and its partial items are in
    the running sums, so the server discards the poisoned fold, rebuilds
    the aggregator, and re-grants the surviving roster in order
    (clients cache the round's result and re-encode on each grant;
    stateless pipelines make the re-encode deterministic). A crashed
    client may reconnect with the server's *current* round epoch and
    participates from the next downlink.

Fault tolerance
    With ``"quorum"`` set (e.g. ``{"quorum": 0.75,
    "straggler_grace_s": 30}``) a round no longer waits
    ``round_timeout_s`` on its slowest client: every uplink gets
    ``straggler_grace_s``; a client that exceeds it is marked a
    straggler, its late stream is drained and discarded on a background
    thread (the timeout-safe reader resumes mid-frame), and the round
    finishes over the contributors the server has — the streaming
    aggregators make partial folds natural, the fold just ``finish()``es
    early. Drained stragglers are re-invited next round. If the fold is
    still below quorum after the roster is exhausted, the server waits
    for drains to complete and re-grants (the client's cached round
    result is still valid), and only gives up when no straggler remains.
    ``FederationClient`` survives transient connection loss with capped
    exponential backoff + jitter (``max_reconnects`` budget); a decode /
    integrity failure (e.g. a corrupted chunk caught by crc32)
    quarantines the *client* and restarts the fold instead of killing
    the server. With a checkpoint directory configured the server
    atomically persists round epoch + global weights + roster after
    every round, and ``--resume`` restarts at round k+1 with
    bitwise-identical weights. ``ChaosProxy``
    (:mod:`repro.core.resilience`) injects seeded stall / blackhole /
    corrupt / throttle faults between real sockets to test all of it;
    ``reference_run`` replays the recorded per-round contributor sets
    sequentially and must match the live weights bitwise
    (``--verify-chaos``).
"""
from __future__ import annotations

import argparse
import contextlib
import hashlib
import json
import math
import os
import random
import socket
import subprocess
import sys
import tempfile
import threading
import time
from struct import error as struct_error
from typing import Any, Mapping, Optional

import numpy as np

from repro.checkpoint import latest_server_state, save_server_state
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.core.pipeline import WirePipeline, registered_stages
from repro.core.resilience import ChaosProxy
from repro.fl.aggregator import build_aggregator
from repro.fl.controller import make_task
from repro.fl.job import (
    aggregator_spec,
    build_client_executor,
    build_pipelines_from_spec,
    initial_weights,
    kernel_backend_scope,
    normalize_spec,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

PROTO = 1

#: uplink scheduling modes: "ordered" serializes grants in roster order
#: (one live fold, bitwise sim-equivalent); "concurrent" folds every
#: uplink at once from per-connection threads (throughput mode)
UPLINK_MODES = ("ordered", "concurrent")


def pipeline_fingerprint(pipelines: Mapping[str, WirePipeline],
                         agg_spec: Any) -> str:
    """Capability fingerprint exchanged at the handshake.

    Hashes everything that must agree for a fold to be meaningful: the
    protocol revision, each hop's stage stack and decode mode, the
    stage registry (a client with extra/missing registered stages could
    decode a task differently), and the aggregator selection. Two
    processes with equal fingerprints provably run the same wire stack.
    """
    desc = {
        "proto": PROTO,
        "stages": {d: [s.name for s in pl.stages]
                   for d, pl in sorted(pipelines.items())},
        "decode_values": {d: bool(pl.decode_values)
                          for d, pl in sorted(pipelines.items())},
        "registry": list(registered_stages()),
        "aggregator": agg_spec,
    }
    return hashlib.sha256(
        json.dumps(desc, sort_keys=True).encode()
    ).hexdigest()[:16]


def live_spec(spec: Mapping[str, Any], clients: Optional[int] = None,
              rounds: Optional[int] = None) -> dict[str, Any]:
    """Normalize + validate a job spec for live deployment.

    The live plane runs real processes on a real clock, so the pieces of
    the spec surface that only make sense inside the simulator are
    rejected up front: the ``runtime`` scenario block (simulated
    networks/availability), the legacy whole-message filter keys, and
    stateful pipelines (crash recovery re-encodes a cached result, which
    must be deterministic — error feedback / DP noise streams are not).

    ``"kernel_backend"`` passes through: the resolved spec ships to
    every client subprocess, so one key selects the quantize-kernel
    implementation on the server and all clients (payloads are
    bitwise-identical across backends, so mixed deployments still fold
    correctly — the key is a per-process performance knob).
    """
    out = normalize_spec(dict(spec))
    if clients is not None:
        out["clients"] = int(clients)
    if rounds is not None:
        out["rounds"] = int(rounds)
    if out.get("runtime"):
        raise ValueError(
            'the "runtime" block configures the *simulated* scenario engine '
            "(virtual networks, availability, async policies); the live plane "
            "runs real clients on a real clock — remove it from live specs"
        )
    if out.get("quantization") or out.get("dp_sigma"):
        raise ValueError(
            'live deployment requires the streaming "pipeline" form; the '
            'legacy "quantization"/"dp_sigma" filter keys are not supported'
        )
    if int(out["clients"]) < 1:
        raise ValueError(f'need at least one client, got {out["clients"]}')
    q = out.get("quorum")
    if q is not None and not 0.0 < float(q) <= 1.0:
        raise ValueError(f'"quorum" must be a fraction in (0, 1], got {q!r}')
    if float(out.get("straggler_grace_s") or 0.0) <= 0.0:
        raise ValueError(
            f'"straggler_grace_s" must be positive, got '
            f'{out.get("straggler_grace_s")!r}')
    if int(out.get("max_reconnects") or 0) < 0:
        raise ValueError(
            f'"max_reconnects" must be >= 0, got {out.get("max_reconnects")!r}')
    pipelines = build_pipelines_from_spec(out)
    for direction, pl in pipelines.items():
        if pl.stateful:
            stateful = [s.name for s in pl.stages if s.stateful]
            raise ValueError(
                f"stateful stage(s) {stateful} in {direction!r}: live crash "
                "recovery re-encodes cached results, which requires "
                "deterministic (stateless) pipelines"
            )
    return out


def weights_bitwise_equal(a: Mapping[str, Any], b: Mapping[str, Any]) -> bool:
    """True iff two flat state dicts are bitwise-identical."""
    if set(a) != set(b):
        return False
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if x.tobytes() != y.tobytes():
            return False
    return True


class _ClientLost(Exception):
    """One client's connection failed mid-round (carries the name).

    ``poisoned`` says whether any of its items already reached the
    running aggregation (the fold must then restart); ``quarantine``
    marks integrity/decode failures — the *client* sent garbage, the
    link is irrelevant, so the failure is recorded as a quarantine
    rather than a transport loss."""

    def __init__(self, name: str, why: str, *, poisoned: bool = True,
                 quarantine: bool = False) -> None:
        super().__init__(f"{name}: {why}")
        self.client = name
        self.why = why
        self.poisoned = poisoned
        self.quarantine = quarantine


class _Straggled(Exception):
    """A client exceeded ``straggler_grace_s`` mid-uplink (quorum mode).

    ``stage`` is where the grace expired (``"result"``: the grant went
    out but no result control frame came back; ``"stream"``: mid chunk
    stream) — the drain thread needs it to know what is still inbound.
    ``poisoned`` mirrors :class:`_ClientLost`."""

    def __init__(self, name: str, stage: str, *, poisoned: bool) -> None:
        super().__init__(f"{name}: straggled at {stage}")
        self.client = name
        self.stage = stage
        self.poisoned = poisoned


class _StaleEpoch(Exception):
    """Handshake reject carrying the server's current round — the
    client retries immediately at the right epoch (a redirect, not a
    fault)."""

    def __init__(self, round_: int) -> None:
        super().__init__(f"server is at round {round_}")
        self.round = round_


class FederationServer:
    """The live server: accept loop, handshakes, real wall-clock rounds.

    Owns a :class:`~repro.core.streaming.TCPServer`; every accepted
    connection handshakes on its own thread, then round logic drives all
    traffic — per-client downlink sender threads, and either ordered
    grant-serialized uplinks (default, sim-bitwise) or concurrent
    per-connection fold threads. Server memory stays O(item): each uplink
    decodes straight into the shared streaming aggregator via
    ``WireDecoder(sink=...)`` — no client payload dict ever materializes.
    """

    def __init__(self, spec: Mapping[str, Any], host: str = "127.0.0.1",
                 port: int = 0, uplink: str = "ordered",
                 join_timeout_s: float = 60.0,
                 round_timeout_s: float = 600.0,
                 handshake_timeout_s: float = 10.0,
                 checkpoint_dir: Optional[str] = None,
                 resume: bool = False) -> None:
        if uplink not in UPLINK_MODES:
            raise ValueError(f"uplink mode {uplink!r}; valid: {UPLINK_MODES}")
        self.spec = live_spec(spec)
        self.n_clients = int(self.spec["clients"])
        self.rounds = int(self.spec["rounds"])
        self.chunk_size = int(self.spec["chunk_mb"] * (1 << 20))
        self.pipelines = build_pipelines_from_spec(self.spec)
        self.agg_spec = aggregator_spec(self.spec)
        self.fingerprint = pipeline_fingerprint(self.pipelines, self.agg_spec)
        self.uplink = uplink
        self.join_timeout_s = join_timeout_s
        self.round_timeout_s = round_timeout_s
        self.handshake_timeout_s = handshake_timeout_s
        q = self.spec.get("quorum")
        self.quorum = None if q is None else float(q)
        self.straggler_grace_s = float(self.spec["straggler_grace_s"])
        self.checkpoint_dir = (checkpoint_dir if checkpoint_dir is not None
                               else self.spec.get("checkpoint"))
        self._server = sm.TCPServer(host, port)
        self.address = self._server.address
        self._lock = threading.Lock()
        self._join_cv = threading.Condition(self._lock)
        # drain bookkeeping shares the lock: a straggler whose late
        # uplink is still being discarded must not be re-granted or
        # re-rostered until its socket is clean again
        self._drain_cv = threading.Condition(self._lock)
        self._conns: dict[str, sm.Connection] = {}
        self._lost: set[str] = set()
        self._draining: dict[str, bool] = {}
        self._tasked: set[str] = set()
        self._round = 0
        self._roster = tuple(f"site-{i}" for i in range(self.n_clients))
        self.round_log: list[dict[str, Any]] = []
        self.bytes_down = 0
        self.bytes_up = 0
        self.restarts = 0
        self.rejects: list[dict[str, str]] = []
        self.faults: dict[str, Any] = {
            "stragglers": {}, "reconnects": {}, "quarantined": {},
            "lost": {}, "handshake_timeouts": 0,
        }
        self.metrics = obs_metrics.MetricsRegistry()
        # adaptive encode-ahead shared by every downlink sender: grows
        # from DEFAULT_ENCODE_AHEAD when the wire observes encode stalls
        # (wire bytes are bitwise-identical at any depth)
        self.encode_ahead = sm.AdaptiveEncodeAhead()
        self.resumed_from: Optional[int] = None
        self._resume_weights: Optional[dict[str, Any]] = None
        if resume:
            if not self.checkpoint_dir:
                raise ValueError(
                    "resume=True needs a checkpoint directory (the "
                    '"checkpoint" spec key or --checkpoint-dir)')
            state = latest_server_state(self.checkpoint_dir)
            if state is not None:
                # epoch set before the accept loop starts, so handshakes
                # see the restart round, not 0
                self._round = int(state["round"]) + 1
                self._resume_weights = state["weights"]
                self.resumed_from = int(state["round"])
                self.round_log = list(state["meta"].get("round_log", []))

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "FederationServer":
        self._server.serve(self._on_connection)
        return self

    def close(self) -> None:
        with self._lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:
            c.close()
        self._server.close()

    @property
    def current_round(self) -> int:
        with self._lock:
            return self._round

    # -- handshake ----------------------------------------------------------
    def _reject(self, conn: sm.Connection, reason: str,
                code: str = "error", **extra: Any) -> None:
        with self._lock:
            self.rejects.append({"peer": str(conn.peer), "reason": reason,
                                 "code": code})
        with contextlib.suppress(OSError):
            conn.send_ctrl({"type": "reject", "reason": reason,
                            "code": code, **extra})
        conn.close()

    def _on_connection(self, conn: sm.Connection) -> None:
        # a connected-but-mute socket is shed after handshake_timeout_s,
        # not round_timeout_s — it must never hold an accept thread (or a
        # roster slot) while a round is in flight
        conn.settimeout(self.handshake_timeout_s)
        tr = obs_trace.ACTIVE
        span = (tr.span("fed.handshake", "fed", peer=str(conn.peer))
                if tr else contextlib.nullcontext())
        with span:
            try:
                hello = conn.recv_ctrl()
            except TimeoutError:
                with self._lock:
                    self.faults["handshake_timeouts"] += 1
                self.metrics.counter("fed.handshake_timeout").inc()
                conn.close()
                return
            except (OSError, sm.ProtocolError, ConnectionError):
                conn.close()
                return
            if hello.get("type") != "hello":
                return self._reject(
                    conn, f'expected "hello", got {hello.get("type")!r}',
                    code="bad-hello")
            if hello.get("proto") != PROTO:
                return self._reject(
                    conn, f"protocol revision {hello.get('proto')} != {PROTO}",
                    code="proto")
            name = str(hello.get("client", ""))
            if name not in self._roster:
                return self._reject(
                    conn, f"unknown client {name!r}; roster is "
                          f"site-0..site-{self.n_clients - 1}",
                    code="unknown-client")
            if hello.get("fingerprint") != self.fingerprint:
                return self._reject(
                    conn,
                    f"pipeline fingerprint mismatch: server runs "
                    f"{self.fingerprint}, client {hello.get('fingerprint')} — "
                    "stage stacks or aggregator differ; refusing to fold",
                    code="fingerprint",
                )
            with self._lock:
                epoch = int(hello.get("epoch", 0))
                cur = self._round
                stale = epoch != cur
                dup = not stale and name in self._conns
                rejoined = False
                if not stale and not dup:
                    # welcome must be on the wire before the round loop
                    # can see this client (notify below) — otherwise the
                    # first task frame could beat the welcome
                    conn.settimeout(self.round_timeout_s)
                    try:
                        conn.send_ctrl({"type": "welcome", "round": cur,
                                        "rounds": self.rounds,
                                        "clients": self.n_clients,
                                        "uplink": self.uplink})
                    except OSError:
                        conn.close()
                        return
                    self._conns[name] = conn
                    rejoined = name in self._lost
                    self._lost.discard(name)
                    self._join_cv.notify_all()
            if stale:
                # structured redirect: the client retries immediately at
                # the round the server is actually on (resume / rejoin)
                return self._reject(
                    conn,
                    f"stale round epoch {epoch}: server is at round {cur}; "
                    f"reconnect with the current epoch",
                    code="stale-epoch", round=cur)
            if dup:
                return self._reject(
                    conn, f"duplicate client {name!r}: already connected",
                    code="duplicate")
            attempts = int(hello.get("reconnects", 0))
            if rejoined or attempts:
                with self._lock:
                    self.faults["reconnects"][name] = (
                        self.faults["reconnects"].get(name, 0) + 1)
                self.metrics.counter("fed.reconnect", client=name).inc()
                if tr:
                    with tr.span("fed.reconnect", "fed", client=name,
                                 round=cur, attempts=attempts):
                        pass

    def wait_for_clients(self, n: Optional[int] = None) -> None:
        """Block until ``n`` (default: the full roster) clients joined."""
        want = self.n_clients if n is None else n
        deadline = time.monotonic() + self.join_timeout_s
        with self._join_cv:
            while len(self._conns) < want:
                left = deadline - time.monotonic()
                if left <= 0 or not self._join_cv.wait(timeout=left):
                    missing = [c for c in self._roster if c not in self._conns]
                    raise TimeoutError(
                        f"{len(self._conns)}/{want} clients joined within "
                        f"{self.join_timeout_s}s; missing {missing}"
                    )

    # -- client failure -----------------------------------------------------
    def _drop(self, name: str, why: str, quarantine: bool = False) -> None:
        with self._drain_cv:
            conn = self._conns.pop(name, None)
            self._lost.add(name)
            self._tasked.discard(name)
            self._draining.pop(name, None)
            self.faults["lost"][name] = why
            if quarantine:
                self.faults["quarantined"][name] = why
            self._drain_cv.notify_all()
        if conn is not None:
            conn.close()

    def _lose(self, exc: _ClientLost) -> None:
        self._drop(exc.client, exc.why, quarantine=exc.quarantine)
        kind = "fed.quarantine" if exc.quarantine else "fed.client_lost"
        self.metrics.counter(kind, client=exc.client).inc()

    # -- stragglers (quorum mode) -------------------------------------------
    def _mark_straggler(self, exc: _Straggled, rnd: int) -> None:
        """Record a straggler and start draining its late uplink.

        The connection stays open: the timeout-safe reader kept every
        byte received so far, so a background thread resumes exactly
        mid-frame, reads the rest of the late stream, and discards it —
        the closed round's data never touches a fold, and the socket is
        clean for the next round's re-invite."""
        name = exc.client
        with self._lock:
            self.faults["stragglers"][name] = (
                self.faults["stragglers"].get(name, 0) + 1)
            conn = self._conns.get(name)
            self._draining[name] = True
        self.metrics.counter("fed.straggler", client=name).inc()
        tr = obs_trace.ACTIVE
        if tr:
            with tr.span("fed.straggler", "fed", client=name, round=rnd,
                         stage=exc.stage):
                pass
        threading.Thread(
            target=self._drain_straggler, args=(name, conn, exc.stage),
            daemon=True, name=f"fed-drain-{name}",
        ).start()

    def _drain_straggler(self, name: str, conn: Optional[sm.Connection],
                         stage: str) -> None:
        try:
            if conn is None:
                raise ConnectionError("connection gone before drain")
            if stage == "result":
                # the grant went out but the result header hadn't
                # arrived yet — it (and the stream) are still inbound
                ctrl = conn.recv_ctrl()
                if ctrl.get("type") != "result":
                    raise sm.ProtocolError(
                        f"draining {name}: expected a late result frame, "
                        f"got {ctrl}")
            conn.recv_stream(lambda chunk: None)  # discard, don't decode
        except (TimeoutError, OSError, ConnectionError, sm.ProtocolError,
                ValueError, struct_error) as exc:
            self._drop(name, f"straggler drain failed: {exc}")
        finally:
            with self._drain_cv:
                self._draining.pop(name, None)
                self._drain_cv.notify_all()

    def _quorum_need(self, roster: list[str]) -> Optional[int]:
        if self.quorum is None:
            return None
        return max(1, math.ceil(self.quorum * len(roster)))

    def _await_rejoin(self, roster: list[str],
                      contributed: list[str]) -> list[str]:
        """Below quorum with no one left to grant: wait for a draining
        straggler to come clean (its cached result for this round is
        still grantable). Returns newly grantable names, or ``[]`` when
        no drain is pending / the wait timed out — quorum unreachable."""
        deadline = time.monotonic() + self.round_timeout_s
        done = set(contributed)
        with self._drain_cv:
            while True:
                ready = [n for n in roster
                         if n in self._conns and n in self._tasked
                         and not self._draining.get(n) and n not in done]
                if ready:
                    return ready
                if not any(self._draining.get(n) for n in roster):
                    return []
                left = deadline - time.monotonic()
                if left <= 0:
                    return []
                self._drain_cv.wait(timeout=left)

    # -- downlink -----------------------------------------------------------
    def _downlink_one(self, name: str, rnd: int,
                      weights: Mapping[str, Any]) -> None:
        conn = self._conns.get(name)
        if conn is None:
            raise _ClientLost(name, "not connected at downlink")
        task = make_task(rnd, weights)
        # destination in the headers, same as the simulator's proxy, so
        # egress stages can be link/client-aware
        task.headers.setdefault("client", name)
        pipeline = self.pipelines["task_data"]
        try:
            # in quorum mode a stalled downlink only gets the straggler
            # grace: a partially-written task stream makes the socket
            # unusable anyway, so the client is dropped (it reconnects)
            # rather than allowed to stall the broadcast barrier
            if self.quorum is not None:
                conn.settimeout(self.straggler_grace_s)
            try:
                conn.send_ctrl({"type": "task", "round": rnd})
                driver = sm.ConnectionDriver(conn)
                msg, ctx = pipeline.begin_encode(task)
                # encode-ahead: this is a real socket, so while item k's
                # segments sit in sendmsg the worker encodes item k+1
                # (bitwise-identical wire bytes — see iter_encode_ahead)
                sm.ContainerStreamer(
                    driver, self.chunk_size, prefetch=self.encode_ahead
                ).send_items(
                    pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg)
                )
            finally:
                if self.quorum is not None:
                    with contextlib.suppress(OSError):
                        conn.settimeout(self.round_timeout_s)
        except TimeoutError as exc:
            raise _ClientLost(
                name, f"downlink stalled past the straggler grace: {exc}",
                poisoned=False) from exc
        except (OSError, ConnectionError) as exc:
            raise _ClientLost(name, f"downlink failed: {exc}",
                              poisoned=False) from exc
        with self._lock:
            self.bytes_down += driver.bytes_sent

    def _downlink(self, roster: list[str], rnd: int,
                  weights: Mapping[str, Any]) -> list[str]:
        """Broadcast the round's task to ``roster`` from parallel sender
        threads; returns the clients that actually received it."""
        tr = obs_trace.ACTIVE
        failed: dict[str, str] = {}

        def send(name: str) -> None:
            span = (tr.span("fed.downlink", "fed", client=name, round=rnd)
                    if tr else contextlib.nullcontext())
            try:
                with span:
                    self._downlink_one(name, rnd, weights)
            except _ClientLost as exc:
                failed[name] = exc.why

        threads = [threading.Thread(target=send, args=(n,), daemon=True,
                                    name=f"fed-downlink-{n}") for n in roster]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for name, why in failed.items():
            self._drop(name, why)
        return [n for n in roster if n not in failed]

    # -- uplink -------------------------------------------------------------
    def _uplink_one(self, name: str, rnd: int, agg: Any) -> dict[str, Any]:
        """Grant ``name``'s uplink and fold its stream into ``agg``.

        Failure taxonomy: a transport error raises :class:`_ClientLost`
        (``quarantine=False``); framed garbage — integrity (crc32),
        decode, or protocol violations — raises :class:`_ClientLost`
        with ``quarantine=True`` (the client is bad, not the link); in
        quorum mode a grace timeout after the grant raises
        :class:`_Straggled` instead. All three carry ``poisoned``: True
        iff any decoded item already reached ``agg`` (its ``begin``
        sample weight or partial items are in the running sums, so the
        caller must discard the fold and restart).
        """
        conn = self._conns.get(name)
        if conn is None:
            raise _ClientLost(name, "not connected at uplink",
                              poisoned=False)
        grace = self.straggler_grace_s if self.quorum is not None else None
        tr = obs_trace.ACTIVE
        span = (tr.span("fed.uplink", "fed", client=name, round=rnd)
                if tr else contextlib.nullcontext())
        stage = "grant"
        folded = [0]
        with span as sp:
            try:
                if grace is not None:
                    conn.settimeout(grace)
                try:
                    conn.send_ctrl({"type": "grant", "round": rnd})
                    stage = "result"
                    ctrl = conn.recv_ctrl()
                    if ctrl.get("type") != "result" or ctrl.get("round") != rnd:
                        raise _ClientLost(
                            name, f"expected result/round={rnd}, got {ctrl}",
                            poisoned=False, quarantine=True)
                    stage = "stream"
                    decoder = self.pipelines["task_result"].decoder(sink=agg)

                    def consume(iname: str, value: Any) -> None:
                        folded[0] += 1  # poison marker: agg was touched
                        decoder.on_item(iname, value)

                    recv = sm.ContainerReceiver(consume=consume,
                                                decode_item=decoder.decode_item)
                    nbytes = conn.recv_stream(recv.on_chunk)
                    result = decoder.finish(MessageKind.TASK_RESULT)
                finally:
                    if grace is not None:
                        with contextlib.suppress(OSError):
                            conn.settimeout(self.round_timeout_s)
            except _ClientLost:
                raise
            except TimeoutError as exc:
                if grace is None or stage == "grant":
                    raise _ClientLost(
                        name, f"uplink timed out at {stage}: {exc}",
                        poisoned=folded[0] > 0) from exc
                raise _Straggled(name, stage,
                                 poisoned=folded[0] > 0) from exc
            except (OSError, ConnectionError) as exc:
                raise _ClientLost(name, f"uplink failed: {exc}",
                                  poisoned=folded[0] > 0) from exc
            except (sm.ProtocolError, ValueError, KeyError,
                    struct_error) as exc:
                # includes WireIntegrityError from crc32: corrupted
                # payload bytes quarantine the sender, never the server
                raise _ClientLost(name, f"uplink decode failed: {exc}",
                                  poisoned=folded[0] > 0,
                                  quarantine=True) from exc
            if sp is not None:
                sp.args["nbytes"] = nbytes
        with self._lock:
            self.bytes_up += nbytes
        return dict(result.headers)

    def _gather(self, roster: list[str],
                rnd: int) -> tuple[dict[str, Any], list[str]]:
        """One round's aggregation with crash recovery; returns the new
        global weights and the clients whose contribution is in them, in
        fold order.

        Without a quorum this is all-surviving-clients-or-restart: any
        loss discards the fold (partial items / ``begin`` weight may be
        in the running sums) and refolds over the survivors. With
        ``"quorum"`` set, each uplink gets ``straggler_grace_s``; a
        clean (un-poisoned) straggle just skips that client — the
        streaming aggregator finishes early over the contributors it
        has — while a poisoned one restarts the fold. If the roster is
        exhausted below quorum, the server waits for straggler drains to
        complete and re-grants them (clients cache the round's result),
        giving up only when no straggler remains to wait for.
        """
        need_fixed = self._quorum_need(roster)
        while True:  # one iteration per fold attempt
            with self._lock:
                queue = [n for n in roster
                         if n in self._conns and n in self._tasked
                         and not self._draining.get(n)]
            need = len(queue) if need_fixed is None else need_fixed
            if need_fixed is None and not queue:
                raise RuntimeError(
                    f"round {rnd}: every client was lost; nothing to aggregate"
                )
            agg = build_aggregator(self.agg_spec)
            contributed: list[str] = []
            poisoned = False

            if self.uplink == "concurrent":
                failures: dict[str, Exception] = {}

                def fold(name: str) -> None:
                    try:
                        self._uplink_one(name, rnd, agg)
                        contributed.append(name)
                    except (_Straggled, _ClientLost) as exc:
                        failures[name] = exc

                threads = [threading.Thread(target=fold, args=(n,),
                                            daemon=True,
                                            name=f"fed-uplink-{n}")
                           for n in queue]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                for exc in failures.values():
                    if isinstance(exc, _Straggled):
                        self._mark_straggler(exc, rnd)
                    else:
                        self._lose(exc)
                    # concurrent folds interleave arbitrarily: any
                    # failure taints the shared sums
                    poisoned = True
            else:
                while queue or len(contributed) < need:
                    if not queue:
                        ready = self._await_rejoin(roster, contributed)
                        if not ready:
                            break  # quorum unreachable — raise below
                        queue.extend(ready)
                        continue
                    name = queue.pop(0)
                    try:
                        self._uplink_one(name, rnd, agg)
                        contributed.append(name)
                    except _Straggled as exc:
                        self._mark_straggler(exc, rnd)
                        if exc.poisoned:
                            poisoned = True
                            break
                    except _ClientLost as exc:
                        self._lose(exc)
                        # without a quorum any loss restarts (the old
                        # all-or-nothing contract); with one, a clean
                        # loss just shrinks the contributor set
                        if exc.poisoned or need_fixed is None:
                            poisoned = True
                            break

            if poisoned:
                with self._lock:
                    self.restarts += 1
                continue
            if len(contributed) >= need:
                return agg.finish(), contributed
            raise RuntimeError(
                f"round {rnd}: quorum unreachable — "
                f"{len(contributed)}/{need} of {len(roster)} clients"
            )

    # -- the round loop -----------------------------------------------------
    def run(self, init_weights: Mapping[str, Any]) -> dict[str, Any]:
        """Run all rounds; returns the final global weights."""
        tracer = None
        trace_spec = self.spec.get("trace")
        if trace_spec:
            tracer = obs_trace.Tracer()
        ctx = (obs_trace.activate(tracer) if tracer is not None
               else contextlib.nullcontext())
        # the spec's kernel_backend selection applies to the whole run:
        # the server's fold kernels here, each client's quantize in its
        # own process (for_spec plumbs the same key)
        with ctx, obs_metrics.activate(self.metrics), \
                kernel_backend_scope(self.spec):
            with self._lock:
                start = self._round  # > 0 when resuming
                resume_weights = self._resume_weights
                self._resume_weights = None
            weights = (dict(resume_weights) if resume_weights is not None
                       else dict(init_weights))
            self.wait_for_clients()
            for rnd in range(start, self.rounds):
                with self._lock:
                    self._round = rnd
                    # stragglers still being drained sit this round out;
                    # they rejoin the roster once their socket is clean
                    roster = [n for n in self._roster
                              if n in self._conns
                              and not self._draining.get(n)]
                if not roster:
                    raise RuntimeError(f"round {rnd}: no clients connected")
                tr = obs_trace.ACTIVE
                span = (tr.span("fed.round", "round", round=rnd,
                                clients=len(roster))
                        if tr else contextlib.nullcontext())
                t0 = time.monotonic()
                with span:
                    active = self._downlink(roster, rnd, weights)
                    with self._lock:
                        self._tasked = set(active)
                    weights, contributed = self._gather(active, rnd)
                self.round_log.append({
                    "round": rnd,
                    "clients": contributed,
                    "stragglers": [n for n in active if n not in contributed],
                    "wall_s": round(time.monotonic() - t0, 6),
                })
                if self.checkpoint_dir:
                    # atomic persist *before* the epoch advances: a crash
                    # between the two resumes at this round's successor
                    # with exactly this round's weights
                    save_server_state(
                        self.checkpoint_dir, rnd, weights,
                        meta={"roster": roster, "contributors": contributed,
                              "round_log": self.round_log})
                with self._lock:
                    self._round = rnd + 1
            with self._lock:
                conns = list(self._conns.values())
            for conn in conns:
                with contextlib.suppress(OSError):
                    conn.send_ctrl({"type": "done"})
        if tracer is not None and isinstance(trace_spec, str):
            tracer.write(trace_spec)
        return weights


class FederationClient:
    """One live client: connect, handshake, then react to server control.

    ``run()`` loops on control frames: ``task`` (receive + decode the
    downlink stream, execute the local computation, cache the result),
    ``grant`` (re-encode the cached round result and stream it up —
    idempotent, so a server-side fold restart can simply grant again),
    ``done`` (exit). A ``reject`` at the handshake raises with the
    server's reason.
    """

    def __init__(self, name: str, executor: Any,
                 pipelines: Mapping[str, WirePipeline],
                 address: tuple[str, int], fingerprint: str,
                 epoch: int = 0, chunk_size: int = 1 << 20,
                 timeout_s: Optional[float] = None,
                 kernel_backend: Optional[str] = None,
                 max_reconnects: int = 0,
                 backoff_base_s: float = 0.25,
                 backoff_cap_s: float = 10.0) -> None:
        self.name = name
        self.executor = executor
        self.pipelines = dict(pipelines)
        self.address = tuple(address)
        self.fingerprint = fingerprint
        self.epoch = epoch
        self.chunk_size = chunk_size
        self.timeout_s = timeout_s
        self.kernel_backend = kernel_backend
        self.max_reconnects = int(max_reconnects)
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.rounds_done = 0
        self.faults = {"reconnects": 0}
        # per-process adaptive uplink encode-ahead (bitwise-stable depth)
        self.encode_ahead = sm.AdaptiveEncodeAhead()

    @classmethod
    def for_spec(cls, spec: Mapping[str, Any], index: int,
                 address: tuple[str, int], epoch: int = 0,
                 timeout_s: Optional[float] = None) -> "FederationClient":
        """Build the client exactly as the spec describes it — same
        executor/pipeline construction path as the simulator, which is
        what makes live weights bitwise-comparable to ``run_job``."""
        spec = live_spec(spec)
        pipelines = build_pipelines_from_spec(spec)
        return cls(
            name=f"site-{index}",
            executor=build_client_executor(spec, index),
            pipelines=pipelines,
            address=address,
            fingerprint=pipeline_fingerprint(pipelines, aggregator_spec(spec)),
            epoch=epoch,
            chunk_size=int(spec["chunk_mb"] * (1 << 20)),
            timeout_s=timeout_s,
            kernel_backend=spec.get("kernel_backend"),
            max_reconnects=int(spec.get("max_reconnects") or 0),
        )

    def run(self) -> int:
        """Participate until the server says ``done``; returns the number
        of rounds this client's results were (last) granted for.

        Transient transport failures (connection refused/reset, socket
        timeout, torn frames) reconnect with capped exponential backoff
        plus deterministic jitter, up to ``max_reconnects`` attempts per
        run; a structured ``stale-epoch`` reject is a redirect — retry
        immediately at the server's round. Either way the client rejoins
        at the server's current epoch and participates from the next
        downlink (the executor is a pure function of (params, round), so
        a re-executed round reproduces its result bitwise)."""
        with kernel_backend_scope({"kernel_backend": self.kernel_backend}):
            attempt = 0
            redirects = 0
            # seeded by name: reproducible per-client jitter, decorrelated
            # across the fleet (str seeding hashes deterministically)
            rng = random.Random(self.name)
            while True:
                try:
                    return self._run()
                except _StaleEpoch as exc:
                    redirects += 1
                    if redirects > 64:
                        raise RuntimeError(
                            f"{self.name}: {redirects} stale-epoch redirects; "
                            "the server is advancing past every rejoin")
                    self.epoch = int(exc.round)
                    time.sleep(0.02)
                except (ConnectionError, TimeoutError, OSError,
                        sm.ProtocolError) as exc:
                    attempt += 1
                    self.faults["reconnects"] = attempt
                    if attempt > self.max_reconnects:
                        raise
                    delay = min(self.backoff_cap_s,
                                self.backoff_base_s * 2.0 ** (attempt - 1))
                    delay *= 0.5 + rng.random() / 2.0
                    time.sleep(delay)

    def _run(self) -> int:
        sock = socket.create_connection(self.address)
        conn = sm.Connection(sock)
        conn.settimeout(self.timeout_s)
        try:
            conn.send_ctrl({"type": "hello", "client": self.name,
                            "epoch": self.epoch, "proto": PROTO,
                            "fingerprint": self.fingerprint,
                            "reconnects": self.faults["reconnects"]})
            resp = conn.recv_ctrl()
            if resp.get("type") != "welcome":
                code = resp.get("code")
                if code == "stale-epoch" and "round" in resp:
                    raise _StaleEpoch(int(resp["round"]))
                if code == "duplicate":
                    # our dead predecessor socket still occupies the slot;
                    # the server sheds it when round traffic next touches
                    # it — retry through the backoff loop
                    raise ConnectionError(
                        f"{self.name}: predecessor connection still "
                        "registered; retrying")
                raise RuntimeError(
                    f"{self.name}: server rejected the handshake: "
                    f"{resp.get('reason', resp)}"
                )
            cached: dict[int, Message] = {}
            while True:
                ctrl = conn.recv_ctrl()
                kind = ctrl.get("type")
                if kind == "task":
                    rnd = int(ctrl["round"])
                    task = self._recv_task(conn)
                    result = self.executor.execute(task)
                    # one round's cache only: grants never reach back
                    # further than the current round's fold restarts
                    cached.clear()
                    cached[rnd] = result
                elif kind == "grant":
                    rnd = int(ctrl["round"])
                    if rnd not in cached:
                        raise RuntimeError(
                            f"{self.name}: granted round {rnd} but no cached "
                            f"result (have {sorted(cached)})"
                        )
                    self._send_result(conn, rnd, cached[rnd])
                    self.rounds_done = rnd + 1
                elif kind == "done":
                    return self.rounds_done
                else:
                    raise RuntimeError(
                        f"{self.name}: unexpected control frame {ctrl}")
        finally:
            conn.close()

    def _recv_task(self, conn: sm.Connection) -> Message:
        decoder = self.pipelines["task_data"].decoder()
        recv = sm.ContainerReceiver(consume=decoder.on_item,
                                    decode_item=decoder.decode_item)
        conn.recv_stream(recv.on_chunk)
        return decoder.finish(MessageKind.TASK_DATA)

    def _send_result(self, conn: sm.Connection, rnd: int,
                     result: Message) -> None:
        # fresh copy per grant: begin_encode may rewrite headers/payload,
        # and a fold restart will ask for this result again
        msg = Message(result.kind, dict(result.payload), dict(result.headers))
        pipeline = self.pipelines["task_result"]
        msg, ctx = pipeline.begin_encode(msg)
        conn.send_ctrl({"type": "result", "round": rnd, "client": self.name})
        # encode-ahead on the uplink too: quantize/crc of item k+1
        # overlaps the socket write of item k (same wire bytes)
        sm.ContainerStreamer(
            sm.ConnectionDriver(conn), self.chunk_size,
            prefetch=self.encode_ahead,
        ).send_items(
            pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg)
        )


# ---------------------------------------------------------------------------
# Sequential reference over recorded contributor sets
# ---------------------------------------------------------------------------

def _wire_roundtrip(pipeline: WirePipeline, msg: Message, kind: MessageKind,
                    chunk_size: int, sink: Optional[Any] = None) -> Message:
    """Encode → chunk → decode one message through a loopback driver —
    the exact arithmetic path of a live transfer, minus the socket."""
    decoder = pipeline.decoder(sink=sink)
    recv = sm.ContainerReceiver(consume=decoder.on_item,
                                decode_item=decoder.decode_item)
    driver = sm.LoopbackDriver()
    driver.connect(recv.on_chunk)
    msg, ctx = pipeline.begin_encode(msg)
    sm.ContainerStreamer(driver, chunk_size).send_items(
        pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg))
    return decoder.finish(kind)


def reference_run(spec: Mapping[str, Any], rosters: list[list[str]],
                  init: Optional[Mapping[str, Any]] = None) -> dict[str, Any]:
    """Replay a federation sequentially over recorded contributor sets.

    ``rosters[r]`` is round ``r``'s contributor list *in fold order* —
    exactly what the live server records in ``round_log[r]["clients"]``.
    Each round downlinks through the task_data pipeline, executes the
    client's (pure, round-keyed) local training, and folds the uplink
    through the task_result pipeline into the same streaming aggregator,
    in the same order — so the result is **bitwise-equal** to a live run
    whose effective contributor sets matched, whatever chaos (stragglers,
    reconnects, quarantines, restarts) produced them. ``--verify-chaos``
    asserts this; ``tests/test_chaos.py`` leans on it throughout."""
    spec = live_spec(spec)
    chunk = int(spec["chunk_mb"] * (1 << 20))
    pipelines = build_pipelines_from_spec(spec)
    executors = {f"site-{i}": build_client_executor(spec, i)
                 for i in range(int(spec["clients"]))}
    weights = dict(initial_weights(spec) if init is None else init)
    with kernel_backend_scope(spec):
        for rnd, roster in enumerate(rosters):
            agg = build_aggregator(aggregator_spec(spec))
            for name in roster:
                task = make_task(rnd, weights)
                task.headers.setdefault("client", name)
                task = _wire_roundtrip(pipelines["task_data"], task,
                                       MessageKind.TASK_DATA, chunk)
                result = executors[name].execute(task)
                msg = Message(result.kind, dict(result.payload),
                              dict(result.headers))
                _wire_roundtrip(pipelines["task_result"], msg,
                                MessageKind.TASK_RESULT, chunk, sink=agg)
            weights = agg.finish()
    return weights


# ---------------------------------------------------------------------------
# Orchestration: spawn subprocess clients + run the server
# ---------------------------------------------------------------------------

def _reap(procs: list[subprocess.Popen],
          deadline_s: float) -> list[Optional[int]]:
    """Reap every subprocess against ONE shared deadline.

    First pass waits (bounded by what's left of the deadline) and
    escalates to ``terminate()`` on expiry; the second pass gives
    terminated processes a short window to exit, then ``kill()``s and
    always reaps — no zombie survives, and a fleet of wedged clients
    costs one deadline, not one per client."""
    if not procs:
        return []
    codes: list[Optional[int]] = [None] * len(procs)
    deadline = time.monotonic() + deadline_s
    for i, p in enumerate(procs):
        try:
            codes[i] = p.wait(timeout=max(0.0, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            with contextlib.suppress(OSError):
                p.terminate()
    kill_at = time.monotonic() + 5.0
    for i, p in enumerate(procs):
        if codes[i] is not None:
            continue
        try:
            codes[i] = p.wait(timeout=max(0.0, kill_at - time.monotonic()))
        except subprocess.TimeoutExpired:
            with contextlib.suppress(OSError):
                p.kill()
            codes[i] = p.wait()
    return codes


def _client_cmd(spec_path: str, index: int, address: tuple[str, int]) -> list[str]:
    return [
        sys.executable, "-m", "repro.launch.federation",
        "--spec", spec_path,
        "--client-index", str(index),
        "--connect", f"{address[0]}:{address[1]}",
    ]


def _client_env() -> dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = f"{src}{os.pathsep}{pp}" if pp else src
    return env


def run_live_federation(
    spec: Mapping[str, Any],
    clients: Optional[int] = None,
    rounds: Optional[int] = None,
    host: str = "127.0.0.1",
    port: int = 0,
    uplink: str = "ordered",
    join_timeout_s: float = 120.0,
    round_timeout_s: float = 600.0,
    spawn: bool = True,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
) -> dict[str, Any]:
    """Run one real federation: server in this process, clients as
    subprocesses (``spawn=True``) or left to the caller (``spawn=False``
    — e.g. clients on other machines pointing at ``result["address"]``...
    which in-process tests also use, running :class:`FederationClient`
    on threads).

    A ``"chaos"`` spec block (``{client_name: fault_plan}``) routes each
    named client through its own :class:`ChaosProxy` with that plan —
    the fault-injection harness for tests and the chaos-smoke CI job.
    The chaos block never reaches the subprocess spec (clients must not
    know they are being sabotaged).

    Returns final weights, the per-round log (contributors, stragglers,
    wall seconds), wire byte totals, fault counters, the telemetry
    snapshot, and the clients' exit codes.
    """
    spec = live_spec(spec, clients=clients, rounds=rounds)
    server = FederationServer(
        spec, host=host, port=port, uplink=uplink,
        join_timeout_s=join_timeout_s, round_timeout_s=round_timeout_s,
        checkpoint_dir=checkpoint_dir, resume=resume,
    ).start()
    procs: list[subprocess.Popen] = []
    proxies: dict[str, ChaosProxy] = {}
    spec_path: Optional[str] = None
    t0 = time.monotonic()
    try:
        if spawn:
            for name, plan in dict(spec.get("chaos") or {}).items():
                proxies[name] = ChaosProxy(server.address, plan).start()
            # subprocesses must see the *fully resolved* spec (clients /
            # rounds overrides included): the partition is keyed by the
            # client count, so a drifting spec would train on wrong data
            fd, spec_path = tempfile.mkstemp(suffix=".json",
                                             prefix="live_spec_")
            with os.fdopen(fd, "w") as fh:
                json.dump({k: v for k, v in spec.items()
                           if k not in ("trace", "chaos")}, fh)
            for i in range(server.n_clients):
                name = f"site-{i}"
                addr = (proxies[name].address if name in proxies
                        else server.address)
                procs.append(subprocess.Popen(
                    _client_cmd(spec_path, i, addr),
                    env=_client_env(),
                ))
        final = server.run(initial_weights(spec))
        wall_s = time.monotonic() - t0
        exit_codes = _reap(procs, 60.0)
        return {
            "final_weights": final,
            "address": server.address,
            "round_log": server.round_log,
            "bytes_down": server.bytes_down,
            "bytes_up": server.bytes_up,
            "restarts": server.restarts,
            "rejects": server.rejects,
            "faults": server.faults,
            "resumed_from": server.resumed_from,
            "telemetry": server.metrics.snapshot(),
            "wall_s": round(wall_s, 6),
            "client_exit_codes": exit_codes,
        }
    finally:
        # always-reap: terminate-then-kill with one shared deadline, so
        # a wedged fleet can't leak zombies or stall shutdown for 60s×N
        _reap(procs, 5.0)
        for proxy in proxies.values():
            proxy.close()
        server.close()
        if spec_path is not None:
            with contextlib.suppress(OSError):
                os.unlink(spec_path)


# ---------------------------------------------------------------------------
# CLI: `python -m repro.launch.federation`
# ---------------------------------------------------------------------------

def _parse_address(s: str) -> tuple[str, int]:
    host, _, port = s.rpartition(":")
    return host or "127.0.0.1", int(port)


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.federation",
        description="Run a real multi-process federation from a job spec "
                    "(server mode), or one client of it (--client-index).",
    )
    ap.add_argument("--spec", required=True, help="path to a JSON job spec")
    ap.add_argument("--clients", type=int, default=None,
                    help="override the spec's client count (server mode)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the spec's round count (server mode)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral)")
    ap.add_argument("--uplink", choices=UPLINK_MODES, default="ordered")
    ap.add_argument("--join-timeout", type=float, default=120.0)
    ap.add_argument("--round-timeout", type=float, default=600.0)
    ap.add_argument("--no-spawn", action="store_true",
                    help="server only; clients connect from elsewhere")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="atomically persist round epoch + global weights "
                         "+ roster here after every round (overrides the "
                         'spec\'s "checkpoint" key)')
    ap.add_argument("--resume", action="store_true",
                    help="restart from the newest checkpoint in "
                         "--checkpoint-dir at round k+1 with "
                         "bitwise-identical weights")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="write the server's Chrome trace-event file "
                         "(open in Perfetto)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the run summary as JSON")
    ap.add_argument("--verify-sim", action="store_true",
                    help="also run the sequential simulator on the same spec "
                         "and fail unless final weights are bitwise-equal")
    ap.add_argument("--verify-chaos", action="store_true",
                    help="replay the run's recorded per-round contributor "
                         "sets sequentially (reference_run) and fail unless "
                         "final weights are bitwise-equal — the equivalence "
                         "check that survives stragglers/reconnects/resume")
    ap.add_argument("--client-index", type=int, default=None,
                    help="client mode: which roster slot this process is")
    ap.add_argument("--connect", metavar="HOST:PORT", default=None,
                    help="client mode: the server address")
    ap.add_argument("--epoch", type=int, default=0,
                    help="client mode: round epoch to present (rejoin)")
    args = ap.parse_args(argv)

    with open(args.spec) as fh:
        spec = json.load(fh)

    if args.client_index is not None:
        if not args.connect:
            ap.error("--client-index requires --connect HOST:PORT")
        client = FederationClient.for_spec(
            spec, args.client_index, _parse_address(args.connect),
            epoch=args.epoch, timeout_s=args.round_timeout,
        )
        client.run()
        return 0

    if args.trace:
        spec["trace"] = args.trace
    result = run_live_federation(
        spec, clients=args.clients, rounds=args.rounds,
        host=args.host, port=args.port, uplink=args.uplink,
        join_timeout_s=args.join_timeout, round_timeout_s=args.round_timeout,
        spawn=not args.no_spawn,
        checkpoint_dir=args.checkpoint_dir, resume=args.resume,
    )
    final = result.pop("final_weights")
    result["weights_sha256"] = hashlib.sha256(
        b"".join(np.asarray(final[k]).tobytes() for k in sorted(final))
    ).hexdigest()

    if args.verify_chaos:
        # the recorded contributor sets are the ground truth: replaying
        # them sequentially must land on the same bits, whatever faults
        # shaped them (a resumed run's restored round_log covers the
        # pre-crash rounds too, so one check spans the server restart)
        ref_spec = {k: v for k, v in live_spec(
            spec, clients=args.clients, rounds=args.rounds).items()
            if k not in ("trace", "chaos")}
        rosters = [list(r["clients"]) for r in result.get("round_log", [])]
        ref = reference_run(ref_spec, rosters)
        equal = weights_bitwise_equal(final, ref)
        result["chaos_ref_equal"] = equal
        if not equal:
            out = json.dumps(result, indent=1, default=str)
            if args.json:
                with open(args.json, "w") as fh:
                    fh.write(out + "\n")
            print(out)
            print("FAIL: live weights differ from the sequential reference "
                  "over the recorded contributor sets", file=sys.stderr)
            return 1

    if args.verify_sim:
        from repro.fl.job import run_job

        sim_spec = {k: v for k, v in live_spec(
            spec, clients=args.clients, rounds=args.rounds).items()
            if k != "trace"}
        sim = run_job(sim_spec)
        equal = weights_bitwise_equal(final, sim["final_weights"])
        result["sim_bitwise_equal"] = equal
        # wall-vs-sim per-round timing: the live server and the
        # sequential controller record the same round_log shape, so the
        # summary can show where deployment overhead (process hops, TCP
        # framing, stragglers) lands round by round
        result["round_timing"] = [
            {
                "round": lv.get("round", i),
                "live_wall_s": lv.get("wall_s"),
                "sim_wall_s": sv.get("wall_s"),
                "delta_s": round(float(lv.get("wall_s", 0.0))
                                 - float(sv.get("wall_s", 0.0)), 6),
            }
            for i, (lv, sv) in enumerate(
                zip(result.get("round_log", []), sim.get("round_log", []))
            )
        ]
        if not equal:
            out = json.dumps(result, indent=1, default=str)
            if args.json:
                with open(args.json, "w") as fh:
                    fh.write(out + "\n")
            print(out)
            print("FAIL: live weights differ from the sequential simulator",
                  file=sys.stderr)
            return 1

    out = json.dumps(result, indent=1, default=str)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(out + "\n")
    print(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
