"""Real multi-process federation over TCP — the live deployment plane.

The simulator proves the paper's quantization + streaming claims on a
simulated clock; this module proves them on a real one. One server
process opens a :class:`~repro.core.streaming.TCPServer` accept loop,
``N`` client subprocesses (``python -m repro.launch.federation
--client-index i --connect host:port``) connect, and real wall-clock
rounds run over the exact wire format, stage pipelines, and streaming
aggregators the simulator uses — driven by the *same* declarative job
spec ``run_job`` takes.

Equivalence guarantee
    With the default ``ordered`` uplink, the server grants uplinks in
    roster order and folds each client's decoded items into one live
    aggregator (``WireDecoder(sink=...)`` — O(item) server memory, never
    K models), executing **identical arithmetic in identical order** to
    the sequential simulator. Deterministic data partitioning + seeds
    make the client subprocesses compute the same local updates, so the
    final weights are **bitwise-equal** to ``run_job`` on the same spec
    (``--verify-sim`` asserts this; the ``live-smoke`` CI job runs it on
    every push). ``--uplink concurrent`` folds all uplinks at once from
    per-connection threads — maximum throughput, order-free arithmetic,
    so equality weakens to numerical closeness.

Protocol (PROTO 1)
    JSON control frames and raw chunk streams interleave on one socket
    (:class:`~repro.core.streaming.Connection`). A client opens with
    ``hello`` (name, round epoch, pipeline fingerprint); the server
    answers ``welcome`` or ``reject`` — a mismatched stage stack or a
    stale epoch fails fast at the handshake instead of corrupting a
    fold. Rounds then alternate ``task`` + downlink stream and ``grant``
    / ``result`` + uplink stream, ending with ``done``.

Crash/rejoin semantics
    A client dying mid-uplink must not register phantom weight: its
    ``begin`` already counted sample weight and its partial items are in
    the running sums, so the server discards the poisoned fold, rebuilds
    the aggregator, and re-grants the surviving roster in order
    (clients cache the round's result and re-encode on each grant;
    stateless pipelines make the re-encode deterministic). A crashed
    client may reconnect with the server's *current* round epoch and
    participates from the next downlink.
"""
from __future__ import annotations

import argparse
import contextlib
import hashlib
import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time
from struct import error as struct_error
from typing import Any, Mapping, Optional

import numpy as np

from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.core.pipeline import WirePipeline, registered_stages
from repro.fl.aggregator import build_aggregator
from repro.fl.controller import make_task
from repro.fl.job import (
    aggregator_spec,
    build_client_executor,
    build_pipelines_from_spec,
    initial_weights,
    kernel_backend_scope,
    normalize_spec,
)
from repro.obs import trace as obs_trace

PROTO = 1

#: uplink scheduling modes: "ordered" serializes grants in roster order
#: (one live fold, bitwise sim-equivalent); "concurrent" folds every
#: uplink at once from per-connection threads (throughput mode)
UPLINK_MODES = ("ordered", "concurrent")


def pipeline_fingerprint(pipelines: Mapping[str, WirePipeline],
                         agg_spec: Any) -> str:
    """Capability fingerprint exchanged at the handshake.

    Hashes everything that must agree for a fold to be meaningful: the
    protocol revision, each hop's stage stack and decode mode, the
    stage registry (a client with extra/missing registered stages could
    decode a task differently), and the aggregator selection. Two
    processes with equal fingerprints provably run the same wire stack.
    """
    desc = {
        "proto": PROTO,
        "stages": {d: [s.name for s in pl.stages]
                   for d, pl in sorted(pipelines.items())},
        "decode_values": {d: bool(pl.decode_values)
                          for d, pl in sorted(pipelines.items())},
        "registry": list(registered_stages()),
        "aggregator": agg_spec,
    }
    return hashlib.sha256(
        json.dumps(desc, sort_keys=True).encode()
    ).hexdigest()[:16]


def live_spec(spec: Mapping[str, Any], clients: Optional[int] = None,
              rounds: Optional[int] = None) -> dict[str, Any]:
    """Normalize + validate a job spec for live deployment.

    The live plane runs real processes on a real clock, so the pieces of
    the spec surface that only make sense inside the simulator are
    rejected up front: the ``runtime`` scenario block (simulated
    networks/availability), the legacy whole-message filter keys, and
    stateful pipelines (crash recovery re-encodes a cached result, which
    must be deterministic — error feedback / DP noise streams are not).

    ``"kernel_backend"`` passes through: the resolved spec ships to
    every client subprocess, so one key selects the quantize-kernel
    implementation on the server and all clients (payloads are
    bitwise-identical across backends, so mixed deployments still fold
    correctly — the key is a per-process performance knob).
    """
    out = normalize_spec(dict(spec))
    if clients is not None:
        out["clients"] = int(clients)
    if rounds is not None:
        out["rounds"] = int(rounds)
    if out.get("runtime"):
        raise ValueError(
            'the "runtime" block configures the *simulated* scenario engine '
            "(virtual networks, availability, async policies); the live plane "
            "runs real clients on a real clock — remove it from live specs"
        )
    if out.get("quantization") or out.get("dp_sigma"):
        raise ValueError(
            'live deployment requires the streaming "pipeline" form; the '
            'legacy "quantization"/"dp_sigma" filter keys are not supported'
        )
    if int(out["clients"]) < 1:
        raise ValueError(f'need at least one client, got {out["clients"]}')
    pipelines = build_pipelines_from_spec(out)
    for direction, pl in pipelines.items():
        if pl.stateful:
            stateful = [s.name for s in pl.stages if s.stateful]
            raise ValueError(
                f"stateful stage(s) {stateful} in {direction!r}: live crash "
                "recovery re-encodes cached results, which requires "
                "deterministic (stateless) pipelines"
            )
    return out


def weights_bitwise_equal(a: Mapping[str, Any], b: Mapping[str, Any]) -> bool:
    """True iff two flat state dicts are bitwise-identical."""
    if set(a) != set(b):
        return False
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        if x.shape != y.shape or x.dtype != y.dtype:
            return False
        if x.tobytes() != y.tobytes():
            return False
    return True


class _ClientLost(Exception):
    """One client's connection failed mid-round (carries the name)."""

    def __init__(self, name: str, why: str) -> None:
        super().__init__(f"{name}: {why}")
        self.client = name
        self.why = why


class FederationServer:
    """The live server: accept loop, handshakes, real wall-clock rounds.

    Owns a :class:`~repro.core.streaming.TCPServer`; every accepted
    connection handshakes on its own thread, then round logic drives all
    traffic — per-client downlink sender threads, and either ordered
    grant-serialized uplinks (default, sim-bitwise) or concurrent
    per-connection fold threads. Server memory stays O(item): each uplink
    decodes straight into the shared streaming aggregator via
    ``WireDecoder(sink=...)`` — no client payload dict ever materializes.
    """

    def __init__(self, spec: Mapping[str, Any], host: str = "127.0.0.1",
                 port: int = 0, uplink: str = "ordered",
                 join_timeout_s: float = 60.0,
                 round_timeout_s: float = 600.0) -> None:
        if uplink not in UPLINK_MODES:
            raise ValueError(f"uplink mode {uplink!r}; valid: {UPLINK_MODES}")
        self.spec = live_spec(spec)
        self.n_clients = int(self.spec["clients"])
        self.rounds = int(self.spec["rounds"])
        self.chunk_size = int(self.spec["chunk_mb"] * (1 << 20))
        self.pipelines = build_pipelines_from_spec(self.spec)
        self.agg_spec = aggregator_spec(self.spec)
        self.fingerprint = pipeline_fingerprint(self.pipelines, self.agg_spec)
        self.uplink = uplink
        self.join_timeout_s = join_timeout_s
        self.round_timeout_s = round_timeout_s
        self._server = sm.TCPServer(host, port)
        self.address = self._server.address
        self._lock = threading.Lock()
        self._join_cv = threading.Condition(self._lock)
        self._conns: dict[str, sm.Connection] = {}
        self._lost: set[str] = set()
        self._round = 0
        self._roster = tuple(f"site-{i}" for i in range(self.n_clients))
        self.round_log: list[dict[str, Any]] = []
        self.bytes_down = 0
        self.bytes_up = 0
        self.restarts = 0
        self.rejects: list[dict[str, str]] = []

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "FederationServer":
        self._server.serve(self._on_connection)
        return self

    def close(self) -> None:
        with self._lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:
            c.close()
        self._server.close()

    @property
    def current_round(self) -> int:
        with self._lock:
            return self._round

    # -- handshake ----------------------------------------------------------
    def _reject(self, conn: sm.Connection, reason: str) -> None:
        with self._lock:
            self.rejects.append({"peer": str(conn.peer), "reason": reason})
        with contextlib.suppress(OSError):
            conn.send_ctrl({"type": "reject", "reason": reason})
        conn.close()

    def _on_connection(self, conn: sm.Connection) -> None:
        conn.settimeout(self.round_timeout_s)
        tr = obs_trace.ACTIVE
        span = (tr.span("fed.handshake", "fed", peer=str(conn.peer))
                if tr else contextlib.nullcontext())
        with span:
            try:
                hello = conn.recv_ctrl()
            except (OSError, sm.ProtocolError, ConnectionError):
                conn.close()
                return
            if hello.get("type") != "hello":
                return self._reject(
                    conn, f'expected "hello", got {hello.get("type")!r}')
            if hello.get("proto") != PROTO:
                return self._reject(
                    conn, f"protocol revision {hello.get('proto')} != {PROTO}")
            name = str(hello.get("client", ""))
            if name not in self._roster:
                return self._reject(
                    conn, f"unknown client {name!r}; roster is "
                          f"site-0..site-{self.n_clients - 1}")
            if hello.get("fingerprint") != self.fingerprint:
                return self._reject(
                    conn,
                    f"pipeline fingerprint mismatch: server runs "
                    f"{self.fingerprint}, client {hello.get('fingerprint')} — "
                    "stage stacks or aggregator differ; refusing to fold",
                )
            with self._lock:
                epoch = int(hello.get("epoch", 0))
                if epoch != self._round:
                    reason = (f"stale round epoch {epoch}: server is at round "
                              f"{self._round}; reconnect with the current epoch")
                    self.rejects.append({"peer": str(conn.peer),
                                         "reason": reason})
                    with contextlib.suppress(OSError):
                        conn.send_ctrl({"type": "reject", "reason": reason})
                    conn.close()
                    return
                if name in self._conns:
                    reason = f"duplicate client {name!r}: already connected"
                    self.rejects.append({"peer": str(conn.peer),
                                         "reason": reason})
                    with contextlib.suppress(OSError):
                        conn.send_ctrl({"type": "reject", "reason": reason})
                    conn.close()
                    return
                self._conns[name] = conn
                self._lost.discard(name)
                self._join_cv.notify_all()
            conn.send_ctrl({"type": "welcome", "round": self._round,
                            "rounds": self.rounds, "clients": self.n_clients,
                            "uplink": self.uplink})

    def wait_for_clients(self, n: Optional[int] = None) -> None:
        """Block until ``n`` (default: the full roster) clients joined."""
        want = self.n_clients if n is None else n
        deadline = time.monotonic() + self.join_timeout_s
        with self._join_cv:
            while len(self._conns) < want:
                left = deadline - time.monotonic()
                if left <= 0 or not self._join_cv.wait(timeout=left):
                    missing = [c for c in self._roster if c not in self._conns]
                    raise TimeoutError(
                        f"{len(self._conns)}/{want} clients joined within "
                        f"{self.join_timeout_s}s; missing {missing}"
                    )

    # -- client failure -----------------------------------------------------
    def _drop(self, name: str, why: str) -> None:
        with self._lock:
            conn = self._conns.pop(name, None)
            self._lost.add(name)
        if conn is not None:
            conn.close()

    # -- downlink -----------------------------------------------------------
    def _downlink_one(self, name: str, rnd: int,
                      weights: Mapping[str, Any]) -> None:
        conn = self._conns.get(name)
        if conn is None:
            raise _ClientLost(name, "not connected at downlink")
        task = make_task(rnd, weights)
        # destination in the headers, same as the simulator's proxy, so
        # egress stages can be link/client-aware
        task.headers.setdefault("client", name)
        pipeline = self.pipelines["task_data"]
        try:
            conn.send_ctrl({"type": "task", "round": rnd})
            driver = sm.ConnectionDriver(conn)
            msg, ctx = pipeline.begin_encode(task)
            # encode-ahead: this is a real socket, so while item k's
            # segments sit in sendmsg the worker encodes item k+1
            # (bitwise-identical wire bytes — see iter_encode_ahead)
            sm.ContainerStreamer(
                driver, self.chunk_size, prefetch=sm.DEFAULT_ENCODE_AHEAD
            ).send_items(
                pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg)
            )
        except (OSError, ConnectionError) as exc:
            raise _ClientLost(name, f"downlink failed: {exc}") from exc
        with self._lock:
            self.bytes_down += driver.bytes_sent

    def _downlink(self, roster: list[str], rnd: int,
                  weights: Mapping[str, Any]) -> list[str]:
        """Broadcast the round's task to ``roster`` from parallel sender
        threads; returns the clients that actually received it."""
        tr = obs_trace.ACTIVE
        failed: dict[str, str] = {}

        def send(name: str) -> None:
            span = (tr.span("fed.downlink", "fed", client=name, round=rnd)
                    if tr else contextlib.nullcontext())
            try:
                with span:
                    self._downlink_one(name, rnd, weights)
            except _ClientLost as exc:
                failed[name] = exc.why

        threads = [threading.Thread(target=send, args=(n,), daemon=True,
                                    name=f"fed-downlink-{n}") for n in roster]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for name, why in failed.items():
            self._drop(name, why)
        return [n for n in roster if n not in failed]

    # -- uplink -------------------------------------------------------------
    def _uplink_one(self, name: str, rnd: int, agg: Any) -> dict[str, Any]:
        """Grant ``name``'s uplink and fold its stream into ``agg``.

        Raises :class:`_ClientLost` on any transport/decode failure — the
        caller must then treat the whole fold as poisoned (a partial
        contribution is already in the running sums) and restart it.
        """
        conn = self._conns.get(name)
        if conn is None:
            raise _ClientLost(name, "not connected at uplink")
        tr = obs_trace.ACTIVE
        span = (tr.span("fed.uplink", "fed", client=name, round=rnd)
                if tr else contextlib.nullcontext())
        with span as sp:
            try:
                conn.send_ctrl({"type": "grant", "round": rnd})
                ctrl = conn.recv_ctrl()
                if ctrl.get("type") != "result" or ctrl.get("round") != rnd:
                    raise _ClientLost(
                        name, f"expected result/round={rnd}, got {ctrl}")
                decoder = self.pipelines["task_result"].decoder(sink=agg)
                recv = sm.ContainerReceiver(consume=decoder.on_item,
                                            decode_item=decoder.decode_item)
                nbytes = conn.recv_stream(recv.on_chunk)
                result = decoder.finish(MessageKind.TASK_RESULT)
            except _ClientLost:
                raise
            except (OSError, ConnectionError, sm.ProtocolError,
                    ValueError, KeyError, struct_error) as exc:
                raise _ClientLost(name, f"uplink failed: {exc}") from exc
            if sp is not None:
                sp.args["nbytes"] = nbytes
        with self._lock:
            self.bytes_up += nbytes
        return dict(result.headers)

    def _gather(self, roster: list[str],
                rnd: int) -> tuple[dict[str, Any], list[str]]:
        """One round's aggregation with crash recovery; returns the new
        global weights and the clients whose contribution is in them.

        Folds every roster client's uplink into a fresh aggregator. If a
        client dies mid-uplink its partial items (and its ``begin``
        sample weight) have poisoned the running sums, so the fold is
        discarded wholesale and restarted over the surviving roster —
        clients re-encode their cached result on the repeat grant, and
        the dead client contributes exactly zero weight.
        """
        survivors = list(roster)
        while True:
            if not survivors:
                raise RuntimeError(
                    f"round {rnd}: every client was lost; nothing to aggregate"
                )
            agg = build_aggregator(self.agg_spec)
            lost: dict[str, str] = {}
            if self.uplink == "ordered":
                for name in survivors:
                    try:
                        self._uplink_one(name, rnd, agg)
                    except _ClientLost as exc:
                        lost[name] = exc.why
                        break  # the fold is poisoned — no point continuing
            else:
                def fold(name: str) -> None:
                    try:
                        self._uplink_one(name, rnd, agg)
                    except _ClientLost as exc:
                        lost[name] = exc.why

                threads = [threading.Thread(target=fold, args=(n,),
                                            daemon=True,
                                            name=f"fed-uplink-{n}")
                           for n in survivors]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            if not lost:
                return agg.finish(), survivors
            for name, why in lost.items():
                self._drop(name, why)
            survivors = [n for n in survivors if n not in lost]
            with self._lock:
                self.restarts += 1

    # -- the round loop -----------------------------------------------------
    def run(self, init_weights: Mapping[str, Any]) -> dict[str, Any]:
        """Run all rounds; returns the final global weights."""
        tracer = None
        trace_spec = self.spec.get("trace")
        if trace_spec:
            tracer = obs_trace.Tracer()
        ctx = (obs_trace.activate(tracer) if tracer is not None
               else contextlib.nullcontext())
        # the spec's kernel_backend selection applies to the whole run:
        # the server's fold kernels here, each client's quantize in its
        # own process (for_spec plumbs the same key)
        with ctx, kernel_backend_scope(self.spec):
            self.wait_for_clients()
            weights = dict(init_weights)
            for rnd in range(self.rounds):
                with self._lock:
                    self._round = rnd
                    roster = [n for n in self._roster if n in self._conns]
                if not roster:
                    raise RuntimeError(f"round {rnd}: no clients connected")
                tr = obs_trace.ACTIVE
                span = (tr.span("fed.round", "round", round=rnd,
                                clients=len(roster))
                        if tr else contextlib.nullcontext())
                t0 = time.monotonic()
                with span:
                    active = self._downlink(roster, rnd, weights)
                    weights, contributed = self._gather(active, rnd)
                self.round_log.append({
                    "round": rnd,
                    "clients": contributed,
                    "wall_s": round(time.monotonic() - t0, 6),
                })
                with self._lock:
                    self._round = rnd + 1
            with self._lock:
                conns = list(self._conns.values())
            for conn in conns:
                with contextlib.suppress(OSError):
                    conn.send_ctrl({"type": "done"})
        if tracer is not None and isinstance(trace_spec, str):
            tracer.write(trace_spec)
        return weights


class FederationClient:
    """One live client: connect, handshake, then react to server control.

    ``run()`` loops on control frames: ``task`` (receive + decode the
    downlink stream, execute the local computation, cache the result),
    ``grant`` (re-encode the cached round result and stream it up —
    idempotent, so a server-side fold restart can simply grant again),
    ``done`` (exit). A ``reject`` at the handshake raises with the
    server's reason.
    """

    def __init__(self, name: str, executor: Any,
                 pipelines: Mapping[str, WirePipeline],
                 address: tuple[str, int], fingerprint: str,
                 epoch: int = 0, chunk_size: int = 1 << 20,
                 timeout_s: Optional[float] = None,
                 kernel_backend: Optional[str] = None) -> None:
        self.name = name
        self.executor = executor
        self.pipelines = dict(pipelines)
        self.address = tuple(address)
        self.fingerprint = fingerprint
        self.epoch = epoch
        self.chunk_size = chunk_size
        self.timeout_s = timeout_s
        self.kernel_backend = kernel_backend
        self.rounds_done = 0

    @classmethod
    def for_spec(cls, spec: Mapping[str, Any], index: int,
                 address: tuple[str, int], epoch: int = 0,
                 timeout_s: Optional[float] = None) -> "FederationClient":
        """Build the client exactly as the spec describes it — same
        executor/pipeline construction path as the simulator, which is
        what makes live weights bitwise-comparable to ``run_job``."""
        spec = live_spec(spec)
        pipelines = build_pipelines_from_spec(spec)
        return cls(
            name=f"site-{index}",
            executor=build_client_executor(spec, index),
            pipelines=pipelines,
            address=address,
            fingerprint=pipeline_fingerprint(pipelines, aggregator_spec(spec)),
            epoch=epoch,
            chunk_size=int(spec["chunk_mb"] * (1 << 20)),
            timeout_s=timeout_s,
            kernel_backend=spec.get("kernel_backend"),
        )

    def run(self) -> int:
        """Participate until the server says ``done``; returns the number
        of rounds this client's results were (last) granted for."""
        with kernel_backend_scope({"kernel_backend": self.kernel_backend}):
            return self._run()

    def _run(self) -> int:
        sock = socket.create_connection(self.address)
        conn = sm.Connection(sock)
        conn.settimeout(self.timeout_s)
        try:
            conn.send_ctrl({"type": "hello", "client": self.name,
                            "epoch": self.epoch, "proto": PROTO,
                            "fingerprint": self.fingerprint})
            resp = conn.recv_ctrl()
            if resp.get("type") != "welcome":
                raise RuntimeError(
                    f"{self.name}: server rejected the handshake: "
                    f"{resp.get('reason', resp)}"
                )
            cached: dict[int, Message] = {}
            while True:
                ctrl = conn.recv_ctrl()
                kind = ctrl.get("type")
                if kind == "task":
                    rnd = int(ctrl["round"])
                    task = self._recv_task(conn)
                    result = self.executor.execute(task)
                    # one round's cache only: grants never reach back
                    # further than the current round's fold restarts
                    cached.clear()
                    cached[rnd] = result
                elif kind == "grant":
                    rnd = int(ctrl["round"])
                    if rnd not in cached:
                        raise RuntimeError(
                            f"{self.name}: granted round {rnd} but no cached "
                            f"result (have {sorted(cached)})"
                        )
                    self._send_result(conn, rnd, cached[rnd])
                    self.rounds_done = rnd + 1
                elif kind == "done":
                    return self.rounds_done
                else:
                    raise RuntimeError(
                        f"{self.name}: unexpected control frame {ctrl}")
        finally:
            conn.close()

    def _recv_task(self, conn: sm.Connection) -> Message:
        decoder = self.pipelines["task_data"].decoder()
        recv = sm.ContainerReceiver(consume=decoder.on_item,
                                    decode_item=decoder.decode_item)
        conn.recv_stream(recv.on_chunk)
        return decoder.finish(MessageKind.TASK_DATA)

    def _send_result(self, conn: sm.Connection, rnd: int,
                     result: Message) -> None:
        # fresh copy per grant: begin_encode may rewrite headers/payload,
        # and a fold restart will ask for this result again
        msg = Message(result.kind, dict(result.payload), dict(result.headers))
        pipeline = self.pipelines["task_result"]
        msg, ctx = pipeline.begin_encode(msg)
        conn.send_ctrl({"type": "result", "round": rnd, "client": self.name})
        # encode-ahead on the uplink too: quantize/crc of item k+1
        # overlaps the socket write of item k (same wire bytes)
        sm.ContainerStreamer(
            sm.ConnectionDriver(conn), self.chunk_size,
            prefetch=sm.DEFAULT_ENCODE_AHEAD,
        ).send_items(
            pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg)
        )


# ---------------------------------------------------------------------------
# Orchestration: spawn subprocess clients + run the server
# ---------------------------------------------------------------------------

def _client_cmd(spec_path: str, index: int, address: tuple[str, int]) -> list[str]:
    return [
        sys.executable, "-m", "repro.launch.federation",
        "--spec", spec_path,
        "--client-index", str(index),
        "--connect", f"{address[0]}:{address[1]}",
    ]


def _client_env() -> dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = f"{src}{os.pathsep}{pp}" if pp else src
    return env


def run_live_federation(
    spec: Mapping[str, Any],
    clients: Optional[int] = None,
    rounds: Optional[int] = None,
    host: str = "127.0.0.1",
    port: int = 0,
    uplink: str = "ordered",
    join_timeout_s: float = 120.0,
    round_timeout_s: float = 600.0,
    spawn: bool = True,
) -> dict[str, Any]:
    """Run one real federation: server in this process, clients as
    subprocesses (``spawn=True``) or left to the caller (``spawn=False``
    — e.g. clients on other machines pointing at ``result["address"]``...
    which in-process tests also use, running :class:`FederationClient`
    on threads).

    Returns final weights, the per-round log (participants + wall
    seconds), wire byte totals, and the clients' exit codes.
    """
    spec = live_spec(spec, clients=clients, rounds=rounds)
    server = FederationServer(
        spec, host=host, port=port, uplink=uplink,
        join_timeout_s=join_timeout_s, round_timeout_s=round_timeout_s,
    ).start()
    procs: list[subprocess.Popen] = []
    spec_path: Optional[str] = None
    t0 = time.monotonic()
    try:
        if spawn:
            # subprocesses must see the *fully resolved* spec (clients /
            # rounds overrides included): the partition is keyed by the
            # client count, so a drifting spec would train on wrong data
            fd, spec_path = tempfile.mkstemp(suffix=".json",
                                             prefix="live_spec_")
            with os.fdopen(fd, "w") as fh:
                json.dump({k: v for k, v in spec.items() if k != "trace"}, fh)
            for i in range(server.n_clients):
                procs.append(subprocess.Popen(
                    _client_cmd(spec_path, i, server.address),
                    env=_client_env(),
                ))
        final = server.run(initial_weights(spec))
        wall_s = time.monotonic() - t0
        exit_codes = []
        for p in procs:
            try:
                exit_codes.append(p.wait(timeout=60))
            except subprocess.TimeoutExpired:
                p.kill()
                exit_codes.append(p.wait())
        return {
            "final_weights": final,
            "address": server.address,
            "round_log": server.round_log,
            "bytes_down": server.bytes_down,
            "bytes_up": server.bytes_up,
            "restarts": server.restarts,
            "rejects": server.rejects,
            "wall_s": round(wall_s, 6),
            "client_exit_codes": exit_codes,
        }
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        server.close()
        if spec_path is not None:
            with contextlib.suppress(OSError):
                os.unlink(spec_path)


# ---------------------------------------------------------------------------
# CLI: `python -m repro.launch.federation`
# ---------------------------------------------------------------------------

def _parse_address(s: str) -> tuple[str, int]:
    host, _, port = s.rpartition(":")
    return host or "127.0.0.1", int(port)


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.federation",
        description="Run a real multi-process federation from a job spec "
                    "(server mode), or one client of it (--client-index).",
    )
    ap.add_argument("--spec", required=True, help="path to a JSON job spec")
    ap.add_argument("--clients", type=int, default=None,
                    help="override the spec's client count (server mode)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the spec's round count (server mode)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral)")
    ap.add_argument("--uplink", choices=UPLINK_MODES, default="ordered")
    ap.add_argument("--join-timeout", type=float, default=120.0)
    ap.add_argument("--round-timeout", type=float, default=600.0)
    ap.add_argument("--no-spawn", action="store_true",
                    help="server only; clients connect from elsewhere")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="write the server's Chrome trace-event file "
                         "(open in Perfetto)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the run summary as JSON")
    ap.add_argument("--verify-sim", action="store_true",
                    help="also run the sequential simulator on the same spec "
                         "and fail unless final weights are bitwise-equal")
    ap.add_argument("--client-index", type=int, default=None,
                    help="client mode: which roster slot this process is")
    ap.add_argument("--connect", metavar="HOST:PORT", default=None,
                    help="client mode: the server address")
    ap.add_argument("--epoch", type=int, default=0,
                    help="client mode: round epoch to present (rejoin)")
    args = ap.parse_args(argv)

    with open(args.spec) as fh:
        spec = json.load(fh)

    if args.client_index is not None:
        if not args.connect:
            ap.error("--client-index requires --connect HOST:PORT")
        client = FederationClient.for_spec(
            spec, args.client_index, _parse_address(args.connect),
            epoch=args.epoch, timeout_s=args.round_timeout,
        )
        client.run()
        return 0

    if args.trace:
        spec["trace"] = args.trace
    result = run_live_federation(
        spec, clients=args.clients, rounds=args.rounds,
        host=args.host, port=args.port, uplink=args.uplink,
        join_timeout_s=args.join_timeout, round_timeout_s=args.round_timeout,
        spawn=not args.no_spawn,
    )
    final = result.pop("final_weights")
    result["weights_sha256"] = hashlib.sha256(
        b"".join(np.asarray(final[k]).tobytes() for k in sorted(final))
    ).hexdigest()

    if args.verify_sim:
        from repro.fl.job import run_job

        sim_spec = {k: v for k, v in live_spec(
            spec, clients=args.clients, rounds=args.rounds).items()
            if k != "trace"}
        sim = run_job(sim_spec)
        equal = weights_bitwise_equal(final, sim["final_weights"])
        result["sim_bitwise_equal"] = equal
        # wall-vs-sim per-round timing: the live server and the
        # sequential controller record the same round_log shape, so the
        # summary can show where deployment overhead (process hops, TCP
        # framing, stragglers) lands round by round
        result["round_timing"] = [
            {
                "round": lv.get("round", i),
                "live_wall_s": lv.get("wall_s"),
                "sim_wall_s": sv.get("wall_s"),
                "delta_s": round(float(lv.get("wall_s", 0.0))
                                 - float(sv.get("wall_s", 0.0)), 6),
            }
            for i, (lv, sv) in enumerate(
                zip(result.get("round_log", []), sim.get("round_log", []))
            )
        ]
        if not equal:
            out = json.dumps(result, indent=1, default=str)
            if args.json:
                with open(args.json, "w") as fh:
                    fh.write(out + "\n")
            print(out)
            print("FAIL: live weights differ from the sequential simulator",
                  file=sys.stderr)
            return 1

    out = json.dumps(result, indent=1, default=str)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(out + "\n")
    print(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
