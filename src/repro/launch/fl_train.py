"""Federated training on the multi-pod mesh — the mesh view of the paper.

Each pod is one FL site: it holds a model replica (sharded over its own
data/model axes), runs ``local_steps`` of AdamW on its own (non-IID-able)
data shard, then the round closes with a cross-pod aggregation of the
parameter delta:

    --agg fp32        paper-faithful full-precision aggregation (pmean)
    --agg int8        quantized collective (blockwise-int8 wire, fp32 agg)
    --agg int8-bucket quantized + bucketed (streaming) collective

Demo (CPU, fake devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.fl_train --arch qwen1.5-0.5b --smoke \
      --rounds 5 --local-steps 2 --pods 2 --agg int8
"""
from __future__ import annotations

import argparse
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import collectives as C
from repro.data import dirichlet_partition
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.compat import make_mesh, shard_map


def make_fl_round(model, *, local_steps: int, lr: float, agg: str, mesh):
    """One federated round as a single jitted program:

    shard_map over 'pod' (each pod trains locally), then cross-pod
    aggregation of the parameter delta with the configured wire format.
    """

    def local_train(params, opt_state, batches):
        def one_step(carry, batch):
            params, opt_state = carry
            (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
            params, opt_state, _ = adamw_update(params, grads, opt_state, jnp.float32(lr))
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(one_step, (params, opt_state), batches)
        return params, opt_state, losses

    def fl_round(params, opt_state, batches):
        # shard_map keeps the (now size-1) pod dim on the batch stack
        batches = jax.tree_util.tree_map(lambda x: x[0], batches)
        # ---- local phase (per pod) ----
        start = params
        params, opt_state, losses = local_train(params, opt_state, batches)
        # ---- aggregation phase (cross-pod; the FL communication) ----
        delta = jax.tree_util.tree_map(
            lambda new, old: new.astype(jnp.float32) - old.astype(jnp.float32), params, start
        )
        if agg == "fp32":
            delta = C.fp32_fedavg_tree(delta, axis_name="pod")
        elif agg == "int8":
            delta = C.quantized_fedavg_tree(delta, axis_name="pod")
        elif agg == "int8-bucket":
            delta = C.quantized_fedavg_tree(delta, axis_name="pod", bucket_bytes=8 << 20)
        else:
            raise ValueError(agg)
        params = jax.tree_util.tree_map(
            lambda old, d: (old.astype(jnp.float32) + d).astype(old.dtype), start, delta
        )
        return params, opt_state, jnp.mean(losses)

    pspec = P()  # params replicated within pod; pod axis handled by shard_map
    batch_spec = P("pod")  # leading dim = pod-local batches

    fl_round_sm = shard_map(
        fl_round,
        mesh=mesh,
        in_specs=(pspec, pspec, batch_spec),
        out_specs=(pspec, pspec, pspec),
        check=False,
    )
    return jax.jit(fl_round_sm, donate_argnums=(0, 1))


def run(args) -> dict[str, Any]:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = create_model(cfg)
    mesh = make_mesh((args.pods, jax.device_count() // args.pods), ("pod", "data"))
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    datasets = dirichlet_partition(
        cfg.vocab_size, args.seq, args.pods, alpha=args.alpha, seed=args.seed
    )
    round_fn = make_fl_round(
        model, local_steps=args.local_steps, lr=args.lr, agg=args.agg, mesh=mesh
    )
    history = []
    for rnd in range(args.rounds):
        # stack per-pod local batches: (pods, local_steps, B, S) — sample
        # ONCE per (pod, step) so tokens and labels stay paired
        samples = [
            [ds.sample(args.batch) for _ in range(args.local_steps)] for ds in datasets
        ]
        batches = {
            k: jnp.stack(
                [jnp.stack([jnp.asarray(s[k]) for s in pod]) for pod in samples]
            )
            for k in ("tokens", "labels")
        }
        t0 = time.time()
        params, opt_state, loss = round_fn(params, opt_state, batches)
        loss = float(loss)
        history.append(loss)
        print(f"round {rnd:3d} agg={args.agg:11s} loss={loss:.4f} ({time.time()-t0:.1f}s)")
    return {"history": history, "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--agg", choices=["fp32", "int8", "int8-bucket"], default="int8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run(args)
    print(f"final loss {out['history'][-1]:.4f} (start {out['history'][0]:.4f})")


if __name__ == "__main__":
    main()
