"""Multi-pod dry-run: AOT lower + compile every (architecture x input
shape) on the production meshes, with no device allocation
(ShapeDtypeStruct stand-ins), and emit the roofline terms.

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all                      # 40-pair sweep
    python -m repro.launch.dryrun --all --multi-pod          # 512-chip pass

Results are appended as JSON lines to experiments/dryrun/*.json and
consumed by benchmarks/roofline_report.py and EXPERIMENTS.md.
"""
# The dry-run (and ONLY the dry-run) fakes 512 host devices so
# jax.make_mesh can build the production mesh. Must run before ANY other
# import that could initialize jax.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from typing import Any, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch import sharding as SH  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    INPUT_SHAPES,
    apply_variant,
    input_specs,
    params_specs,
    plan_for,
)
from repro.models import create_model  # noqa: E402
from repro.optim import adamw_init, adamw_update  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _batch_shardings(mesh, batch_specs, rules):
    return jax.tree_util.tree_map(
        lambda s: SH.batch_sharding(mesh, s.shape, rules), batch_specs
    )


def build_step(cfg, plan, mesh, rules=None):
    """Returns (step_fn, arg_specs, in_shardings, out_shardings, donate)."""
    rules = rules or SH.DEFAULT_RULES
    model = create_model(cfg)
    p_specs = params_specs(cfg)
    p_shard = SH.tree_shardings(p_specs, model.param_axes(), mesh, rules)
    specs = input_specs(cfg, plan)

    if plan.kind == "train":
        opt_specs = jax.eval_shape(lambda: adamw_init(p_specs))
        opt_shard = jax.tree_util.tree_map(
            lambda leaf, sh: sh,
            (opt_specs.m, opt_specs.v),
            (p_shard, p_shard),
        )
        opt_shard_full = type(opt_specs)(SH.replicated(mesh), opt_shard[0], opt_shard[1])
        b_shard = _batch_shardings(mesh, specs["batch"], rules)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
            params, opt_state, info = adamw_update(
                params, grads, opt_state, jnp.float32(1e-4)
            )
            return params, opt_state, {**metrics, "loss": loss, **info}

        args = (p_specs, opt_specs, specs["batch"])
        in_sh = (p_shard, opt_shard_full, b_shard)
        out_sh = (p_shard, opt_shard_full, None)
        return train_step, args, in_sh, out_sh, (0, 1)

    if plan.kind == "prefill":

        def prefill_step(params, inputs):
            extra = inputs.get("frames", inputs.get("patches"))
            if extra is not None:
                return model.prefill(params, inputs["tokens"], extra)
            return model.prefill(params, inputs["tokens"])

        b_shard = _batch_shardings(mesh, specs, rules)
        args = (p_specs, specs)
        return prefill_step, args, (p_shard, b_shard), None, ()

    # decode
    cache_shard = SH.tree_shardings(specs["cache"], model.cache_axes(), mesh, rules)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    tok_shard = SH.batch_sharding(mesh, specs["tokens"].shape, rules)
    args = (p_specs, specs["cache"], specs["tokens"], specs["pos"])
    in_sh = (p_shard, cache_shard, tok_shard, SH.replicated(mesh))
    out_sh = (None, cache_shard)
    return serve_step, args, in_sh, out_sh, (1,)


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules=None,
    variant_override: Optional[str] = None,
    tag: str = "baseline",
    save: bool = True,
    mesh=None,
) -> dict[str, Any]:
    cfg = get_config(arch).with_overrides(
        param_dtype=jnp.bfloat16, activ_dtype=jnp.bfloat16
    )
    plan = plan_for(cfg, shape_name)
    if variant_override:
        plan = plan.__class__(**{**plan.__dict__, "variant": variant_override})
    cfg = apply_variant(cfg, plan)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    t0 = time.time()
    from repro.models import layers as model_layers
    from repro.launch import sharding as sharding_mod

    step, args, in_sh, out_sh, donate = build_step(cfg, plan, mesh, rules)
    model_layers.set_sharding_context(mesh, rules or sharding_mod.DEFAULT_RULES)
    try:
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=out_sh,
                donate_argnums=donate if donate else (),
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        model_layers.set_sharding_context(None, None)

    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else (cost_list or {})
    try:
        mem_an = compiled.memory_analysis()
        memory = (
            {
                "argument_bytes": float(getattr(mem_an, "argument_size_in_bytes", 0)),
                "output_bytes": float(getattr(mem_an, "output_size_in_bytes", 0)),
                "temp_bytes": float(getattr(mem_an, "temp_size_in_bytes", 0)),
                "peak_bytes": float(
                    getattr(mem_an, "peak_memory_in_bytes", 0)
                    or getattr(mem_an, "temp_size_in_bytes", 0)
                ),
            }
            if mem_an is not None
            else None
        )
    except Exception:
        memory = None
    hlo_text = compiled.as_text()

    info = INPUT_SHAPES[shape_name]
    report = RL.analyze(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        variant=plan.variant,
        chips=chips,
        cfg=cfg,
        kind=plan.kind,
        seq_len=info["seq_len"],
        global_batch=info["global_batch"],
        cost=cost,
        hlo_text=hlo_text,
        memory_per_device=memory,
    )
    out = report.to_dict()
    out["tag"] = tag
    out["lower_s"] = round(t_lower, 2)
    out["compile_s"] = round(t_compile, 2)
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fname = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}__{tag}.json")
        with open(fname, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--variant", choices=["paper", "swa"], default=None)
    args = ap.parse_args()

    pairs = (
        [(a, s) for a in ARCH_IDS if a != "llama3.2-1b" for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    for arch, shape in pairs:
        try:
            out = run_one(
                arch,
                shape,
                multi_pod=args.multi_pod,
                variant_override=args.variant,
                tag=args.tag,
                mesh=mesh,
            )
            print(
                f"[ok] {arch:24s} {shape:12s} mesh={out['mesh']:9s} "
                f"variant={out['variant']:5s} flops={out['hlo_flops']:.3e} "
                f"bytes={out['hlo_bytes']:.3e} wire={out['collective_wire_bytes']:.3e} "
                f"bottleneck={out['bottleneck']} compile={out['compile_s']}s"
            )
        except Exception as e:  # noqa: BLE001 — sweep must report every pair
            print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {e}")
            raise


if __name__ == "__main__":
    main()
