"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_wire_bytes_per_device / link_bw

Hardware constants (TPU v5e, from the brief): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. HLO_FLOPs / HLO_bytes come from
``compiled.cost_analysis()``; collective bytes from parsing the optimized
HLO (repro.utils.hlo). MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference)
with N = active params — the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/dispatch/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.models.base import ModelConfig, active_param_count
from repro.utils import hlo as hlo_utils

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    variant: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_wire_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_flop_ratio: float
    collective_detail: dict[str, dict[str, float]]
    memory_per_device: Optional[dict[str, float]] = None

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, kind: str, seq_len: int, global_batch: int) -> float:
    n = active_param_count(cfg)
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * global_batch


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    variant: str,
    chips: int,
    cfg: ModelConfig,
    kind: str,
    seq_len: int,
    global_batch: int,
    cost: dict[str, float],
    hlo_text: str,
    memory_per_device: Optional[dict[str, float]] = None,
) -> RooflineReport:
    # Loop-aware per-device quantities derived from the SPMD-partitioned
    # module (XLA's cost_analysis counts while bodies once — see
    # repro.utils.hlo). `cost` (cost_analysis) is kept for reference only.
    del cost
    flops = hlo_utils.module_flops(hlo_text)
    bytes_accessed = hlo_utils.module_traffic_bytes(hlo_text)
    coll = hlo_utils.collective_stats(hlo_text)
    wire = sum(s["wire_bytes"] for s in coll.values())
    mf = model_flops(cfg, kind, seq_len, global_batch)
    # all three inputs are PER-DEVICE quantities
    compute_s = flops / PEAK_FLOPS if flops else 0.0
    memory_s = bytes_accessed / HBM_BW if bytes_accessed else 0.0
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        variant=variant,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_accessed,
        collective_wire_bytes=wire,
        model_flops=mf,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        useful_flop_ratio=(mf / (flops * chips)) if flops else 0.0,
        collective_detail=coll,
        memory_per_device=memory_per_device,
    )
