"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

The four shapes from the brief:

=============  ==========  ============  ===================
name           seq_len     global_batch  step lowered
=============  ==========  ============  ===================
train_4k       4,096       256           train_step
prefill_32k    32,768      32            prefill
decode_32k     32,768      128           serve_step (1 token)
long_500k      524,288     1             serve_step (1 token)
=============  ==========  ============  ===================

``long_500k`` requires sub-quadratic attention: native for ssm/hybrid;
dense-family archs run it under the sliding-window *variant*
(``variant='swa'``, window 4096) — the paper-faithful full-attention
config skips it (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import create_model
from repro.models.base import ModelConfig

SWA_WINDOW = 4096

INPUT_SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

# families whose serve path is O(1)/O(window) state natively
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapePlan:
    shape_name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int
    variant: str                 # "paper" | "swa"
    skip_reason: Optional[str] = None


def plan_for(cfg: ModelConfig, shape_name: str, *, allow_swa: bool = True) -> ShapePlan:
    info = INPUT_SHAPES[shape_name]
    variant = "paper"
    skip = None
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        if allow_swa:
            variant = "swa"  # beyond-paper sliding-window variant
        else:
            skip = (
                f"{cfg.arch_id} is full-attention; long_500k needs sub-quadratic "
                "attention (run with --variant swa)"
            )
    return ShapePlan(shape_name, info["kind"], info["seq_len"], info["global_batch"], variant, skip)


def apply_variant(cfg: ModelConfig, plan: ShapePlan) -> ModelConfig:
    if plan.variant == "swa":
        return cfg.with_overrides(sliding_window=SWA_WINDOW)
    return cfg


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, plan: ShapePlan) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the lowered step

    (weak-type-correct, shardable, no device allocation)."""
    Bsz, S = plan.global_batch, plan.seq_len
    if plan.kind == "train":
        batch: dict[str, Any] = {
            "tokens": _sds((Bsz, S), jnp.int32),
            "labels": _sds((Bsz, S), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = _sds((Bsz, cfg.encoder_seq, cfg.d_model), cfg.activ_dtype)
        if cfg.family == "vlm":
            batch["patches"] = _sds((Bsz, cfg.num_patches, cfg.d_model), cfg.activ_dtype)
        return {"batch": batch}
    if plan.kind == "prefill":
        out: dict[str, Any] = {"tokens": _sds((Bsz, S), jnp.int32)}
        if cfg.family == "encdec":
            out["frames"] = _sds((Bsz, cfg.encoder_seq, cfg.d_model), cfg.activ_dtype)
        if cfg.family == "vlm":
            out["patches"] = _sds((Bsz, cfg.num_patches, cfg.d_model), cfg.activ_dtype)
        return out
    # decode: ONE new token against a seq_len-sized cache/state
    model = create_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(Bsz, S))
    return {
        "cache": cache,
        "tokens": _sds((Bsz, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def params_specs(cfg: ModelConfig) -> Any:
    model = create_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
