"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every model parameter carries a tuple of logical axis names (from its
ParamDef); these rules map them to mesh axes with an automatic fallback:
if a dim is not divisible by the product of its mapped mesh axes, the
mapping is dropped (replicated) — so odd head counts (whisper 12H,
recurrentgemma 10H) and batch=1 decode shapes lower cleanly everywhere.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import base as B

# rule set: logical axis -> mesh axes (tried in order, dropped if indivisible)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    B.BATCH: ("pod", "data"),
    B.VOCAB: ("model",),
    B.EMBED: ("data",),      # FSDP: weights' d_model dim sharded over data
    B.Q_FEAT: ("model",),
    B.KV_FEAT: ("model",),
    B.MLP: ("model",),
    B.EXPERT: ("model",),
    B.STATE: ("model",),
    B.SEQ: (),
    B.LAYER: (),
    B.CONV: (),
}

# variant without FSDP (pure tensor-parallel; small models replicate embed)
TP_ONLY_RULES = dict(DEFAULT_RULES, **{B.EMBED: ()})


def spec_for(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: dict[str, tuple[str, ...]],
) -> P:
    """Build a PartitionSpec for one array, honoring divisibility."""
    used: set = set()
    entries: list[Any] = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in rules:
            entries.append(None)
            continue
        mesh_axes = [
            m for m in rules[ax] if m in mesh.axis_names and m not in used
        ]
        # drop axes until the dim divides the product
        while mesh_axes:
            prod = int(np.prod([mesh.shape[m] for m in mesh_axes]))
            if dim % prod == 0:
                break
            mesh_axes = mesh_axes[:-1]
        if mesh_axes:
            used.update(mesh_axes)
            entries.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            entries.append(None)
    return P(*entries)


def tree_shardings(
    shapes_tree: Any,
    axes_tree: Any,
    mesh: Mesh,
    rules: Optional[dict[str, tuple[str, ...]]] = None,
) -> Any:
    """shapes_tree: pytree of ShapeDtypeStruct/arrays; axes_tree: same

    structure of logical-axis tuples -> pytree of NamedSharding."""
    rules = rules or DEFAULT_RULES

    def one(leaf, axes):
        return NamedSharding(mesh, spec_for(leaf.shape, axes, mesh, rules))

    # axes_tree tuples sit at shapes_tree's leaf positions; tree_map's
    # flatten-up-to keeps them whole
    return jax.tree_util.tree_map(one, shapes_tree, axes_tree)


def batch_sharding(mesh: Mesh, shape: Sequence[int], rules=None) -> NamedSharding:
    """Standard activation sharding: dim0 = batch over (pod, data), with

    divisibility fallback (batch=1 decode shapes replicate)."""
    rules = rules or DEFAULT_RULES
    axes = (B.BATCH,) + (None,) * (len(shape) - 1)
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
