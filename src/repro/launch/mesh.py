"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
the **federation axis** (DESIGN.md §3): each pod holds one FL site's
model replica; cross-pod collectives carry the (quantized) FL round.

Functions, not module constants — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many devices exist (tests)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
