"""Single-program training driver (centralized or one FL site's local

trainer). Runs a real training loop on the available devices; the same
``make_train_step`` is what the dry-run lowers on the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 20 --batch 8 --seq 128 --smoke
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import SyntheticLMDataset
from repro.models import create_model
from repro.optim import adamw_init, adamw_update, cosine_schedule


def make_train_step(model, schedule):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        lr = schedule(opt_state.step)
        params, opt_state, info = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {**metrics, "loss": loss, **info}

    return train_step


def train_loop(
    cfg,
    *,
    steps: int,
    batch_size: int,
    seq_len: int,
    lr: float = 3e-4,
    seed: int = 0,
    dataset: Optional[SyntheticLMDataset] = None,
    params: Optional[Any] = None,
    log_every: int = 10,
    extra_batch: Optional[dict[str, np.ndarray]] = None,
) -> tuple[Any, list]:
    model = create_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    schedule = cosine_schedule(lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    step_fn = jax.jit(make_train_step(model, schedule), donate_argnums=(0, 1))
    dataset = dataset or SyntheticLMDataset(cfg.vocab_size, seq_len, seed=seed)
    history = []
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in dataset.sample(batch_size).items()}
        if extra_batch:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch.items()})
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:4d} loss {loss:.4f} ({(time.time()-t0)*1e3:.0f} ms)")
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": np.zeros((args.batch, cfg.encoder_seq, cfg.d_model), np.float32)}
    if cfg.family == "vlm":
        extra = {"patches": np.zeros((args.batch, cfg.num_patches, cfg.d_model), np.float32)}
    _, history = train_loop(
        cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        extra_batch=extra,
    )
    print(f"final loss: {history[-1]:.4f} (start {history[0]:.4f})")


if __name__ == "__main__":
    main()
