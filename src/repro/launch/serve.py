"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import create_model


def generate(
    model,
    params,
    prompts: jnp.ndarray,
    *,
    gen_len: int,
    extra: Optional[dict[str, Any]] = None,
    greedy: bool = True,
    rng: Optional[jax.Array] = None,
):
    """prompts: (B, P) int32 -> (B, P+gen_len) tokens."""
    Bsz, P = prompts.shape
    if extra:
        frames = extra.get("frames")
        patches = extra.get("patches")
        arg = frames if frames is not None else patches
        logits, cache = model.prefill(params, prompts, arg)
    else:
        logits, cache = model.prefill(params, prompts)

    decode = jax.jit(model.decode_step)
    out = [prompts]
    # prefill caches are sized to the prompt for full-attention models, so
    # decode continues with a fresh right-sized cache warmed by replay when
    # needed; recurrent/window models continue from the returned state.
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    if (hasattr(model, "init_cache") and model.__class__.__name__ == "DecoderLM"
            and model.cfg.sliding_window is None):
        # replay prompt into a (P+gen_len)-sized cache
        cache = model.init_cache(Bsz, P + gen_len)
        for t in range(P):
            logits, cache = decode(params, cache, prompts[:, t : t + 1], jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos0 = P
    for i in range(gen_len):
        out.append(tok)
        if i == gen_len - 1:
            break
        logits, cache = decode(params, cache, tok, jnp.int32(pos0 + i))
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits[:, 0]).astype(jnp.int32)[:, None]
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.with_overrides(remat=False)
    model = create_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)}
    if cfg.family == "vlm":
        extra = {"patches": jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.float32)}
    t0 = time.time()
    tokens = generate(model, params, prompts, gen_len=args.gen, extra=extra)
    dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(tokens[0, -args.gen:]))


if __name__ == "__main__":
    main()
