"""Heterogeneous-fleet demo: FedAsync vs tiered selection under churn,
with precision that tracks each client's link.

Eight clients spread from fiber to 3G train a toy least-squares model
while a random availability trace takes them on- and offline (dispatches
to offline clients are deferred; departures mid round trip interrupt and
resume). An AdaptiveQuantizeFilter bound to the runtime's network model
picks each client's wire precision from its simulated link — fiber ships
fp32, 3G ships NF4 — with no per-client configuration.

    PYTHONPATH=src python examples/hetero_federation.py
"""
import numpy as np

from repro.core.filters import (
    AdaptiveQuantizeFilter,
    DequantizeFilter,
    FilterChain,
    FilterPoint,
    no_filters,
)
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import (
    FedAsyncPolicy,
    RuntimeConfig,
    TieredPolicy,
    heterogeneous_network,
    random_availability,
)

NUM_CLIENTS, ROUNDS, DIM = 8, 5, 32 * 1024
NAMES = [f"site-{i}" for i in range(NUM_CLIENTS)]


def make_client(name: str, seed: int, w_true: np.ndarray, losses: list) -> TrainExecutor:
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((256, DIM)).astype(np.float32) / np.sqrt(DIM)
    y = X @ w_true

    def train_fn(params, rnd):
        w = np.asarray(params["w"], np.float32).copy()
        # keyed by model version: the append order is wall-clock thread
        # order (nondeterministic), so the report sorts before slicing
        losses.append((rnd, float(np.mean((X @ w - y) ** 2))))
        for _ in range(2):
            w -= 0.8 * (X.T @ (X @ w - y))
        return {"w": w}, len(y), {"loss": float(np.mean((X @ w - y) ** 2))}

    return TrainExecutor(name, train_fn)


def build_filters(network):
    filt = AdaptiveQuantizeFilter.from_network(network, budget_s=0.05)
    server = no_filters()
    server[FilterPoint.TASK_DATA_OUT] = FilterChain([filt])
    server[FilterPoint.TASK_RESULT_IN] = FilterChain([DequantizeFilter()])
    client = no_filters()
    client[FilterPoint.TASK_DATA_IN] = FilterChain([DequantizeFilter()])
    client[FilterPoint.TASK_RESULT_OUT] = FilterChain([filt])
    return server, client, filt


def run(policy_name: str) -> None:
    w_true = np.sin(np.linspace(0, 8 * np.pi, DIM)).astype(np.float32)
    network = heterogeneous_network(NAMES, seed=0, compute_base_s=0.3, compute_spread=5.0)
    availability = random_availability(NAMES, mean_online_s=90.0, mean_offline_s=30.0,
                                       horizon_s=600.0, seed=0)
    server_f, client_f, filt = build_filters(network)
    if policy_name == "fedasync":
        policy = FedAsyncPolicy(total_tasks=ROUNDS * NUM_CLIENTS, mixing_rate=0.6)
    else:
        policy = TieredPolicy(FedAvgAggregator(), num_rounds=ROUNDS * 2,
                              num_tiers=3, network=network, seed=1)
    losses: list = []
    sim = FLSimulator(
        [make_client(n, i, w_true, losses) for i, n in enumerate(NAMES)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=server_f,
        client_filters=client_f,
        runtime=RuntimeConfig(seed=0, max_concurrency=NUM_CLIENTS,
                              dropout_prob=0.05, max_retries=2),
        policy=policy,
        network=network,
        availability=availability,
    )
    sim.run({"w": np.zeros(DIM, np.float32)})
    ordered = [loss for _, loss in sorted(losses)]
    k = max(1, len(ordered) // 4)
    first, last = np.mean(ordered[:k]), np.mean(ordered[-k:])
    s = sim.scheduler.stats
    print(f"\n== {policy_name} ==")
    print(f"  simulated makespan: {s.sim_time_s:7.2f} s "
          f"| model updates: {s.model_updates} "
          f"| client loss {first:.3f} -> {last:.3f}")
    print(f"  dispatches: {s.dispatches} | deferrals: {s.deferrals} "
          f"| interruptions: {s.interruptions} | dropouts: {s.dropouts} "
          f"| wire: {sim.stats.bytes_sent / 1e6:.2f} MB")
    if policy_name == "tiered":
        print(f"  tiers: {policy.tiers}")
        print(f"  rounds served by tier: {policy.selected_tiers}")
    print("  link -> wire precision (adaptive):")
    for n in NAMES:
        fmt = filt.last_fmt_by_client.get(n, "-")
        print(f"    {n}: {network.link(n).name:9s} -> {fmt}")


def main() -> None:
    run("fedasync")
    run("tiered")


if __name__ == "__main__":
    main()
