"""Paper experiment (Fig. 4 + Fig. 5 + Table II): single-site federated

SFT vs centralized, under every message-quantization option, with the
wire savings per round.

    PYTHONPATH=src python examples/fl_sft_quantized.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig45_convergence import centralized, federated  # noqa: E402
from benchmarks.table2_message_size import llama32_1b_layout  # noqa: E402
from repro.core.quantization import message_size_report  # noqa: E402


def main() -> None:
    print("== Fig 4: centralized vs single-site FL ==")
    cen = centralized()
    fl = federated(None)
    print(f"centralized final loss {np.mean(cen[-8:]):.4f}")
    print(f"federated   final loss {np.mean(fl[-8:]):.4f}")

    print("\n== Fig 5: FL with message quantization ==")
    for fmt in ("fp16", "blockwise8", "fp4", "nf4"):
        flq = federated(fmt)
        print(f"{fmt:11s} final loss {np.mean(flq[-8:]):.4f} "
              f"(gap to centralized {abs(np.mean(flq[-8:]) - np.mean(cen[-8:])):.4f})")

    print("\n== Table II: Llama-3.2-1B message sizes ==")
    layout = llama32_1b_layout()
    for fmt in ("fp32", "fp16", "blockwise8", "fp4", "nf4"):
        r = message_size_report(layout, fmt)
        print(f"{fmt:11s} {r['model_mb']:8.2f} MB + {r['meta_mb']:6.2f} MB meta "
              f"= {r['fp32_pct']:6.2f} % of fp32")


if __name__ == "__main__":
    main()
