"""Batched serving example: prefill + greedy decode with a KV cache for a

dense arch, and O(1)-state decode for the recurrent archs — the serve
path the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import create_model


def main() -> None:
    for arch in ("qwen1.5-0.5b", "xlstm-125m", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch).with_overrides(remat=False)
        model = create_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
        t0 = time.time()
        out = generate(model, params, prompts, gen_len=12)
        dt = time.time() - t0
        state_kind = {"ssm": "O(1) recurrent state", "hybrid": "O(window) hybrid state"}.get(
            cfg.family, "KV cache"
        )
        print(f"{arch:20s} [{state_kind:22s}] generated {out.shape[1]-16} tokens x "
              f"{out.shape[0]} seqs in {dt:.1f}s -> {np.asarray(out[0, 16:24])}")


if __name__ == "__main__":
    main()
