"""Paper §III / Table III demo: one global-weight transmission under the

three streaming settings, with byte-exact peak transmission memory — plus
the pull-mode ObjectRetriever and a real-TCP driver round trip.

    PYTHONPATH=src python examples/streaming_demo.py
"""
import os
import tempfile
import time

import numpy as np

from repro.core import serialization as ser
from repro.core import streaming as sm
from repro.checkpoint import save_checkpoint
from repro.checkpoint.streaming_ckpt import iter_checkpoint
from repro.utils.mem import MemoryMeter


def main() -> None:
    rng = np.random.default_rng(0)
    # embed-dominated model dict, like Llama's Table I layout
    sd = {"embed_tokens": rng.standard_normal((16384, 512)).astype(np.float32)}
    for i in range(8):
        sd[f"layers.{i}.w"] = rng.standard_normal((512, 2048)).astype(np.float32)
    total = sum(v.nbytes for v in sd.values())
    print(f"model: {len(sd)} tensors, {total/1e6:.1f} MB "
          f"(largest item {max(v.nbytes for v in sd.values())/1e6:.1f} MB)\n")

    tmp = tempfile.mkdtemp()
    src = os.path.join(tmp, "model.bin")
    open(src, "wb").write(ser.serialize_container(sd))

    for mode in ("regular", "container", "file"):
        meter = MemoryMeter()
        t0 = time.time()
        with meter.activate():
            driver = sm.LoopbackDriver()
            if mode == "regular":
                recv = sm.BlobReceiver(); driver.connect(recv.on_chunk)
                sm.ObjectStreamer(driver).send_container(sd)
            elif mode == "container":
                recv = sm.ContainerReceiver(consume=lambda n, v: None)
                driver.connect(recv.on_chunk)
                sm.ContainerStreamer(driver).send_container(sd)
            else:
                recv = sm.FileReceiver(os.path.join(tmp, "out.bin"))
                driver.connect(recv.on_chunk)
                sm.FileStreamer(driver).send_file(src)
        print(f"{mode:10s} peak transmission memory {meter.peak/1e6:8.2f} MB "
              f"({time.time()-t0:.2f}s)")

    # pull-mode retrieval (paper contribution 2: ObjectRetriever)
    retr = sm.ObjectRetriever()
    retr.register_container("global_weights", sd)
    got = retr.retrieve("global_weights", mode="container")
    assert set(got) == set(sd)
    print("\nObjectRetriever: container pulled OK")

    # streaming checkpoint: written item-by-item, servable by FileStreamer
    ck = os.path.join(tmp, "ckpt.stream")
    nbytes = save_checkpoint(ck, sd, fmt="nf4")  # 4-bit at rest
    back = dict(iter_checkpoint(ck))  # streamed item-by-item off disk
    err = max(float(np.max(np.abs(back[k] - sd[k]))) for k in sd)
    print(f"streaming checkpoint: {nbytes/1e6:.1f} MB on disk (nf4), "
          f"max dequant err {err:.3f}")

    # driver swap: same streamer over real TCP
    driver = sm.TCPDriver()
    recv = sm.ContainerReceiver()
    driver.connect(recv.on_chunk)
    sm.ContainerStreamer(driver).send_container(sd)
    driver.close()
    assert set(recv.result) == set(sd)
    print("TCP driver: container streamed over localhost OK")


if __name__ == "__main__":
    main()
