"""Async federation demo: stragglers, dropouts, and buffered aggregation.

Eight clients on heterogeneous links (fiber down to 3G) train a toy
least-squares model. The same task budget runs twice through the
event-driven runtime: once with the round-barrier SyncPolicy (every
round waits for the 3G straggler) and once with FedBuff buffered async
aggregation (fast clients keep contributing). Both runs use int8
message quantization over the real streaming transport and inject
seeded client dropouts; timings are simulated seconds derived from the
actual wire bytes.

    PYTHONPATH=src python examples/async_federation.py
"""
import numpy as np

from repro.core.filters import two_way_quantization
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import EventKind, FedBuffPolicy, RuntimeConfig, heterogeneous_network

NUM_CLIENTS, ROUNDS, DIM = 8, 5, 512


def make_client(name: str, seed: int, w_true: np.ndarray) -> TrainExecutor:
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((1024, DIM)).astype(np.float32)
    y = X @ w_true

    def train_fn(params, rnd):
        w = np.asarray(params["w"], np.float32).copy()
        for _ in range(2):
            w -= 0.8 * (X.T @ (X @ w - y)) / len(y)
        return {"w": w}, len(y), {"loss": float(np.mean((X @ w - y) ** 2))}

    return TrainExecutor(name, train_fn)


def run(policy_name: str) -> None:
    names = [f"site-{i}" for i in range(NUM_CLIENTS)]
    w_true = np.sin(np.linspace(0, 8 * np.pi, DIM)).astype(np.float32)
    filters = two_way_quantization("blockwise8")
    policy = (
        FedBuffPolicy(total_tasks=ROUNDS * NUM_CLIENTS, buffer_size=4)
        if policy_name == "fedbuff"
        else None  # default: SyncPolicy, bitwise-equal to ScatterAndGather
    )
    sim = FLSimulator(
        [make_client(n, i, w_true) for i, n in enumerate(names)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=filters,
        client_filters=filters,
        runtime=RuntimeConfig(seed=0, max_concurrency=NUM_CLIENTS,
                              dropout_prob=0.1, max_retries=2),
        policy=policy,
        network=heterogeneous_network(names, seed=0, compute_base_s=0.3, compute_spread=5.0),
    )
    final = sim.run({"w": np.zeros(DIM, np.float32)})
    err = float(np.max(np.abs(np.asarray(final["w"]) - w_true)))
    s = sim.scheduler.stats
    print(f"\n== {policy_name} ==")
    print(f"  simulated makespan: {s.sim_time_s:7.2f} s "
          f"| model updates: {s.model_updates} | max |w - w*|: {err:.3f}")
    print(f"  dispatches: {s.dispatches} | dropouts: {s.dropouts} "
          f"| retries: {s.retries} | wire: {sim.stats.bytes_sent / 1e6:.2f} MB")
    completions = [e for e in sim.scheduler.timeline if e.kind is EventKind.COMPLETION]
    first = {e.client: e.time for e in reversed(completions)}
    slowest = max(first, key=first.get)
    print(f"  straggler: {slowest} (first completion at t={first[slowest]:.2f}s)")


def main() -> None:
    run("sync")
    run("fedbuff")


if __name__ == "__main__":
    main()
