"""Quickstart: 3-client federated training with message quantization and

container streaming, end to end through the real stack — Controller,
Executors, a quantize+zlib+crc32 wire pipeline, SFM chunked wire — in
~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import dirichlet_partition
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

ROUNDS, LOCAL_STEPS, BATCH, SEQ = 5, 4, 8, 64


def main() -> None:
    cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=256, d_model=128, d_ff=256)
    model = create_model(cfg)
    datasets = dirichlet_partition(cfg.vocab_size, SEQ, num_clients=3, alpha=0.5)

    @jax.jit
    def local_step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(3e-3))
        return params, opt, loss

    def make_client(name, data):
        def train_fn(flat_params, rnd):
            params = unflatten_state_dict(
                {k: jnp.asarray(np.asarray(v)) for k, v in flat_params.items()})
            opt = adamw_init(params)
            loss = None
            for _ in range(LOCAL_STEPS):
                batch = {k: jnp.asarray(v) for k, v in data.sample(BATCH).items()}
                params, opt, loss = local_step(params, opt, batch)
            print(f"    {name}: round {rnd} local loss {float(loss):.4f}")
            return flatten_state_dict(params), BATCH * LOCAL_STEPS, {"loss": float(loss)}

        return TrainExecutor(name, train_fn)

    # the paper's §II-C two-way scheme as a wire-pipeline stack: quantize
    # + compress + checksum run per item inside the container streamer
    stack = ["quantize:blockwise8", "zlib", "crc32"]
    sim = FLSimulator(
        [make_client(f"site-{i}", ds) for i, ds in enumerate(datasets)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        pipelines={"task_data": stack, "task_result": stack},
    )
    init = flatten_state_dict(model.init(jax.random.PRNGKey(0)))
    final = sim.run(init)
    print(f"\nrounds: {ROUNDS} | messages: {sim.stats.messages} "
          f"| wire bytes: {sim.stats.bytes_sent/1e6:.1f} MB (int8+zlib wire)")
    print(f"final global weights: {len(final)} tensors")


if __name__ == "__main__":
    main()
