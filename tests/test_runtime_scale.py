"""Scale regression for the event runtime (ROADMAP item): 200 clients /
thousands of events, with the settle-wave barrier relaxed to a
launch-order prefix.

The profile that motivated the relaxation: with a heterogeneous fleet,
the old ``_settle`` blocked on *every* in-flight future before
processing the next event, so the scheduler sat idle behind one
wall-clock straggler even when that trip's earliest possible event lay
far past the queue head. The prefix settle lets queued completions
process (and their follow-up dispatches launch) while stragglers keep
running — ``RuntimeStats.partial_settles`` counts how often the early
stop engaged, which these tests pin as a regression guard.
"""
import numpy as np
import pytest

from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import EventKind, FedBuffPolicy, RuntimeConfig, heterogeneous_network

N_CLIENTS = 200
TOTAL_TASKS = 800


def _identity_exec(name):
    return TrainExecutor(
        name, lambda params, rnd: ({k: np.asarray(v) for k, v in params.items()}, 1, {})
    )


def _fleet(streaming=False, seed=0):
    names = [f"site-{i}" for i in range(N_CLIENTS)]
    sim = FLSimulator(
        [_identity_exec(n) for n in names],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=1, chunk_size=4096),
        pipelines={"task_data": [], "task_result": []},
        runtime=RuntimeConfig(seed=seed, max_concurrency=32),
        policy=FedBuffPolicy(total_tasks=TOTAL_TASKS, buffer_size=16),
        network=heterogeneous_network(names, seed=seed, compute_spread=8.0),
        server_streaming_agg=streaming,
    )
    out = sim.run({"w": np.arange(64, dtype=np.float32)})
    return np.asarray(out["w"]), sim


@pytest.mark.slow
def test_scale_200_clients_thousands_of_events():
    w1, sim1 = _fleet()
    sched = sim1.scheduler
    assert sched.stats.completions == TOTAL_TASKS
    assert len(sched.timeline) > 2000  # dispatch/arrival/completion per trip
    times = [e.time for e in sched.timeline]
    assert times == sorted(times)
    # the settle-wave relaxation engages on a heterogeneous fleet: the
    # scheduler repeatedly stopped settling early instead of blocking on
    # the whole wave
    assert sched.stats.partial_settles > 0
    assert sched.stats.settled_futures == sched.stats.dispatches
    # deterministic at scale: identical seeds, identical weights+timeline
    w2, sim2 = _fleet()
    np.testing.assert_array_equal(w1, w2)
    tl1 = [(e.kind, e.client, e.time) for e in sim1.scheduler.timeline]
    tl2 = [(e.kind, e.client, e.time) for e in sim2.scheduler.timeline]
    assert tl1 == tl2


@pytest.mark.slow
def test_scale_200_clients_streaming_agg_bitwise():
    """Streaming aggregation holds its bitwise-equality and O(item)
    claims at fleet scale: 800 FedBuff folds, one live fold stream at a
    time, same bits as the batch path."""
    w_batch, _ = _fleet(streaming=False)
    w_stream, sim = _fleet(streaming=True)
    np.testing.assert_array_equal(w_batch, w_stream)
    assert sim.scheduler.stats.completions == TOTAL_TASKS
    kinds = {e.kind for e in sim.scheduler.timeline}
    assert {EventKind.DISPATCH, EventKind.ARRIVAL, EventKind.COMPLETION} <= kinds
