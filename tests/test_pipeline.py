"""Wire pipeline: golden-bytes framing, per-stage round trips, ordered
stacks, legacy FilterChain-shim equivalence, the O(largest item) peak
transmission-memory envelope with quantization enabled (the composition
the pipeline redesign exists for), and chunk-level fault injection
feeding retransmitted bytes back into simulated transfer time.
"""
import json
import struct

import numpy as np
import pytest

from repro.core import pipeline as pl
from repro.core import serialization as ser
from repro.core.filters import no_filters, two_way_quantization
from repro.core.messages import Message, MessageKind
from repro.core.quantization import QuantizedTensor, quantize
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.runtime import LinkProfile, NetworkModel, RuntimeConfig


def _msg(payload, **headers):
    return Message(MessageKind.TASK_RESULT, dict(payload), dict(headers))


def _roundtrip(pipeline, message):
    """Encode a message through the pipeline and decode it back,
    item-for-item, the way the simulator wire does."""
    msg, ctx = pipeline.begin_encode(message)
    dec = pipeline.decoder()
    for _name, blob in pipeline.iter_encode(msg, ctx):
        name, value, consumed = dec.decode_item(blob)
        assert consumed == len(blob)
        dec.on_item(name, value)
    return dec.finish(msg.kind, pipeline.unsent_headers(msg))


def _sd(seed=0, items=4, shape=(64, 32)):
    rng = np.random.default_rng(seed)
    return {f"layer.{i}.w": rng.standard_normal(shape).astype(np.float32)
            for i in range(items)}


# ---------------------------------------------------------------------------
# golden bytes / framing
# ---------------------------------------------------------------------------

def test_empty_pipeline_is_byte_compatible_with_plain_serialization():
    """A stage-less pipeline frames items exactly like the inner codec —
    the pre-pipeline wire format, byte for byte."""
    p = pl.build_pipeline([])
    m = _msg(_sd(items=2))
    msg, ctx = p.begin_encode(m)
    envs = {name: blob for name, blob in p.iter_encode(msg, ctx) if name != pl.META_ITEM}
    for name, value in m.payload.items():
        assert envs[name] == ser.serialize_item(name, value)


def test_plain_item_golden_bytes():
    """The inner item framing is locked: u32 header length, sorted-key
    JSON header, raw C-order array bytes."""
    arr = np.arange(4, dtype=np.float32)
    header = b'{"dtype": "float32", "kind": "array", "name": "w", "shape": [4]}'
    golden = struct.pack("<I", len(header)) + header + arr.tobytes()
    assert ser.serialize_item("w", arr) == golden


def test_wire_envelope_carries_stage_metadata():
    """Envelope header records the stage stack (names + per-stage meta),
    is valid sorted-key JSON, and encoding is deterministic."""
    p = pl.build_pipeline(["quantize:nf4", "zlib", "crc32"])
    m = _msg({"w": np.linspace(-1, 1, 256).astype(np.float32)})
    msg, ctx = p.begin_encode(m)
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    blob2 = p.encode_wire_item("w", msg.payload["w"], ctx)
    assert blob == blob2  # deterministic bytes
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = json.loads(blob[4:4 + hlen])
    assert header["kind"] == "wire" and header["name"] == "w"
    assert header["v"] == ["quantize"]
    assert [b[0] for b in header["b"]] == ["zlib", "crc32"]
    assert "crc" in header["b"][1][1] and "n" in header["b"][0][1]
    assert header["n"] == len(blob) - 4 - hlen


def test_message_headers_cross_the_wire():
    out = _roundtrip(pl.build_pipeline(["crc32"]),
                     _msg({"w": np.ones(8, np.float32)}, round=3, client="site-1",
                          metrics={"loss": 0.125}))
    assert out.headers["round"] == 3
    assert out.headers["client"] == "site-1"
    assert out.headers["metrics"] == {"loss": 0.125}
    assert out.kind is MessageKind.TASK_RESULT


# ---------------------------------------------------------------------------
# per-stage round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt,tol", [("fp16", 1e-3), ("blockwise8", 0.03), ("nf4", 0.6)])
def test_quantize_stage_roundtrip(fmt, tol):
    m = _msg({"w": np.random.default_rng(0).standard_normal((65, 33)).astype(np.float32),
              "step": np.asarray(7, np.int32)})
    out = _roundtrip(pl.build_pipeline([f"quantize:{fmt}"]), m)
    np.testing.assert_allclose(np.asarray(out.payload["w"]), m.payload["w"], atol=tol)
    assert int(out.payload["step"]) == 7  # non-float passes through
    assert "quantized_fmt" not in out.headers  # popped after decode


def test_quantize_stage_keeps_wire_form_when_decode_values_off():
    p = pl.build_pipeline(["quantize:blockwise8"], decode_values=False)
    out = _roundtrip(p, _msg({"w": np.ones((64,), np.float32)}))
    assert isinstance(out.payload["w"], QuantizedTensor)
    assert out.headers["quantized_fmt"] == "blockwise8"  # header kept too


def test_zlib_stage_roundtrip_and_actually_compresses():
    m = _msg({"w": np.zeros((1 << 14,), np.float32)})
    p = pl.build_pipeline(["zlib"])
    msg, ctx = p.begin_encode(m)
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    assert len(blob) < m.payload["w"].nbytes / 50  # zeros compress hard
    out = _roundtrip(p, m)
    np.testing.assert_array_equal(np.asarray(out.payload["w"]), m.payload["w"])


def test_zlib_stage_rejects_length_mismatch():
    """Decompression is bounded by the envelope-declared original length:
    a stream that inflates past (or under) its declaration is rejected
    instead of expanding unbounded."""
    p = pl.build_pipeline(["zlib"])
    m = _msg({"w": np.zeros((4096,), np.float32)})
    msg, ctx = p.begin_encode(m)
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = json.loads(blob[4:4 + hlen])
    header["b"][0][1]["n"] //= 2  # lie about the original length
    hb = json.dumps(header, sort_keys=True).encode()
    # note: header["n"] (compressed body length) is unchanged
    tampered = struct.pack("<I", len(hb)) + hb + blob[4 + hlen:]
    with pytest.raises(pl.WireIntegrityError, match="declared"):
        p.decoder().decode_item(tampered)


def test_crc32_stage_rejects_corruption():
    p = pl.build_pipeline(["crc32"])
    m = _msg({"w": np.arange(64, dtype=np.float32)})
    msg, ctx = p.begin_encode(m)
    blob = bytearray(p.encode_wire_item("w", msg.payload["w"], ctx))
    blob[-1] ^= 0xFF  # flip one payload byte
    with pytest.raises(pl.WireIntegrityError, match="crc32 mismatch"):
        p.decoder().decode_item(bytes(blob))


def test_dp_noise_stage_adds_noise_once():
    m = _msg({"w": np.zeros((4096,), np.float32)})
    out = _roundtrip(pl.build_pipeline([{"stage": "dp-noise", "sigma": 0.1, "seed": 3}]), m)
    std = float(np.std(np.asarray(out.payload["w"])))
    assert 0.08 < std < 0.12  # noised on encode, identity on decode


def test_ef_quantize_stage_residual_shrinks_error():
    """Error feedback: repeated transmissions of the same tensor drive the
    *cumulative* quantization error toward zero (EF-SGD mechanism)."""
    x = np.random.default_rng(5).standard_normal((256,)).astype(np.float32)
    stage = pl.build_stage("ef-quantize:nf4")
    p = pl.WirePipeline([stage])
    recovered = []
    for _ in range(30):
        out = _roundtrip(p, _msg({"w": x.copy()}))
        recovered.append(np.asarray(out.payload["w"], np.float32))
    plain = _roundtrip(pl.build_pipeline(["quantize:nf4"]), _msg({"w": x.copy()}))
    err_plain = np.abs(np.asarray(plain.payload["w"]) - x).mean()
    err_ef = np.abs(np.mean(recovered, axis=0) - x).mean()
    assert err_ef < err_plain / 3  # residual carry-over averages out


def test_ef_quantize_residuals_are_per_client():
    """One ef-quantize stage serves a whole hop direction; the ``client``
    header keeps each site's error stream independent (client B must not
    inherit client A's residual)."""
    x = np.random.default_rng(7).standard_normal((256,)).astype(np.float32)
    shared = pl.WirePipeline([pl.build_stage("ef-quantize:nf4")])

    def one_client_sequence(pipeline, client):
        return [np.asarray(
            _roundtrip(pipeline, _msg({"w": x.copy()}, client=client)).payload["w"],
            np.float32,
        ) for _ in range(4)]

    seq_a = one_client_sequence(shared, "site-a")
    seq_b = one_client_sequence(shared, "site-b")
    # a dedicated stage for one client reproduces the shared stage's
    # stream exactly — interleaving another client changed nothing
    solo = one_client_sequence(pl.WirePipeline([pl.build_stage("ef-quantize:nf4")]), "site-b")
    for got, want in zip(seq_b, solo):
        np.testing.assert_array_equal(got, want)
    for got, want in zip(seq_a, solo):
        np.testing.assert_array_equal(got, want)


def test_receiver_without_sender_pipeline_decodes_from_envelope():
    """The self-describing envelope claim: a receiver holding only an
    empty pipeline resolves stage names through the registry — including
    stages whose constructors need encode-side args (quantize)."""
    sender = pl.build_pipeline(["quantize:blockwise8", "zlib", "crc32"])
    m = _msg({"w": np.random.default_rng(2).standard_normal((128,)).astype(np.float32)},
             round=1)
    msg, ctx = sender.begin_encode(m)
    receiver = pl.build_pipeline([]).decoder()
    for _name, blob in sender.iter_encode(msg, ctx):
        name, value, _ = receiver.decode_item(blob)
        receiver.on_item(name, value)
    out = receiver.finish(m.kind)
    np.testing.assert_allclose(np.asarray(out.payload["w"]), m.payload["w"], atol=0.03)


def test_legacy_quantize_filters_do_not_serialize_transfers():
    """Stateless legacy filters (the two-way quantization config) must
    not mark the shim pipeline stateful — that would collapse async
    wire concurrency to one transfer at a time."""
    pls = pl.legacy_wire_pipelines(two_way_quantization("nf4"),
                                   two_way_quantization("nf4"))
    assert not pls["task_data"].stateful
    assert not pls["task_result"].stateful
    from repro.core.filters import DPGaussianNoiseFilter, FilterChain, FilterPoint
    noisy = two_way_quantization("nf4")
    noisy[FilterPoint.TASK_RESULT_OUT] = FilterChain([DPGaussianNoiseFilter(0.1)])
    assert pl.legacy_wire_pipelines(noisy, noisy)["task_result"].stateful


def test_adaptive_stage_tracks_per_client_link():
    slow = LinkProfile("slow", bandwidth_mbps=1.0, latency_ms=10.0)
    fast = LinkProfile("fast", bandwidth_mbps=10000.0, latency_ms=1.0)
    net = NetworkModel(profiles={"site-slow": slow, "site-fast": fast})
    stage = pl.build_stage({"stage": "adaptive", "budget_s": 0.5})
    stage.bind_network(net)
    p = pl.WirePipeline([stage])
    payload = {"w": np.ones((1 << 16,), np.float32)}  # 256 KiB
    out_slow = _roundtrip(p, _msg(dict(payload), client="site-slow"))
    out_fast = _roundtrip(p, _msg(dict(payload), client="site-fast"))
    assert stage.last_fmt_by_client["site-slow"] in ("nf4", "blockwise8")
    assert stage.last_fmt_by_client["site-fast"] == "fp32"
    np.testing.assert_array_equal(np.asarray(out_fast.payload["w"]), payload["w"])
    assert np.abs(np.asarray(out_slow.payload["w"]) - payload["w"]).max() < 0.5


def test_secure_mask_stage_masks_telescope():
    from repro.core.secure_agg import SCALE, SecureAggregator

    clients = [0, 1, 2]
    xs = [np.random.default_rng(i).standard_normal((129,)).astype(np.float32)
          for i in clients]
    agg = SecureAggregator(num_clients=3)
    for i in clients:
        p = pl.WirePipeline([pl.SecureMaskStage(i, clients, base_seed=9)])
        out = _roundtrip(p, _msg({"w": xs[i]}, num_samples=1))
        assert out.payload["w"].dtype == np.uint32  # masked on the wire
        agg.accept(out)
    np.testing.assert_allclose(agg.finish()["w"], np.mean(xs, axis=0), atol=3.0 / SCALE)


# ---------------------------------------------------------------------------
# ordered stacks + registry
# ---------------------------------------------------------------------------

def test_stacked_quantize_zlib_crc_roundtrip_through_simulator():
    sd = _sd(items=6)

    def train_fn(params, rnd):
        return {k: np.asarray(v) for k, v in params.items()}, 1, {}

    stack = ["quantize:blockwise8", "zlib", "crc32"]
    sim = FLSimulator(
        [TrainExecutor("s0", train_fn)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=2, chunk_size=1024),
        pipelines={"task_data": stack, "task_result": stack},
    )
    final = sim.run(dict(sd))
    for k in sd:
        np.testing.assert_allclose(np.asarray(final[k]), sd[k], atol=0.03)
    assert sim.stats.bytes_sent > 0


def test_unknown_stage_name_raises():
    with pytest.raises(ValueError, match="unknown stage"):
        pl.build_pipeline(["carrier-pigeon"])


def test_third_party_stage_registers_and_runs():
    name = "test-negate"
    if name not in pl.registered_stages():
        @pl.register_stage(name)
        class _NegateStage(pl.Stage):
            def encode_item(self, n, v, ctx):
                return -np.asarray(v)

            def decode_item(self, n, v, ctx):
                return -np.asarray(v)

    out = _roundtrip(pl.build_pipeline([name]),
                     _msg({"w": np.arange(8, dtype=np.float32)}))
    np.testing.assert_array_equal(np.asarray(out.payload["w"]),
                                  np.arange(8, dtype=np.float32))
    with pytest.raises(ValueError, match="already registered"):
        pl.register_stage(name)(pl.Stage)


# ---------------------------------------------------------------------------
# legacy FilterChain shim equivalence
# ---------------------------------------------------------------------------

def _lsq_executor(name, seed, w_true, n=128, lr=0.3, local_steps=3):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, w_true.size)).astype(np.float32)
    y = X @ w_true

    def train_fn(params, rnd):
        w = np.asarray(params["w"]).copy()
        for _ in range(local_steps):
            w = w - lr * (X.T @ (X @ w - y) / n)
        return {"w": w}, n, {}

    return TrainExecutor(name, train_fn)


@pytest.mark.parametrize("transmission", ["regular", "container"])
def test_filterchain_shim_matches_pipeline_bitwise(transmission):
    """The deprecated Filter/FilterChain configuration, adapted through
    the shim, trains to bitwise-identical weights as the equivalent
    per-item pipeline — the API redesign changes where transforms run,
    not what they compute."""
    w_true = np.arange(1, 9, dtype=np.float32) / 8.0

    def run(wire_kwargs):
        sim = FLSimulator(
            [_lsq_executor(f"site-{i}", i, w_true) for i in range(3)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=6, transmission=transmission, chunk_size=2048),
            **wire_kwargs,
        )
        return sim.run({"w": np.zeros(8, np.float32)})

    filters = two_way_quantization("blockwise8")
    legacy = run({"server_filters": filters, "client_filters": filters})
    stack = ["quantize:blockwise8"]
    new = run({"pipelines": {"task_data": stack, "task_result": stack}})
    np.testing.assert_array_equal(np.asarray(legacy["w"]), np.asarray(new["w"]))


def test_legacy_filters_and_pipelines_are_mutually_exclusive():
    with pytest.raises(ValueError, match="not both"):
        FLSimulator(
            [_lsq_executor("s0", 0, np.ones(4, np.float32))],
            FedAvgAggregator(),
            SimulationConfig(),
            server_filters=no_filters(),
            pipelines={"task_data": []},
        )


# ---------------------------------------------------------------------------
# acceptance: peak transmission memory is O(largest item) with quantization
# ---------------------------------------------------------------------------

def test_container_quantized_peak_is_largest_item_not_whole_payload():
    """The tentpole claim: with container streaming and an nf4 quantize
    *stage*, peak transmission memory is bounded by ~one (quantized)
    item; the legacy filter path materializes the whole quantized
    payload before streaming and is metered accordingly."""
    sd = {f"layer.{i}": np.random.default_rng(i).standard_normal((128, 128))
          .astype(np.float32) for i in range(16)}  # 1 MiB total, 64 KiB items
    q_item = quantize(next(iter(sd.values())), "nf4").total_bytes
    q_total = sum(quantize(v, "nf4").total_bytes for v in sd.values())

    def train_fn(params, rnd):
        return {k: np.asarray(v) for k, v in params.items()}, 1, {}

    def run(wire_kwargs):
        sim = FLSimulator(
            [TrainExecutor("s0", train_fn)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=1, transmission="container", chunk_size=4096),
            **wire_kwargs,
        )
        sim.run(dict(sd))
        return sim.meter.peak

    stack = ["quantize:nf4"]
    peak_pipeline = run({"pipelines": {"task_data": stack, "task_result": stack}})
    filters = two_way_quantization("nf4")
    peak_legacy = run({"server_filters": filters, "client_filters": filters})

    # pipeline: ~one quantized item live on each side of the loopback
    assert peak_pipeline <= 4 * (q_item + 8192)
    assert peak_pipeline < q_total / 2
    # legacy shim: the whole quantized payload is materialized first
    assert peak_legacy >= q_total
    assert peak_pipeline < peak_legacy / 2


# ---------------------------------------------------------------------------
# honest wire accounting
# ---------------------------------------------------------------------------

def test_traffic_stats_count_true_bytes_on_wire():
    """bytes_sent includes frame headers, envelopes and the transmitted
    message-header item — strictly more than the tensor payload; with a
    compression stage on compressible data, strictly (and hugely) less.
    """
    sd = {"w": np.zeros((1 << 15,), np.float32)}  # 128 KiB of zeros

    def train_fn(params, rnd):
        return {k: np.asarray(v) for k, v in params.items()}, 1, {}

    def run(stack):
        sim = FLSimulator(
            [TrainExecutor("s0", train_fn)], FedAvgAggregator(),
            SimulationConfig(num_rounds=1, chunk_size=4096),
            pipelines={"task_data": stack, "task_result": stack},
        )
        sim.run(dict(sd))
        return sim.stats

    plain = run([])
    assert plain.bytes_sent > plain.payload_bytes > 0  # framing overhead counted
    zipped = run(["zlib"])
    assert zipped.payload_bytes == plain.payload_bytes
    assert zipped.bytes_sent < plain.payload_bytes / 20  # honest compression ratio


# ---------------------------------------------------------------------------
# chunk-level fault injection end-to-end (scheduler wire)
# ---------------------------------------------------------------------------

def test_chunk_faults_retransmit_and_lengthen_simulated_time():
    """LossyDriver + ReliableTransfer run inside the scheduler wire:
    payloads survive bit-exactly, retransmitted chunks are counted, and
    the extra bytes feed back into simulated transfer time."""
    w_true = np.arange(1, 5, dtype=np.float32)
    net = NetworkModel(default=LinkProfile("slow", bandwidth_mbps=4.0, latency_ms=5.0))

    def run(**cfg_kwargs):
        sim = FLSimulator(
            [_lsq_executor(f"site-{i}", i, w_true) for i in range(2)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=3, chunk_size=256, **cfg_kwargs),
            pipelines={"task_data": [], "task_result": ["crc32"]},
            runtime=RuntimeConfig(seed=0),
            network=net,
        )
        final = sim.run({"w": np.zeros(4, np.float32)})
        return final, sim

    clean, sim_clean = run()
    lossy, sim_lossy = run(chunk_drop_prob=0.25, chunk_dup_prob=0.05,
                           chunk_reorder_window=3, fault_seed=7)
    # exact reassembly: the lossy federation trains identically
    np.testing.assert_array_equal(np.asarray(clean["w"]), np.asarray(lossy["w"]))
    assert sim_lossy.stats.retransmits > 0
    assert sim_lossy.stats.bytes_sent > sim_clean.stats.bytes_sent
    # retransmitted bytes feed the network model -> longer simulated rounds
    assert sim_lossy.sim_time_s > sim_clean.sim_time_s


def test_chunk_faults_are_deterministic():
    w_true = np.arange(1, 5, dtype=np.float32)

    def run():
        sim = FLSimulator(
            [_lsq_executor("site-0", 0, w_true)], FedAvgAggregator(),
            SimulationConfig(num_rounds=2, chunk_size=128, chunk_drop_prob=0.3,
                             fault_seed=3),
            runtime=RuntimeConfig(seed=1),
        )
        final = sim.run({"w": np.zeros(4, np.float32)})
        return np.asarray(final["w"]), sim.stats.bytes_sent, sim.stats.retransmits

    w1, b1, r1 = run()
    w2, b2, r2 = run()
    np.testing.assert_array_equal(w1, w2)
    assert (b1, r1) == (b2, r2) and r1 > 0


def test_chunk_faults_rejected_over_tcp():
    with pytest.raises(ValueError, match="tcp"):
        FLSimulator(
            [_lsq_executor("s0", 0, np.ones(4, np.float32))],
            FedAvgAggregator(),
            SimulationConfig(driver="tcp", chunk_drop_prob=0.1),
        )


def test_unknown_driver_name_raises():
    with pytest.raises(ValueError, match="unknown driver"):
        FLSimulator(
            [_lsq_executor("s0", 0, np.ones(4, np.float32))],
            FedAvgAggregator(),
            SimulationConfig(driver="quic"),
        ).run({"w": np.zeros(4, np.float32)})


# ---------------------------------------------------------------------------
# delta / topk / zstd stages + quantize rules (ISSUE 4 satellites)
# ---------------------------------------------------------------------------

def test_delta_stage_transmits_residuals_and_reconstructs():
    """Round r ships x_r - base_{r-1}; the decoder reconstructs each x_r
    to one float32 rounding (the encoder tracks the decoder's
    reconstruction, so the error never accumulates across rounds), and
    the envelope meta tracks the stream position."""
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((64,)).astype(np.float32) for _ in range(8)]
    p = pl.WirePipeline([pl.build_stage("delta")])
    for i, x in enumerate(xs):
        msg, ctx = p.begin_encode(_msg({"w": x.copy()}, client="site-0"))
        blob = p.encode_wire_item("w", msg.payload["w"], ctx)
        (hlen,) = struct.unpack_from("<I", blob, 0)
        header = json.loads(blob[4:4 + hlen])
        assert header["v"] == ["delta"]
        assert header["vm"][0]["d"] == i          # stream position on the wire
        assert header["vm"][0].get("full", 0) == (1 if i == 0 else 0)
        name, value, _ = p.decoder().decode_item(blob)
        np.testing.assert_allclose(np.asarray(value), x, rtol=1e-6, atol=1e-6)


def test_delta_stage_near_converged_rounds_compress_away():
    """The point of delta encoding: once the model stops moving, the
    residual is all zeros and zlib collapses it."""
    x = np.linspace(-1, 1, 1 << 14).astype(np.float32)
    p = pl.WirePipeline([pl.build_stage("delta"), pl.build_stage("zlib")])

    def wire_len(arr):
        msg, ctx = p.begin_encode(_msg({"w": arr.copy()}, client="c"))
        blob = p.encode_wire_item("w", msg.payload["w"], ctx)
        name, value, _ = p.decoder().decode_item(blob)
        np.testing.assert_array_equal(np.asarray(value), arr)
        return len(blob)

    first = wire_len(x)
    repeat = wire_len(x)  # unchanged payload => zero residual
    assert repeat < first / 100


def test_delta_stage_residual_streams_are_per_client():
    x = np.ones((32,), np.float32)
    p = pl.WirePipeline([pl.build_stage("delta")])

    def roundtrip(client):
        msg, ctx = p.begin_encode(_msg({"w": x.copy()}, client=client))
        blob = p.encode_wire_item("w", msg.payload["w"], ctx)
        (hlen,) = struct.unpack_from("<I", blob, 0)
        return json.loads(blob[4:4 + hlen])["vm"][0]

    assert roundtrip("site-a") == {"d": 0, "full": 1}
    assert roundtrip("site-b") == {"d": 0, "full": 1}  # b starts fresh
    assert roundtrip("site-a")["d"] == 1


def test_delta_stage_desynchronized_receiver_fails_loudly():
    x = np.ones((16,), np.float32)
    sender = pl.WirePipeline([pl.build_stage("delta")])
    for _ in range(2):
        msg, ctx = sender.begin_encode(_msg({"w": x.copy()}, client="c"))
        blob = sender.encode_wire_item("w", msg.payload["w"], ctx)
    # a fresh receiver (registry fallback) is at position 0, wire says 1
    with pytest.raises(pl.WireIntegrityError, match="out of sync"):
        pl.build_pipeline([]).decoder().decode_item(blob)


def test_topk_stage_roundtrip_and_sparse_golden_serialization():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((1 << 12,)).astype(np.float32)
    p = pl.build_pipeline(["topk:0.05"])
    msg, ctx = p.begin_encode(_msg({"w": x.copy()}))
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = json.loads(blob[4:4 + hlen])
    k = int(np.ceil(0.05 * x.size))
    assert header["vm"][0] == {"k": k, "n": x.size}
    assert len(blob) < x.nbytes / 2  # indices+values beat dense
    name, value, _ = p.decoder().decode_item(blob)
    dense = np.asarray(value)
    kept = np.flatnonzero(dense)
    assert len(kept) == k
    np.testing.assert_array_equal(dense[kept], x[kept])  # survivors exact
    # the k largest |x| all survived
    assert np.min(np.abs(x[kept])) >= np.max(np.abs(np.delete(x, kept)))


def test_topk_sparse_tensor_inner_codec_roundtrip():
    from repro.core.serialization import deserialize_item, serialize_item
    from repro.core.sparse import topk_sparsify

    x = np.arange(-8, 8, dtype=np.float32).reshape(4, 4)
    sp = topk_sparsify(x, 0.25)
    name, back, consumed = deserialize_item(serialize_item("w", sp))
    assert name == "w" and consumed == len(serialize_item("w", sp))
    np.testing.assert_array_equal(back.to_dense(), sp.to_dense())
    assert back.orig_shape == (4, 4)


def test_topk_small_tensors_pass_dense():
    p = pl.build_pipeline([{"stage": "topk", "fraction": 0.1, "min_params": 64}])
    out = _roundtrip(p, _msg({"bias": np.arange(8, dtype=np.float32)}))
    np.testing.assert_array_equal(np.asarray(out.payload["bias"]),
                                  np.arange(8, dtype=np.float32))


def test_zstd_stage_registered_only_when_importable():
    try:
        import zstandard  # noqa: F401
        available = True
    except ImportError:
        available = False
    assert ("zstd" in pl.registered_stages()) == available


def test_zstd_stage_roundtrip_when_available():
    pytest.importorskip("zstandard")
    m = _msg({"w": np.zeros((1 << 14,), np.float32)})
    p = pl.build_pipeline(["zstd:5"])
    msg, ctx = p.begin_encode(m)
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    assert len(blob) < m.payload["w"].nbytes / 50
    out = _roundtrip(p, m)
    np.testing.assert_array_equal(np.asarray(out.payload["w"]), m.payload["w"])


def test_quantize_rules_per_layer_precision():
    """The SelectiveQuantizeFilter policy as a stage: first matching
    substring rule decides each tensor's format, default covers the
    rest, "keep" pins original precision."""
    rng = np.random.default_rng(5)
    payload = {
        "embed.w": rng.standard_normal((64,)).astype(np.float32),
        "layers.0.norm": rng.standard_normal((64,)).astype(np.float32),
        "layers.0.mlp": rng.standard_normal((64,)).astype(np.float32),
    }
    p = pl.build_pipeline(["quantize:norm=fp16,embed=keep,nf4"])
    msg, ctx = p.begin_encode(_msg(dict(payload)))
    assert msg.headers["quantized_fmt"] == "mixed:fp16,nf4"
    fmts = {}
    for name, value in msg.payload.items():
        enc = p.stages[0].encode_item(name, value, ctx)
        fmts[name] = enc.fmt if isinstance(enc, QuantizedTensor) else "keep"
    assert fmts == {"embed.w": "keep", "layers.0.norm": "fp16",
                    "layers.0.mlp": "nf4"}
    out = _roundtrip(p, _msg(dict(payload)))
    np.testing.assert_array_equal(np.asarray(out.payload["embed.w"]),
                                  payload["embed.w"])  # kept bit-exact
    np.testing.assert_allclose(np.asarray(out.payload["layers.0.norm"]),
                               payload["layers.0.norm"], atol=1e-3)
    np.testing.assert_allclose(np.asarray(out.payload["layers.0.mlp"]),
                               payload["layers.0.mlp"], atol=0.6)


def test_quantize_rules_dict_spec_matches_selective_filter():
    from repro.core.filters import SelectiveQuantizeFilter

    rng = np.random.default_rng(6)
    payload = {"a.norm": rng.standard_normal((128,)).astype(np.float32),
               "b.body": rng.standard_normal((128,)).astype(np.float32)}
    stage_out = _roundtrip(
        pl.build_pipeline([{"stage": "quantize",
                            "rules": [["norm", "fp16"]], "fmt": "blockwise8"}]),
        _msg(dict(payload)))
    filt = SelectiveQuantizeFilter([("norm", "fp16")], default_fmt="blockwise8")
    from repro.core.filters import DequantizeFilter
    filter_out = DequantizeFilter().process(filt.process(_msg(dict(payload))))
    for k in payload:
        np.testing.assert_array_equal(np.asarray(stage_out.payload[k]),
                                      np.asarray(filter_out.payload[k]))


def test_quantize_stage_requires_fmt_or_rules():
    with pytest.raises(ValueError, match="format and/or rules"):
        pl.build_pipeline([{"stage": "quantize"}])


def test_zstd_stage_oversize_stream_raises_wire_integrity_error():
    pytest.importorskip("zstandard")
    p = pl.build_pipeline(["zstd"])
    m = _msg({"w": np.zeros((4096,), np.float32)})
    msg, ctx = p.begin_encode(m)
    blob = p.encode_wire_item("w", msg.payload["w"], ctx)
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = json.loads(blob[4:4 + hlen])
    header["b"][0][1]["n"] //= 2  # declare half the true original length
    hb = json.dumps(header, sort_keys=True).encode()
    tampered = struct.pack("<I", len(hb)) + hb + blob[4 + hlen:]
    with pytest.raises(pl.WireIntegrityError, match="declared length"):
        p.decoder().decode_item(tampered)


def test_delta_stage_residual_without_base_raises_wire_error():
    """A forged/corrupted envelope claiming position 0 but no 'full'
    snapshot must surface as a wire-integrity fault, not a KeyError."""
    x = np.ones((16,), np.float32)
    sender = pl.WirePipeline([pl.build_stage("delta")])
    msg, ctx = sender.begin_encode(_msg({"w": x}, client="c"))
    blob = sender.encode_wire_item("w", msg.payload["w"], ctx)
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = json.loads(blob[4:4 + hlen])
    del header["vm"][0]["full"]  # lie: claim this is a residual
    hb = json.dumps(header, sort_keys=True).encode()
    tampered = struct.pack("<I", len(hb)) + hb + blob[4 + hlen:]
    with pytest.raises(pl.WireIntegrityError, match="no base"):
        pl.build_pipeline([]).decoder().decode_item(tampered)


def test_quantize_rules_reject_two_bare_defaults():
    with pytest.raises(ValueError, match="two default"):
        pl.build_pipeline(["quantize:norm=fp16,nf4,int8"])
