"""Streaming-first aggregation plane: the begin/accept_item/finish
protocol, streaming-vs-batch bitwise equality across both runtimes and
all four scheduling policies, and the MemoryMeter bound — server peak
transmission+aggregation memory stays ~one item (not one model) even
with 32 concurrent streaming senders.
"""
import threading

import numpy as np
import pytest

from repro.core import pipeline as pl
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.fl import (
    FedAvgAggregator,
    FLSimulator,
    QuantizedFedAvgAggregator,
    ScatterAndGather,
    SimulationConfig,
    TrainExecutor,
    build_aggregator,
    register_aggregator,
    registered_aggregators,
)
from repro.fl.job import run_job
from repro.runtime import (
    ComputeProfile,
    FedAsyncPolicy,
    FedBuffPolicy,
    LinkProfile,
    NetworkModel,
    RuntimeConfig,
    TieredPolicy,
    heterogeneous_network,
)
from repro.utils.mem import MemoryMeter


def _msg(payload, **headers):
    return Message(MessageKind.TASK_RESULT, dict(payload), dict(headers))


# ---------------------------------------------------------------------------
# aggregator protocol + registry
# ---------------------------------------------------------------------------

def test_protocol_and_batch_shim_are_the_same_arithmetic():
    """accept() is a shim over begin/accept_item, so feeding items through
    either surface produces bitwise-identical aggregates."""
    rng = np.random.default_rng(0)
    payloads = [{f"l{j}": rng.standard_normal((33,)).astype(np.float32)
                 for j in range(3)} for _ in range(4)]
    batch, stream = FedAvgAggregator(), FedAvgAggregator()
    for i, p in enumerate(payloads):
        batch.accept(_msg(p, num_samples=i + 1))
        w = stream.begin({"num_samples": i + 1})
        for name, value in p.items():
            stream.accept_item(name, value, w)
    a, b = batch.finish(), stream.finish()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_fedavg_begin_returns_sample_weight():
    agg = FedAvgAggregator()
    assert agg.begin({"num_samples": 7}) == 7.0
    assert agg.begin({}) == 1.0  # default weight
    assert agg.accepted == 2


def test_aggregator_registry_builds_and_rejects():
    assert {"fedavg", "quantized-fedavg"} <= set(registered_aggregators())
    assert isinstance(build_aggregator("fedavg"), FedAvgAggregator)
    assert isinstance(build_aggregator({"aggregator": "quantized-fedavg"}),
                      QuantizedFedAvgAggregator)
    with pytest.raises(ValueError, match="unknown aggregator"):
        build_aggregator("median")
    name = "test-sum"
    if name not in registered_aggregators():
        @register_aggregator(name)
        class _SumAgg(FedAvgAggregator):
            pass
    assert isinstance(build_aggregator(name), FedAvgAggregator)
    with pytest.raises(ValueError, match="already registered"):
        register_aggregator(name)(FedAvgAggregator)


def test_streaming_controller_requires_protocol_aggregator():
    class LegacyAgg:
        def accept(self, result):
            pass

        def finish(self):
            return {}

    with pytest.raises(TypeError, match="begin/accept_item"):
        ScatterAndGather([TrainExecutor("s0", lambda p, r: (p, 1, {}))],
                         LegacyAgg(), 1, streaming=True)


# ---------------------------------------------------------------------------
# wire plane: concurrent senders, O(item) server peak
# ---------------------------------------------------------------------------

def _stream_into(sink, payload, client, chunk_size=8192, stages=()):
    """One uplink transfer through pipeline + container streaming into a
    streaming-aggregation sink — the full server receive plane."""
    p = pl.build_pipeline(list(stages))
    msg = _msg(payload, num_samples=1, client=client)
    enc, ctx = p.begin_encode(msg)
    dec = p.decoder(sink=sink)
    recv = sm.ContainerReceiver(consume=dec.on_item, decode_item=dec.decode_item)
    driver = sm.LoopbackDriver()
    driver.connect(recv.on_chunk)
    sm.ContainerStreamer(driver, chunk_size).send_items(
        p.iter_encode(enc, ctx), p.n_items(enc)
    )
    return dec.finish(msg.kind, p.unsent_headers(enc))


def test_server_peak_is_items_not_models_with_32_concurrent_senders():
    """The acceptance bound: 32 clients streaming a 256-item model into
    one shared aggregator concurrently keep the metered server peak at a
    few items *per sender* — far below one model per sender, and below
    even a single model. Integer-valued tensors make the shared running
    sum exact, so the fold result is independent of thread interleaving.
    """
    items, item_elems = 256, 4096  # 256 x 16 KiB = 4 MiB model
    rng = np.random.default_rng(0)
    sd = {f"layer.{i}": rng.integers(-8, 8, item_elems).astype(np.float32)
          for i in range(items)}
    model_bytes = sum(v.nbytes for v in sd.values())
    item_bytes = item_elems * 4
    senders = 32

    agg = FedAvgAggregator()
    meter = MemoryMeter()
    errors = []

    def send(i):
        try:
            _stream_into(agg, sd, f"site-{i}")
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    with meter.activate():
        threads = [threading.Thread(target=send, args=(i,)) for i in range(senders)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    final = agg.finish()
    for k in sd:  # integer-valued sums are exact in fp32 at this scale
        np.testing.assert_array_equal(final[k], sd[k])
    # each sender holds ~one item (encoded envelope + chunk buffers +
    # the decoded value during its fold) — never its whole payload
    assert meter.peak <= senders * 6 * item_bytes
    assert meter.peak < model_bytes / 2


def test_streaming_beats_batch_collection_peak():
    """Same wire, same pipeline: collecting decoded payload dicts (the
    batch plane) holds one model per sender; the streaming plane holds
    one item. The measured gap is the tentpole's point.

    The batch senders rendezvous on a barrier *while their decoded
    models are resident*, so the batch peak is exactly ``senders``
    models regardless of how the scheduler interleaves the threads —
    without the barrier, a loaded machine can serialize the senders and
    the measured peak races the scheduler (this test used to flake
    under full-suite load)."""
    items, item_elems, senders = 64, 4096, 8
    item_bytes = item_elems * 4
    rng = np.random.default_rng(1)
    sd = {f"layer.{i}": rng.standard_normal(item_elems).astype(np.float32)
          for i in range(items)}
    model_bytes = sum(v.nbytes for v in sd.values())
    stages = ("quantize:blockwise8", "zlib")
    all_resident = threading.Barrier(senders)

    def run(streaming):
        agg = FedAvgAggregator()
        meter = MemoryMeter()
        errors = []

        def send(i):
            try:
                if streaming:
                    _stream_into(agg, sd, f"site-{i}", stages=stages)
                else:
                    from repro.fl import CollectingSink
                    from repro.utils import mem

                    sink = CollectingSink()
                    out = _stream_into(sink, sd, f"site-{i}", stages=stages)
                    # the batch plane's decoded payload dict is resident
                    # until the whole-message accept finishes
                    held = sum(v.nbytes for v in sink.payload.values())
                    mem.record_alloc(held)
                    # every sender's model provably resident at once
                    all_resident.wait(timeout=60)
                    agg.accept(Message(out.kind, sink.payload, out.headers))
                    mem.record_free(held)
            except Exception as exc:  # noqa: BLE001 - surfaced below
                all_resident.abort()
                errors.append(exc)

        with meter.activate():
            threads = [threading.Thread(target=send, args=(i,))
                       for i in range(senders)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert not errors
        agg.finish()
        return meter.peak

    peak_stream = run(True)
    peak_batch = run(False)
    assert peak_batch >= senders * model_bytes  # all models resident
    # per-sender streaming envelope: ~one item in flight (encoded
    # envelope + chunk buffers + the decoded value during the fold) —
    # the same documented bound the 32-sender acceptance test uses
    assert peak_stream <= senders * 6 * item_bytes
    assert peak_stream < peak_batch / 8
    assert peak_stream < model_bytes


# ---------------------------------------------------------------------------
# sequential controller: streaming == batch, bitwise, always
# ---------------------------------------------------------------------------

def _lsq_executor(name, seed, w_true, n=128, lr=0.3, local_steps=3):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, w_true.size)).astype(np.float32)
    y = X @ w_true

    def train_fn(params, rnd):
        w = np.asarray(params["w"]).copy()
        for _ in range(local_steps):
            w = w - lr * (X.T @ (X @ w - y) / n)
        return {"w": w}, n, {}

    return TrainExecutor(name, train_fn)


W_TRUE = np.arange(1, 9, dtype=np.float32) / 8.0


def _sequential(streaming, transmission="container", stack=("quantize:blockwise8", "zlib"),
                **cfg):
    sim = FLSimulator(
        [_lsq_executor(f"site-{i}", i, W_TRUE) for i in range(3)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=4, transmission=transmission, chunk_size=2048, **cfg),
        pipelines={"task_data": list(stack), "task_result": list(stack)},
        server_streaming_agg=streaming,
    )
    out = sim.run({"w": np.zeros(8, np.float32)})
    return np.asarray(out["w"]), sim


@pytest.mark.parametrize("transmission", ["container", "regular"])
def test_sequential_streaming_bitwise_matches_batch(transmission):
    """Clients run one at a time in list order on the sequential
    controller, so the streaming fold executes the exact arithmetic of
    the batch path in the exact order — bitwise-equal final weights,
    identical wire traffic."""
    batch, sim_b = _sequential(False, transmission)
    stream, sim_s = _sequential(True, transmission)
    np.testing.assert_array_equal(batch, stream)
    assert sim_b.stats.bytes_sent == sim_s.stats.bytes_sent
    assert sim_b.stats.messages == sim_s.stats.messages


def test_sequential_streaming_bitwise_under_chunk_faults():
    """OrderedDeliveryBuffer gives the fold exactly-once in-order item
    delivery even when the wire drops/duplicates/reorders chunks, so
    streaming aggregation stays bitwise-equal to batch on a lossy link."""
    batch, _ = _sequential(False, chunk_drop_prob=0.2, chunk_dup_prob=0.05,
                           chunk_reorder_window=3, fault_seed=11)
    stream, sim = _sequential(True, chunk_drop_prob=0.2, chunk_dup_prob=0.05,
                              chunk_reorder_window=3, fault_seed=11)
    np.testing.assert_array_equal(batch, stream)
    assert sim.stats.retransmits > 0


def test_sequential_streaming_results_are_header_only():
    captured = []
    sim = FLSimulator(
        [_lsq_executor(f"site-{i}", i, W_TRUE) for i in range(2)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=1),
        on_round_end=lambda rnd, w, results: captured.extend(results),
        server_streaming_agg=True,
    )
    sim.run({"w": np.zeros(8, np.float32)})
    for r in captured:
        assert r.payload == {}  # the server never held the payload dict
        assert r.headers["num_samples"] == 128
        assert r.headers["client"].startswith("site-")


def test_sequential_streaming_quantized_aggregation():
    """decode_values=False + QuantizedFedAvgAggregator: wire-form int8
    items stream straight into the fused-kernel aggregator."""

    def run(streaming):
        sim = FLSimulator(
            [_lsq_executor(f"site-{i}", i, W_TRUE) for i in range(3)],
            QuantizedFedAvgAggregator(),
            SimulationConfig(num_rounds=3, chunk_size=2048),
            pipelines={
                "task_data": ["quantize:blockwise8"],
                "task_result": pl.build_pipeline(["quantize:blockwise8"],
                                                 decode_values=False),
            },
            server_streaming_agg=streaming,
        )
        return np.asarray(sim.run({"w": np.zeros(8, np.float32)})["w"])

    np.testing.assert_array_equal(run(False), run(True))


# ---------------------------------------------------------------------------
# async scheduler: streaming == batch for all four policies
# ---------------------------------------------------------------------------

def _uniform_net():
    return NetworkModel(
        default=LinkProfile("lan", bandwidth_mbps=100.0, latency_ms=1.0, jitter=0.0),
        default_compute=ComputeProfile(base_seconds=0.01, jitter=0.0),
        seed=0,
    )


def _async(streaming, num_clients=4, rounds=3, stack=("quantize:blockwise8",),
           policy=None, network=None, **runtime_kwargs):
    sim = FLSimulator(
        [_lsq_executor(f"site-{i}", i, W_TRUE) for i in range(num_clients)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=rounds, chunk_size=2048),
        pipelines={"task_data": list(stack), "task_result": list(stack)},
        runtime=RuntimeConfig(seed=0, max_concurrency=num_clients, **runtime_kwargs),
        policy=policy,
        network=network,
        server_streaming_agg=streaming,
    )
    out = sim.run({"w": np.zeros(8, np.float32)})
    return np.asarray(out["w"]), sim


def test_async_sync_policy_streaming_bitwise_on_uniform_links():
    """SyncPolicy's streaming barrier folds at each completion instant;
    on uniform jitter-free links with equal wire sizes completion order
    is client-list order, so streaming, batch, and the sequential
    controller all produce the same bits. The timeline and wire traffic
    match batch exactly on any network (the pricing pass feeds the clock
    the same bytes)."""
    batch, sim_b = _async(False, network=_uniform_net())
    stream, sim_s = _async(True, network=_uniform_net())
    np.testing.assert_array_equal(batch, stream)
    assert sim_b.sim_time_s == sim_s.sim_time_s
    assert sim_b.stats.bytes_sent == sim_s.stats.bytes_sent
    sequential, _ = _sequential(True, stack=("quantize:blockwise8",), )
    # same federation trained sequentially with streaming aggregation
    sim = FLSimulator(
        [_lsq_executor(f"site-{i}", i, W_TRUE) for i in range(4)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=3, chunk_size=2048),
        pipelines={"task_data": ["quantize:blockwise8"],
                   "task_result": ["quantize:blockwise8"]},
    )
    seq = np.asarray(sim.run({"w": np.zeros(8, np.float32)})["w"])
    np.testing.assert_array_equal(seq, stream)


def test_async_tiered_policy_streaming_bitwise_on_uniform_links():
    def run(streaming):
        return _async(
            streaming, num_clients=6, rounds=4,
            policy=TieredPolicy(FedAvgAggregator(), 4, num_tiers=2, seed=3),
            network=_uniform_net(),
        )

    batch, _ = run(False)
    stream, sim = run(True)
    np.testing.assert_array_equal(batch, stream)
    assert sim.scheduler.policy.selected_tiers  # tiers actually drawn


def test_async_fedbuff_streaming_bitwise_on_heterogeneous_links():
    """FedBuff folds at the completion instant with completion-time
    staleness in both modes, so streaming == batch bitwise even when a
    heterogeneous network scrambles completion order and zlib makes every
    client's wire size different."""
    names = [f"site-{i}" for i in range(4)]

    def run(streaming):
        return _async(
            streaming, stack=("quantize:blockwise8", "zlib"),
            policy=FedBuffPolicy(total_tasks=16, buffer_size=2),
            network=heterogeneous_network(names, seed=1),
        )

    batch, sim_b = run(False)
    stream, sim_s = run(True)
    np.testing.assert_array_equal(batch, stream)
    assert sim_b.sim_time_s == sim_s.sim_time_s
    assert sim_s.scheduler.policy.staleness_seen == sim_b.scheduler.policy.staleness_seen


def test_async_fedasync_streaming_bitwise_on_heterogeneous_links():
    names = [f"site-{i}" for i in range(4)]

    def run(streaming):
        return _async(
            streaming, stack=("quantize:blockwise8", "zlib"),
            policy=FedAsyncPolicy(total_tasks=16),
            network=heterogeneous_network(names, seed=2),
        )

    batch, _ = run(False)
    stream, sim = run(True)
    np.testing.assert_array_equal(batch, stream)
    assert sim.scheduler.stats.model_updates == 16  # one mix per update


def test_async_streaming_with_dropouts_deterministic_and_close_to_batch():
    """Dropout draws are consumed in launch order in both modes, so the
    timelines agree event for event; the sync fold order differs
    (completion vs barrier order) so weights agree numerically, not
    bitwise."""
    def run(streaming):
        return _async(streaming, rounds=2, network=_uniform_net(),
                      dropout_prob=0.3, max_retries=1)

    batch, sim_b = run(False)
    stream1, sim_s1 = run(True)
    stream2, sim_s2 = run(True)
    np.testing.assert_array_equal(stream1, stream2)  # run-to-run determinism
    np.testing.assert_allclose(batch, stream1, rtol=1e-5, atol=1e-6)
    tl_b = [(e.kind, e.client, e.time) for e in sim_b.scheduler.timeline]
    tl_s = [(e.kind, e.client, e.time) for e in sim_s1.scheduler.timeline]
    assert tl_b == tl_s
    assert sim_b.stats.bytes_sent == sim_s1.stats.bytes_sent


def test_async_streaming_rejects_stateful_uplink_pipeline():
    with pytest.raises(ValueError, match="stateless"):
        FLSimulator(
            [_lsq_executor("s0", 0, W_TRUE)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=1),
            pipelines={"task_data": [], "task_result": ["ef-quantize:nf4"]},
            runtime=RuntimeConfig(seed=0),
            server_streaming_agg=True,
        )


def test_sequential_streaming_allows_stateful_uplink_pipeline():
    """The sequential controller folds during the single uplink pass, so
    stateful stages (error feedback) compose with streaming aggregation."""
    stream, _ = _sequential(True, stack=("ef-quantize:blockwise8",))
    assert np.all(np.isfinite(stream))


# ---------------------------------------------------------------------------
# job-spec surface
# ---------------------------------------------------------------------------

def _job_spec(**over):
    spec = {
        "arch": "qwen1.5-0.5b", "smoke": True,
        "rounds": 2, "local_steps": 1, "batch": 2, "seq": 16,
        "clients": 2, "pipeline": {"task_result_out": ["quantize:blockwise8"]},
    }
    spec.update(over)
    return spec


def test_job_spec_server_streaming_agg_bitwise():
    batch = run_job(_job_spec())
    stream = run_job(_job_spec(server_streaming_agg=True))
    for k in batch["final_weights"]:
        np.testing.assert_array_equal(
            np.asarray(batch["final_weights"][k]),
            np.asarray(stream["final_weights"][k]),
        )
    assert batch["wire_bytes"] == stream["wire_bytes"]


def test_job_spec_streaming_with_fedasync_runtime():
    res = run_job(_job_spec(
        server_streaming_agg=True,
        runtime={"policy": "fedasync", "total_tasks": 4,
                 "network": {"default": "wifi"}},
    ))
    assert res["policy"] == "fedasync"
    assert res["runtime_stats"]["completions"] == 4


def test_job_spec_aggregator_registry_key():
    res = run_job(_job_spec(aggregator="fedavg", server_streaming_agg=True))
    for v in res["final_weights"].values():
        assert np.all(np.isfinite(np.asarray(v)))
    with pytest.raises(ValueError, match="unknown aggregator"):
        run_job(_job_spec(aggregator="krum"))


def test_streaming_rejects_legacy_ingress_filters():
    from repro.core.filters import two_way_quantization

    filters = two_way_quantization("nf4")
    with pytest.raises(ValueError, match="per-item pipeline"):
        FLSimulator(
            [_lsq_executor("s0", 0, W_TRUE)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=1),
            server_filters=filters,
            client_filters=filters,
            server_streaming_agg=True,
        )


def test_failed_batch_accept_leaves_no_phantom_weight():
    """A payload rejected mid-message must not register its sample
    weight: the shim folds items first and begins the contribution last,
    so a controller that skips the bad client still averages correctly."""
    from repro.core.quantization import quantize

    agg = FedAvgAggregator()
    agg.accept(_msg({"w": np.full(4, 2.0, np.float32)}, num_samples=1))
    bad = _msg({"w": quantize(np.ones(64, np.float32), "nf4")}, num_samples=99)
    with pytest.raises(TypeError, match="quantized item"):
        agg.accept(bad)
    agg.accept(_msg({"w": np.full(4, 4.0, np.float32)}, num_samples=1))
    assert agg.accepted == 2
    np.testing.assert_array_equal(agg.finish()["w"], np.full(4, 3.0, np.float32))


def test_sync_policy_mixed_batch_and_streamed_results_fold_once_each():
    """A fleet where only some proxies support stream_task: streamed
    clients fold at completion, batch clients fold at the barrier, and
    every contribution counts exactly once — in both rounds."""
    from repro.runtime import SyncPolicy

    agg = FedAvgAggregator()
    policy = SyncPolicy(agg, 2)
    dispatches = {d.client: d for d in
                  policy.begin({"w": np.zeros(4, np.float32)}, ["site-0", "site-1"])}

    def fake_deliver(payload, headers):
        def deliver(sink):
            w = sink.begin(headers)
            for name, value in payload.items():
                sink.accept_item(name, value, w)
            return Message(MessageKind.TASK_RESULT, {}, dict(headers))
        return deliver

    def run_round(rnd, dispatches):
        p0 = {"w": np.full(4, 2.0 + rnd, np.float32)}
        p1 = {"w": np.full(4, 6.0 + rnd, np.float32)}
        follow = policy.on_result_stream(
            dispatches["site-0"], {"num_samples": 1, "client": "site-0"},
            fake_deliver(p0, {"num_samples": 1}))
        assert follow == []
        follow = policy.on_result(
            dispatches["site-1"],
            _msg(p1, num_samples=3, client="site-1"))
        return {d.client: d for d in follow}

    next_dispatches = run_round(0, dispatches)
    # weighted mean of both contributions: (1*2 + 3*6) / 4 = 5
    np.testing.assert_array_equal(np.asarray(policy._weights["w"]),
                                  np.full(4, 5.0, np.float32))
    run_round(1, next_dispatches)  # _streamed reset: round 2 also exact
    np.testing.assert_array_equal(np.asarray(policy._weights["w"]),
                                  np.full(4, 6.0, np.float32))
    assert policy.complete


def test_retriever_sink_rejected_on_regular_mode_without_pipeline():
    retr = sm.ObjectRetriever()
    retr.register_container("w", {"a": np.ones(4, np.float32)})
    with pytest.raises(ValueError, match="container"):
        retr.retrieve("w", mode="regular", sink=FedAvgAggregator())


def test_build_aggregator_dict_spec_without_name_key_is_friendly():
    with pytest.raises(ValueError, match='"aggregator" name key'):
        build_aggregator({"buffer": 4})


def test_streaming_controller_rejects_pre_streaming_proxy_signature():
    from repro.fl.controller import ClientProxy

    class OldProxy(ClientProxy):
        name = "old"

        def submit_task(self, task):  # pre-streaming signature
            return task

    with pytest.raises(TypeError, match="result_sink"):
        ScatterAndGather([OldProxy()], FedAvgAggregator(), 1, streaming=True)
