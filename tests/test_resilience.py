"""Streaming resilience (paper §V): exact reassembly through lossy

transports — drops, duplicates, reordering — via the record-and-repair
transfer, with hypothesis sweeps over fault rates.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the fault-matrix tests still run
    from hypothesis_stub import given, settings, st

from repro.core import streaming as sm
from repro.core.resilience import LossyDriver, OrderedDeliveryBuffer, ReliableTransfer


def _sd(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": rng.standard_normal((128, 32)).astype(np.float32),
        "w1": rng.standard_normal((64, 64)).astype(np.float32),
        "w2": rng.standard_normal((32,)).astype(np.float32),
    }


def _assert_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_ordered_buffer_reorders_and_dedups():
    seen = []
    buf = OrderedDeliveryBuffer(lambda c: seen.append(c.seq))
    chunks = [sm.Chunk(b"x" * 16, i, b"p", sm.FLAG_EOF if i == 4 else 0) for i in range(5)]
    for c in (chunks[2], chunks[0], chunks[0], chunks[1], chunks[4], chunks[3]):
        buf.on_chunk(c)
    assert seen == [0, 1, 2, 3, 4]
    assert buf.complete and not buf.missing()


def test_missing_reports_gaps():
    buf = OrderedDeliveryBuffer(lambda c: None)
    buf.on_chunk(sm.Chunk(b"x" * 16, 0, b"p", 0))
    buf.on_chunk(sm.Chunk(b"x" * 16, 3, b"p", sm.FLAG_EOF))
    assert buf.missing() == {1, 2}


@pytest.mark.parametrize(
    "drop,dup,reorder", [(0.3, 0.0, 0), (0.0, 0.4, 0), (0.0, 0.0, 5), (0.25, 0.25, 4)])
def test_reliable_transfer_through_faults(drop, dup, reorder):
    sd = _sd()
    driver = LossyDriver(
        sm.LoopbackDriver(), drop_prob=drop, dup_prob=dup, reorder_window=reorder, seed=7
    )
    recv = sm.ContainerReceiver()
    xfer = ReliableTransfer(driver, chunk_size=256)
    ok = xfer.send_container(sd, recv)
    assert ok
    _assert_equal(sd, recv.result)
    if drop > 0:
        assert xfer.retransmits > 0


@settings(max_examples=15, deadline=None)
@given(
    drop=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_reliable_transfer_property(drop, seed):
    sd = _sd(seed % 5)
    driver = LossyDriver(sm.LoopbackDriver(), drop_prob=drop, seed=seed)
    recv = sm.ContainerReceiver()
    ok = ReliableTransfer(driver, chunk_size=512).send_container(sd, recv, max_rounds=60)
    assert ok
    _assert_equal(sd, recv.result)


def test_lossless_path_has_no_retransmits():
    sd = _sd(3)
    driver = LossyDriver(sm.LoopbackDriver(), seed=1)
    recv = sm.BlobReceiver()
    xfer = ReliableTransfer(driver, chunk_size=1024)
    assert xfer.send_container(sd, recv, mode="regular")
    assert xfer.retransmits == 0
    _assert_equal(sd, recv.result)
