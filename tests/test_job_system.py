"""Declarative job system: every example job spec runs end-to-end and the

configuration knobs (quantization fmt, EF, DP, fused server aggregation,
transmission) actually take effect.
"""
import glob
import os

import numpy as np
import pytest

from repro.fl.job import run_job, run_job_file

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BASE = {
    "arch": "llama3.2-1b",
    "smoke": True,
    "rounds": 3,
    "local_steps": 2,
    "clients": 2,
    "batch": 4,
    "seq": 32,
}


@pytest.mark.parametrize("path", sorted(glob.glob(os.path.join(ROOT, "examples", "jobs", "*.json"))))
def test_example_jobs_run(path):
    out = run_job_file(path)
    assert out["messages"] > 0 and out["wire_bytes"] > 0
    assert len(out["history"]) > 0
    assert np.isfinite(out["history"][-1])


def test_quantization_config_changes_wire_bytes():
    a = run_job({**BASE, "quantization": None})
    b = run_job({**BASE, "quantization": {"fmt": "nf4"}})
    assert b["wire_bytes"] < a["wire_bytes"] / 5.0  # ~7x smaller wire


def test_fused_server_aggregation_matches_plain():
    plain = run_job({**BASE, "quantization": {"fmt": "blockwise8"}, "seed": 3})
    fused = run_job(
        {**BASE, "quantization": {"fmt": "blockwise8"}, "server_quantized_aggregation": True, "seed": 3}
    )
    for k in plain["final_weights"]:
        np.testing.assert_allclose(
            np.asarray(plain["final_weights"][k], np.float32),
            np.asarray(fused["final_weights"][k], np.float32),
            rtol=2e-4,
            atol=2e-5,
        )


def test_dp_sigma_changes_result():
    a = run_job({**BASE, "seed": 1})
    b = run_job({**BASE, "dp_sigma": 0.01, "seed": 1})
    diffs = [
        float(np.max(np.abs(np.asarray(a["final_weights"][k], np.float32) - np.asarray(b["final_weights"][k], np.float32))))
        for k in a["final_weights"]
    ]
    assert max(diffs) > 1e-4  # noise visibly applied
