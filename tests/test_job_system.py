"""Declarative job system: every example job spec runs end-to-end and the

configuration knobs (quantization fmt, EF, DP, fused server aggregation,
transmission) actually take effect.
"""
import glob
import os

import numpy as np
import pytest

from repro.fl.job import run_job, run_job_file

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BASE = {
    "arch": "llama3.2-1b",
    "smoke": True,
    "rounds": 3,
    "local_steps": 2,
    "clients": 2,
    "batch": 4,
    "seq": 32,
}


@pytest.mark.parametrize(
    "path", sorted(glob.glob(os.path.join(ROOT, "examples", "jobs", "*.json"))))
def test_example_jobs_run(path):
    out = run_job_file(path)
    assert out["messages"] > 0 and out["wire_bytes"] > 0
    assert len(out["history"]) > 0
    assert np.isfinite(out["history"][-1])


def test_quantization_config_changes_wire_bytes():
    a = run_job({**BASE, "quantization": None})
    b = run_job({**BASE, "quantization": {"fmt": "nf4"}})
    assert b["wire_bytes"] < a["wire_bytes"] / 5.0  # ~7x smaller wire


def test_fused_server_aggregation_matches_plain():
    plain = run_job({**BASE, "quantization": {"fmt": "blockwise8"}, "seed": 3})
    fused = run_job(
        {**BASE, "quantization": {"fmt": "blockwise8"},
         "server_quantized_aggregation": True, "seed": 3}
    )
    for k in plain["final_weights"]:
        np.testing.assert_allclose(
            np.asarray(plain["final_weights"][k], np.float32),
            np.asarray(fused["final_weights"][k], np.float32),
            rtol=2e-4,
            atol=2e-5,
        )


def test_job_runtime_sync_matches_sequential():
    """Round-trip of the "runtime" spec keys: the sync policy through the
    declarative surface is bitwise-equal to the plain sequential job."""
    seq = run_job(dict(BASE))
    sync = run_job({**BASE, "runtime": {"policy": "sync"}})
    assert sync["policy"] == "sync" and sync["sim_time_s"] > 0
    for k in seq["final_weights"]:
        np.testing.assert_array_equal(
            np.asarray(seq["final_weights"][k]), np.asarray(sync["final_weights"][k])
        )


def test_job_runtime_matches_direct_construction():
    """run_job(spec) is exactly build_job(spec).run(): the declarative
    surface adds nothing over direct FLSimulator construction."""
    from repro.fl.job import build_job
    from repro.fl.simulator import FLSimulator
    from repro.runtime import FedAsyncPolicy

    spec = {**BASE, "runtime": {"policy": "fedasync", "total_tasks": 6,
                                "network": {"kind": "hetero", "tiers": ["fiber", "3g"]}}}
    via_run = run_job(spec)
    job = build_job(spec)
    assert isinstance(job.sim, FLSimulator)
    assert isinstance(job.sim.scheduler.policy, FedAsyncPolicy)
    direct = job.run()
    for k in via_run["final_weights"]:
        np.testing.assert_array_equal(
            np.asarray(via_run["final_weights"][k]), np.asarray(direct["final_weights"][k])
        )
    assert via_run["runtime_stats"] == direct["runtime_stats"]


def test_job_runtime_fedasync_completes_multi_round():
    out = run_job({**BASE, "runtime": {"policy": "fedasync", "total_tasks": 8,
                                       "mixing_rate": 0.5,
                                       "network": {"kind": "hetero"}}})
    assert out["policy"] == "fedasync"
    assert out["runtime_stats"]["model_updates"] == 8
    assert out["sim_time_s"] > 0 and np.isfinite(out["history"][-1])


def test_job_runtime_tiered_completes_multi_round():
    out = run_job({**BASE, "rounds": 4,
                   "runtime": {"policy": "tiered", "num_tiers": 2,
                               "network": {"kind": "hetero", "tiers": ["fiber", "3g"]}}})
    assert out["policy"] == "tiered"
    assert out["runtime_stats"]["model_updates"] == 4  # one per round barrier
    assert np.isfinite(out["history"][-1])


def test_job_runtime_availability_and_adaptive_quantization():
    out = run_job({**BASE,
                   "quantization": {"fmt": "adaptive", "budget_s": 1.0},
                   "runtime": {"policy": "fedbuff", "buffer_size": 2, "total_tasks": 6,
                               "network": {"profiles": {"site-0": "fiber", "site-1": "3g"},
                                           "compute_base_s": 0.5},
                               "availability": {"kind": "random", "mean_online_s": 60,
                                                "mean_offline_s": 20, "horizon_s": 300,
                                                "seed": 1}}})
    assert out["policy"] == "fedbuff"
    fmts = out["adaptive_fmts"]
    assert fmts["site-0"] != fmts["site-1"]  # precision tracked the link
    assert out["runtime_stats"]["completions"] == 6


def test_job_runtime_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown runtime policy"):
        run_job({**BASE, "runtime": {"policy": "carrier-pigeon"}})


def test_job_runtime_rejects_quantized_aggregation_with_async_policies():
    # fedbuff/fedasync bypass the aggregator and skip quantized payload
    # items, so this combination would silently train nothing
    with pytest.raises(ValueError, match="server_quantized_aggregation"):
        run_job({**BASE, "quantization": {"fmt": "blockwise8"},
                 "server_quantized_aggregation": True,
                 "runtime": {"policy": "fedasync", "total_tasks": 4}})


def test_job_rejects_quantized_aggregation_with_adaptive_precision():
    # clients on different links ship different formats; the fused
    # aggregator needs one uniform wire format
    with pytest.raises(ValueError, match="mixed formats"):
        run_job({**BASE, "quantization": {"fmt": "adaptive"},
                 "server_quantized_aggregation": True})


def test_dp_sigma_changes_result():
    a = run_job({**BASE, "seed": 1})
    b = run_job({**BASE, "dp_sigma": 0.01, "seed": 1})
    diffs = [
        float(np.max(np.abs(np.asarray(a["final_weights"][k], np.float32)
                            - np.asarray(b["final_weights"][k], np.float32))))
        for k in a["final_weights"]
    ]
    assert max(diffs) > 1e-4  # noise visibly applied
