"""Fault-tolerant live federation under injected chaos (ISSUE 10).

Every scenario runs the real :class:`FederationServer` over localhost
TCP with in-process :class:`FederationClient` threads, routing the
afflicted client through a :class:`ChaosProxy` — real sockets, real
protocol, deterministic byte-offset faults:

* a **stalled** uplink past ``straggler_grace_s`` closes the round over
  the contributors the server has (quorum mode), drains the late stream
  on the side, and re-invites the straggler in a later round;
* a **blackholed** connection reconnects with exponential backoff and
  rejoins — the poisoned fold restarts over the survivors first;
* a **corrupted** chunk (one flipped byte, caught by crc32/decode)
  quarantines the *client* and restarts the fold — the server survives;
* the server **checkpoint/resume** path reproduces the uninterrupted
  run's weights bitwise.

The equivalence oracle throughout: replaying the recorded per-round
contributor sets sequentially through the same wire pipelines must land
on the same bits as the live run, whatever faults shaped those sets.
Satellites: the handshake timeout sheds mute sockets, ``_reap``
escalates terminate→kill against one shared deadline, and the
ChaosProxy primitives themselves are pinned.
"""
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.core.resilience import ChaosProxy
from repro.checkpoint import latest_server_state, save_server_state
from repro.fl import TrainExecutor
from repro.fl.aggregator import build_aggregator
from repro.fl.controller import make_task
from repro.launch.federation import (
    FederationClient,
    FederationServer,
    _reap,
    _wire_roundtrip,
    aggregator_spec,
    build_pipelines_from_spec,
    live_spec,
    weights_bitwise_equal,
)

STACK = ["quantize:blockwise8", "crc32"]

# a payload big enough (16 KiB) that a fault offset of a few KiB lands
# mid-uplink-stream — after the hello and the result control frame
DIM = 4096
W_TRUE = np.arange(1, DIM + 1, dtype=np.float32) / DIM
INIT = {"w": np.zeros(DIM, np.float32)}


def _executor(name, seed, sleep_s=0.0, dim=DIM):
    w_true = W_TRUE[:dim]
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((64, dim)).astype(np.float32)
    y = X @ w_true

    def train_fn(params, rnd):
        if sleep_s:
            time.sleep(sleep_s)
        w = np.asarray(params["w"]).copy()
        for _ in range(2):
            w = w - 0.3 * (X.T @ (X @ w - y) / 64.0)
        return {"w": w}, 64, {}

    return TrainExecutor(name, train_fn)


def _spec(clients=3, rounds=3, stack=(), **over):
    spec = {"clients": clients, "rounds": rounds, "chunk_mb": 1,
            "pipeline": {"task_data": list(stack),
                         "task_result": list(stack)}}
    spec.update(over)
    return spec


def _launch(server, executors, addresses=None, **kwargs):
    """In-process clients on threads; ``addresses`` reroutes named
    clients (e.g. through a ChaosProxy). Returns (threads, errors)."""
    pipelines = build_pipelines_from_spec(server.spec)
    errors, threads = [], []
    for ex in executors:
        client = FederationClient(
            name=ex.name, executor=ex, pipelines=pipelines,
            address=(addresses or {}).get(ex.name, server.address),
            fingerprint=server.fingerprint, timeout_s=60.0, **kwargs,
        )

        def run(c=client):
            try:
                c.run()
            except Exception as exc:  # noqa: BLE001 - surfaced by the test
                errors.append(exc)

        t = threading.Thread(target=run, daemon=True, name=f"chaos-{ex.name}")
        t.start()
        threads.append(t)
    return threads, errors


def _join(threads, timeout=60):
    for t in threads:
        t.join(timeout=timeout)
        assert not t.is_alive(), "client thread wedged"


def _replay(spec, make_executors, rosters, init):
    """Sequential reference over recorded contributor sets: the same
    wire pipelines, executors, and fold order as the live server — the
    bitwise oracle for every chaos scenario (`reference_run` is this,
    for spec-built executors)."""
    spec = live_spec(spec)
    chunk = int(spec["chunk_mb"] * (1 << 20))
    pipelines = build_pipelines_from_spec(spec)
    executors = {ex.name: ex for ex in make_executors()}
    weights = dict(init)
    for rnd, roster in enumerate(rosters):
        agg = build_aggregator(aggregator_spec(spec))
        for name in roster:
            task = make_task(rnd, weights)
            task.headers.setdefault("client", name)
            task = _wire_roundtrip(pipelines["task_data"], task,
                                   MessageKind.TASK_DATA, chunk)
            result = executors[name].execute(task)
            msg = Message(result.kind, dict(result.payload),
                          dict(result.headers))
            _wire_roundtrip(pipelines["task_result"], msg,
                            MessageKind.TASK_RESULT, chunk, sink=agg)
        weights = agg.finish()
    return weights


# ---------------------------------------------------------------------------
# ChaosProxy primitives
# ---------------------------------------------------------------------------

def _echo_server():
    srv = socket.create_server(("127.0.0.1", 0))

    def serve(c):
        with c:
            while True:
                data = c.recv(1 << 16)
                if not data:
                    return
                c.sendall(data)

    def accept():
        while True:
            try:
                c, _ = srv.accept()
            except OSError:
                return
            threading.Thread(target=serve, args=(c,), daemon=True).start()

    threading.Thread(target=accept, daemon=True).start()
    return srv


def _roundtrip(addr, payload, want=None, timeout=10.0):
    want = len(payload) if want is None else want
    with socket.create_connection(addr, timeout=timeout) as s:
        s.settimeout(timeout)
        s.sendall(payload)
        got = b""
        while len(got) < want:
            data = s.recv(1 << 16)
            if not data:
                break
            got += data
    return got


def test_chaos_proxy_corrupt_flips_exactly_one_byte_then_runs_clean():
    srv = _echo_server()
    proxy = ChaosProxy(srv.getsockname(),
                       {"kind": "corrupt", "after_bytes": 100,
                        "xor": 0x01}).start()
    try:
        payload = bytes(range(256))
        got = _roundtrip(proxy.address, payload)
        assert len(got) == 256
        assert got[100] == payload[100] ^ 0x01
        assert got[:100] == payload[:100] and got[101:] == payload[101:]
        # triggers budget spent: the next connection forwards untouched
        assert _roundtrip(proxy.address, payload) == payload
        assert proxy.connections == 2 and proxy.triggered == 1
    finally:
        proxy.close()
        srv.close()


def test_chaos_proxy_stall_delays_losslessly():
    srv = _echo_server()
    proxy = ChaosProxy(srv.getsockname(),
                       {"kind": "stall", "after_bytes": 50,
                        "stall_s": 0.5}).start()
    try:
        payload = bytes(200)
        t0 = time.monotonic()
        got = _roundtrip(proxy.address, payload)
        assert time.monotonic() - t0 >= 0.4
        assert got == payload  # a straggler, not data loss
    finally:
        proxy.close()
        srv.close()


def test_chaos_proxy_blackhole_drops_the_connection():
    srv = _echo_server()
    proxy = ChaosProxy(srv.getsockname(),
                       {"kind": "blackhole", "after_bytes": 50}).start()
    try:
        got = _roundtrip(proxy.address, bytes(200), want=200)
        assert len(got) <= 50  # stream died mid-flight
    finally:
        proxy.close()
        srv.close()


def test_chaos_proxy_throttle_paces_the_stream():
    srv = _echo_server()
    proxy = ChaosProxy(srv.getsockname(),
                       {"kind": "throttle", "after_bytes": 0,
                        "bps": 200_000}).start()
    try:
        # several 64 KiB pump batches, so the per-batch pacing sleep is
        # felt by every batch after the first
        payload = bytes(200_000)
        t0 = time.monotonic()
        got = _roundtrip(proxy.address, payload)
        assert got == payload
        assert time.monotonic() - t0 >= 0.3  # ~200 KB at 200 KB/s
    finally:
        proxy.close()
        srv.close()


def test_chaos_proxy_seeded_offset_is_deterministic():
    a = ChaosProxy(("127.0.0.1", 1), {"kind": "stall", "seed": 7})
    b = ChaosProxy(("127.0.0.1", 1), {"kind": "stall", "seed": 7})
    c = ChaosProxy(("127.0.0.1", 1), {"kind": "stall", "seed": 8})
    try:
        assert a.plan["after_bytes"] == b.plan["after_bytes"]
        assert a.plan["after_bytes"] != c.plan["after_bytes"]
        assert (1 << 10) <= a.plan["after_bytes"] < (1 << 16)
    finally:
        a.close(), b.close(), c.close()


# ---------------------------------------------------------------------------
# scenario 1: straggler — quorum closes the round over the survivors
# ---------------------------------------------------------------------------

def test_straggler_quorum_round_finishes_with_survivors_bitwise():
    """site-2's uplink stalls past the grace: the round closes over
    site-0/site-1 (quorum 2 of 3), the late stream is drained and
    discarded, site-2 is re-invited once its socket is clean, and the
    final weights bitwise-match the sequential replay of exactly the
    contributor sets the server recorded."""
    spec = _spec(rounds=6, quorum=0.6, straggler_grace_s=0.6)

    def executors():
        return [_executor("site-0", 0, sleep_s=0.2),
                _executor("site-1", 1, sleep_s=0.2),
                _executor("site-2", 2)]

    server = FederationServer(spec, join_timeout_s=30).start()
    proxy = ChaosProxy(server.address,
                       {"kind": "stall", "after_bytes": 2000,
                        "stall_s": 1.2, "direction": "up"}).start()
    try:
        threads, errors = _launch(server, executors(),
                                  addresses={"site-2": proxy.address})
        live = server.run(dict(INIT))
        _join(threads)
        assert not errors
    finally:
        proxy.close()
        server.close()

    log = server.round_log
    assert log[0]["clients"] == ["site-0", "site-1"]
    assert log[0]["stragglers"] == ["site-2"]
    assert server.faults["stragglers"].get("site-2", 0) >= 1
    # drained straggler is re-invited once clean, not dropped for good
    assert any("site-2" in r["clients"] for r in log[1:])
    assert "site-2" not in server.faults["lost"]
    ref = _replay(spec, executors, [r["clients"] for r in log], INIT)
    assert weights_bitwise_equal(live, ref)


# ---------------------------------------------------------------------------
# scenario 2: blackhole — reconnect with backoff, rejoin, refold
# ---------------------------------------------------------------------------

def test_blackhole_reconnects_with_backoff_and_rejoins_bitwise():
    """site-2's socket dies mid-uplink: the poisoned fold restarts over
    the survivors, the client reconnects through backoff (the proxy's
    trigger budget is spent, so the retry path is clean), rejoins at the
    server's epoch, and contributes to later rounds."""
    spec = _spec(rounds=4)

    def executors():
        return [_executor(f"site-{i}", i, sleep_s=0.15) for i in range(3)]

    server = FederationServer(spec, join_timeout_s=30).start()
    proxy = ChaosProxy(server.address,
                       {"kind": "blackhole", "after_bytes": 2000,
                        "direction": "up"}).start()
    try:
        threads, errors = _launch(server, executors(),
                                  addresses={"site-2": proxy.address},
                                  max_reconnects=8, backoff_base_s=0.05)
        live = server.run(dict(INIT))
        _join(threads)
        assert not errors  # the client survived via reconnect
    finally:
        proxy.close()
        server.close()

    log = server.round_log
    assert log[0]["clients"] == ["site-0", "site-1"]
    assert server.restarts >= 1  # the poisoned fold was discarded
    assert server.faults["reconnects"].get("site-2", 0) >= 1
    assert "site-2" in log[-1]["clients"]
    ref = _replay(spec, executors, [r["clients"] for r in log], INIT)
    assert weights_bitwise_equal(live, ref)


# ---------------------------------------------------------------------------
# scenario 3: corrupt — crc32 quarantines the client, not the server
# ---------------------------------------------------------------------------

def test_corrupt_chunk_quarantines_client_and_the_fold_restarts():
    """One flipped byte in site-2's uplink payload: the integrity stage
    (crc32) rejects the item, the server quarantines site-2 and restarts
    the fold over the survivors — the decode error never kills the
    server, and the reconnecting client participates again later."""
    spec = _spec(rounds=4, stack=STACK)

    def executors():
        return [_executor(f"site-{i}", i, sleep_s=0.15) for i in range(3)]

    server = FederationServer(spec, join_timeout_s=30).start()
    proxy = ChaosProxy(server.address,
                       {"kind": "corrupt", "after_bytes": 2600,
                        "direction": "up"}).start()
    try:
        threads, errors = _launch(server, executors(),
                                  addresses={"site-2": proxy.address},
                                  max_reconnects=8, backoff_base_s=0.05)
        live = server.run(dict(INIT))
        _join(threads)
        assert not errors
    finally:
        proxy.close()
        server.close()

    log = server.round_log
    assert log[0]["clients"] == ["site-0", "site-1"]
    assert "site-2" in server.faults["quarantined"]
    assert server.restarts >= 1
    assert "site-2" in log[-1]["clients"]
    ref = _replay(spec, executors, [r["clients"] for r in log], INIT)
    assert weights_bitwise_equal(live, ref)


# ---------------------------------------------------------------------------
# scenario 4: checkpoint / resume — bitwise-identical restart
# ---------------------------------------------------------------------------

def test_checkpoint_resume_reproduces_uninterrupted_weights_bitwise(tmp_path):
    """Kill-and-resume equals never-killed: run rounds 0..1 with
    checkpointing, restart a fresh server with ``resume=True`` for the
    full schedule, and the final weights are bitwise-equal to one
    uninterrupted run — and to the sequential replay of the stitched
    round log that spans the restart."""
    def executors():
        return [_executor(f"site-{i}", i, dim=256) for i in range(3)]

    init = {"w": np.zeros(256, np.float32)}
    full = _spec(rounds=4, stack=STACK)

    # the uninterrupted reference
    ref_server = FederationServer(full, join_timeout_s=30).start()
    try:
        threads, errors = _launch(ref_server, executors())
        uninterrupted = ref_server.run(dict(init))
        _join(threads)
        assert not errors
    finally:
        ref_server.close()

    # the interrupted run: rounds 0..1, checkpointed...
    ckpt = str(tmp_path / "ckpt")
    first = FederationServer(_spec(rounds=2, stack=STACK), join_timeout_s=30,
                             checkpoint_dir=ckpt).start()
    try:
        threads, errors = _launch(first, executors())
        first.run(dict(init))
        _join(threads)
        assert not errors
    finally:
        first.close()

    # ...then a fresh server resumes at round 2 (fresh clients present
    # epoch 0 and are redirected to the restart epoch by the handshake)
    second = FederationServer(full, join_timeout_s=30,
                              checkpoint_dir=ckpt, resume=True).start()
    try:
        threads, errors = _launch(second, executors())
        resumed = second.run(dict(init))
        _join(threads)
        assert not errors
    finally:
        second.close()

    assert second.resumed_from == 1
    assert [r["round"] for r in second.round_log] == [0, 1, 2, 3]
    assert weights_bitwise_equal(resumed, uninterrupted)
    # one replay spans the restart: the restored round_log covers the
    # pre-crash rounds, so --verify-chaos works on resumed runs too
    ref = _replay(full, executors,
                  [r["clients"] for r in second.round_log], init)
    assert weights_bitwise_equal(resumed, ref)


def test_server_state_checkpoints_are_atomic_pruned_and_torn_tolerant(tmp_path):
    d = str(tmp_path)
    w = {"a.b": np.arange(6, dtype=np.float32), "c": np.ones(3, np.int32)}
    for rnd in range(5):
        save_server_state(d, rnd, w, meta={"roster": ["site-0"]}, keep=3)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == [  # pruned to the newest three complete pairs
        "round_000002.ckpt", "round_000002.json",
        "round_000003.ckpt", "round_000003.json",
        "round_000004.ckpt", "round_000004.json",
    ]
    # torn leftovers from a crash mid-write are skipped, not fatal
    (tmp_path / "round_000005.json").write_text("{not json")
    state = latest_server_state(d)
    assert state["round"] == 4
    assert state["meta"]["roster"] == ["site-0"]
    # weights load flat (dotted wire names intact), bitwise
    assert weights_bitwise_equal(state["weights"], w)


# ---------------------------------------------------------------------------
# satellites: handshake timeout, subprocess reaping
# ---------------------------------------------------------------------------

def test_mute_connection_is_shed_without_disturbing_the_round():
    spec = _spec(clients=2, rounds=2)
    server = FederationServer(spec, join_timeout_s=30,
                              handshake_timeout_s=0.3).start()
    try:
        mute = socket.create_connection(server.address)  # never says hello
        threads, errors = _launch(
            server, [_executor(f"site-{i}", i, sleep_s=0.2)
                     for i in range(2)])
        live = server.run(dict(INIT))
        _join(threads)
        assert not errors
        # the mute socket was closed by the server, not left holding an
        # accept thread hostage for round_timeout_s
        mute.settimeout(10.0)
        assert mute.recv(1) == b""
        mute.close()
    finally:
        server.close()
    assert server.faults["handshake_timeouts"] == 1
    assert [r["clients"] for r in server.round_log] == [
        ["site-0", "site-1"]] * 2
    assert live["w"].shape == (DIM,)


def test_reap_escalates_terminate_then_kill_against_one_deadline():
    quick = subprocess.Popen([sys.executable, "-c", "pass"])
    stuck = subprocess.Popen([
        sys.executable, "-c",
        "import signal, time\n"
        "signal.signal(signal.SIGTERM, signal.SIG_IGN)\n"
        "print('armed', flush=True)\n"
        "time.sleep(60)",
    ], stdout=subprocess.PIPE)
    stuck.stdout.readline()  # SIGTERM handler installed
    t0 = time.monotonic()
    codes = _reap([quick, stuck], 0.5)
    wall = time.monotonic() - t0
    stuck.stdout.close()
    assert codes[0] == 0
    # terminate() was ignored, so the second pass had to kill(); either
    # way the zombie is reaped and the exit code is real
    assert codes[1] is not None and codes[1] != 0
    assert quick.poll() is not None and stuck.poll() is not None
    assert wall < 15.0  # one shared deadline + one bounded kill window
