"""Fallback shim when ``hypothesis`` isn't installed.

Importing this instead of hypothesis lets a test module keep its
example-based tests runnable while every ``@given`` property test turns
into a clean skip (instead of the whole module dying at collection).

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_stub import given, settings, st
"""
from __future__ import annotations

import pytest


class _AnyStrategy:
    """Stands in for ``strategies``: every attribute is a no-op factory."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


st = _AnyStrategy()


def settings(*args, **kwargs):
    def decorate(fn):
        return fn

    return decorate


def given(*args, **kwargs):
    return pytest.mark.skip(reason="hypothesis not installed (see requirements-dev.txt)")
