"""Fused sLSTM scan kernel vs the model's lax.scan reference: identical

hidden-state trajectories across batch/seq/chunk/head sweeps (interpret
mode; compiled path is TPU-only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm
from repro.kernels.slstm_scan import slstm_scan_pallas


def _scan_reference(gx, r_tree, cfg):
    """Drive the model's _slstm_cell with the same hoisted gates."""
    Bsz, S, four, D = gx.shape
    p = {name: {"r": r_tree[i]} for i, name in enumerate(("z", "i", "f", "o"))}
    H, hd = ssm._slstm_dims(cfg)
    gx_named = {
        name: gx[:, :, i].reshape(Bsz, S, H, hd) for i, name in enumerate(("z", "i", "f", "o"))
    }
    gx_t = jax.tree_util.tree_map(lambda g: g.transpose(1, 0, 2, 3), gx_named)

    def step(state, gx_slice):
        new = ssm._slstm_cell(state, gx_slice, p, cfg)
        return new, new["h"]

    _, hs = jax.lax.scan(step, ssm.slstm_init_state(cfg, Bsz), gx_t)
    return hs.transpose(1, 0, 2, 3).reshape(Bsz, S, D)


def _inputs(cfg, B, S, seed=0):
    H, hd = ssm._slstm_dims(cfg)
    D = H * hd
    rng = np.random.default_rng(seed)
    gx = jnp.asarray(rng.standard_normal((B, S, 4, D)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((4, H, hd, hd)) * 0.05, jnp.float32)
    return gx, r


@pytest.mark.parametrize("B,S,chunk", [(2, 32, 8), (1, 64, 16), (3, 32, 32)])
def test_slstm_kernel_matches_scan(B, S, chunk):
    cfg = get_smoke_config("xlstm-125m")
    gx, r = _inputs(cfg, B, S, seed=B * S)
    got = slstm_scan_pallas(gx, r, num_heads=cfg.num_heads, chunk=chunk, interpret=True)
    want = _scan_reference(gx, r, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_slstm_kernel_state_resets_between_batch_rows():
    """Batch rows are independent: permuting rows permutes outputs."""
    cfg = get_smoke_config("xlstm-125m")
    gx, r = _inputs(cfg, 2, 32, seed=5)
    out = slstm_scan_pallas(gx, r, num_heads=cfg.num_heads, chunk=8, interpret=True)
    out_sw = slstm_scan_pallas(gx[::-1], r, num_heads=cfg.num_heads, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out_sw), np.asarray(out[::-1]), rtol=1e-5)


def test_slstm_kernel_bf16_input():
    cfg = get_smoke_config("xlstm-125m")
    gx, r = _inputs(cfg, 1, 32, seed=9)
    got = slstm_scan_pallas(
        gx.astype(jnp.bfloat16), r, num_heads=cfg.num_heads, chunk=8, interpret=True
    )
    want = _scan_reference(gx.astype(jnp.bfloat16).astype(jnp.float32), r, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)
