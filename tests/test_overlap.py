"""Compute/IO-overlapped wire hot path + backend selection (ISSUE 9).

The encode-ahead primitive (:func:`repro.core.streaming.iter_encode_ahead`)
buys overlap by running the encode iterator on a worker thread at a
bounded depth. Everything observable must stay exactly as in the
sequential loop: item order (stateful stages like ``delta`` depend on
it), wire bytes (bitwise), exception behavior, and the memory envelope
(queued items are live bytes). These tests pin each of those, plus:

* the sender-stall telemetry (``wire.encode_wait_us`` histogram and
  ``wire.encode_ahead_depth`` gauge) lands in the active registry;
* an in-process live federation with lookahead enabled on both
  directions still trains to weights bitwise-equal to the simulator;
* the ``kernel_backend`` job-spec key: validation, the scoped
  :func:`repro.kernels.ops.backend` override, and a full quantized
  federation that is bitwise-identical under ``ref`` and
  ``pallas_interpret`` — backends select an implementation, never a
  format.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import pipeline as pl
from repro.core import serialization as ser
from repro.core import streaming as sm
from repro.core.messages import Message, MessageKind
from repro.fl.job import kernel_backend_scope, normalize_spec, run_job
from repro.kernels import ops
from repro.obs import MetricsRegistry
from repro.obs import metrics as obs_metrics
from repro.utils.mem import MemoryMeter


def _views(payload: bytes) -> list[memoryview]:
    return [memoryview(payload)]


# ---------------------------------------------------------------------------
# iter_encode_ahead: order, bounds, errors, memory
# ---------------------------------------------------------------------------

def test_encode_ahead_preserves_items_and_order():
    items = [(f"t{i}", _views(bytes([i]) * 8)) for i in range(16)]
    for depth in (1, 2, 4, 32):
        got = list(sm.iter_encode_ahead(iter(items), depth))
        assert [n for n, _ in got] == [n for n, _ in items]
        assert [ser.join_views(v) for _, v in got] == \
            [ser.join_views(v) for _, v in items]


def test_encode_ahead_drives_source_strictly_in_order():
    """The worker advances the underlying iterator in item order — the
    contract stateful stages (delta, crc32 chains) rely on."""
    produced: list[int] = []

    def source():
        for i in range(12):
            produced.append(i)
            yield f"t{i}", _views(b"x" * 4)

    consumed = []
    for name, _ in sm.iter_encode_ahead(source(), depth=3):
        # at every observation point the production log is a prefix of
        # 0..n in order, never a permutation
        assert produced == sorted(produced)
        consumed.append(name)
    assert consumed == [f"t{i}" for i in range(12)]
    assert produced == list(range(12))


def test_encode_ahead_lookahead_is_bounded():
    """With the consumer parked, the worker encodes at most depth items
    plus the one blocked in ``put`` — not the whole stream."""
    produced = threading.Semaphore(0)
    n_produced = [0]

    def source():
        for i in range(64):
            n_produced[0] += 1
            produced.release()
            yield f"t{i}", _views(b"y" * 4)

    depth = 2
    it = sm.iter_encode_ahead(source(), depth)
    next(it)  # start the worker, take one item
    for _ in range(depth):
        assert produced.acquire(timeout=5.0)
    time.sleep(0.2)  # give an unbounded worker time to run away
    assert n_produced[0] <= depth + 2
    it.close()


def test_encode_ahead_reraises_source_exception():
    def source():
        yield "ok", _views(b"z" * 4)
        raise RuntimeError("encode stage blew up")

    it = sm.iter_encode_ahead(source(), depth=2)
    assert next(it)[0] == "ok"
    with pytest.raises(RuntimeError, match="encode stage blew up"):
        list(it)


def test_encode_ahead_abandon_stops_worker_and_frees_queue():
    """Closing the consumer mid-stream stops the pump promptly and
    releases every queued item's metered bytes."""
    meter = MemoryMeter()
    with meter.activate():
        it = sm.iter_encode_ahead(
            ((f"t{i}", _views(b"q" * 1024)) for i in range(1000)), depth=4)
        next(it)
        it.close()
    assert meter.live == 0
    assert meter.peak >= 1024
    # no lingering encode-ahead worker
    assert not [t for t in threading.enumerate()
                if t.name == "wire-encode-ahead" and t.is_alive()]


def test_encode_ahead_meters_queued_items_as_live_bytes():
    item = 1 << 16
    meter = MemoryMeter()
    with meter.activate():
        for _ in sm.iter_encode_ahead(
                ((f"t{i}", _views(b"m" * item)) for i in range(8)), depth=3):
            pass
    assert meter.live == 0
    # the queue held real lookahead at some point, and never more than
    # depth queued + 1 yielded + 1 in-flight
    assert item <= meter.peak <= 5 * item


def test_depth_zero_is_the_identity():
    items = [("a", _views(b"1")), ("b", _views(b"2"))]
    src = iter(items)
    assert sm.iter_encode_ahead(src, 0) is not src  # generator wrapper
    assert list(sm.iter_encode_ahead(iter(items), 0)) == items
    assert not [t for t in threading.enumerate()
                if t.name == "wire-encode-ahead" and t.is_alive()]


# ---------------------------------------------------------------------------
# wire bytes: lookahead reorders work, never bytes
# ---------------------------------------------------------------------------

def _sd(round_no: int = 0):
    rng = np.random.default_rng(7 + round_no)
    return {
        "embed.w": rng.standard_normal((64, 32)).astype(np.float32),
        "layers.0.attn.wq": rng.standard_normal((32, 32)).astype(np.float32),
        "layers.0.norm": rng.standard_normal((32,)).astype(np.float32),
    }


def _container_bytes(pipeline, prefetch: int, rounds: int = 2) -> bytes:
    sent = bytearray()

    class _Tap(sm.LoopbackDriver):
        def send(self, chunk):
            for seg in chunk.segments:
                sent.extend(seg)
            super().send(chunk)

    driver = _Tap()
    decoder = pipeline.decoder()
    recv = sm.ContainerReceiver(consume=lambda n, v: None,
                                decode_item=decoder.decode_item)
    driver.connect(recv.on_chunk)
    for rnd in range(rounds):
        msg = Message(MessageKind.TASK_RESULT, _sd(rnd),
                      {"round": rnd, "num_samples": 5})
        msg, ctx = pipeline.begin_encode(msg)
        sm.ContainerStreamer(driver, 4096, prefetch=prefetch).send_items(
            pipeline.iter_encode_views(msg, ctx), pipeline.n_items(msg))
    return bytes(sent)


@pytest.mark.parametrize("stack", [
    ["quantize:nf4", "zlib", "crc32"],
    ["quantize:nf4", "delta", "zlib", "crc32"],  # stateful across rounds
    [],
], ids=["nf4-zlib-crc32", "nf4-delta-zlib-crc32", "plain"])
def test_wire_bytes_bitwise_identical_with_prefetch(stack):
    baseline = _container_bytes(pl.build_pipeline(list(stack)), prefetch=0)
    for depth in (1, 2, 4):
        assert _container_bytes(pl.build_pipeline(list(stack)),
                                prefetch=depth) == baseline


def test_adaptive_encode_ahead_grows_only_under_observed_stalls():
    a = sm.AdaptiveEncodeAhead(depth=2, max_depth=5, grow_threshold=0.10)
    assert a.depth == 2
    a.observe(0.05, 1.0)  # 5% stall: the socket is the bottleneck
    assert a.depth == 2 and a.grown == 0
    for _ in range(10):
        a.observe(0.5, 1.0)  # encoder-bound transfers: +1 each, capped
    assert a.depth == 5 and a.grown == 3
    a.observe(1.0, 0.0)  # degenerate wall time: ignored
    assert a.depth == 5
    assert sm.AdaptiveEncodeAhead().depth == sm.DEFAULT_ENCODE_AHEAD


def test_adaptive_encode_ahead_publishes_depth_gauge():
    reg = MetricsRegistry()
    a = sm.AdaptiveEncodeAhead(depth=3)
    with obs_metrics.activate(reg):
        a.observe(1.0, 1.0)
    assert a.depth == 4
    assert reg.gauge("wire.encode_ahead_depth").as_value() == 4


def test_adaptive_prefetch_wire_bytes_bitwise_identical():
    """An AdaptiveEncodeAhead controller re-reads its depth per transfer
    and feeds stalls back — and whatever depth it lands on, the wire
    bytes stay bitwise-identical to the sequential loop."""
    stack = ["quantize:nf4", "zlib", "crc32"]
    baseline = _container_bytes(pl.build_pipeline(list(stack)), prefetch=0)
    # threshold 0 forces growth after every transfer, so the rounds in
    # one capture run at different depths — bytes must not care
    adaptive = sm.AdaptiveEncodeAhead(depth=1, grow_threshold=0.0)
    got = _container_bytes(pl.build_pipeline(list(stack)), prefetch=adaptive)
    assert got == baseline
    assert adaptive.grown >= 1


def test_delta_stage_decodes_correctly_under_lookahead():
    """Two delta rounds (snapshot, then residual) through a prefetching
    streamer decode back to the exact original tensors."""
    p = pl.build_pipeline(["quantize:fp16", "delta", "crc32"])
    decoded: dict[int, dict[str, np.ndarray]] = {}
    for rnd in range(2):
        decoder = p.decoder()
        got: dict[str, np.ndarray] = {}

        def consume(name, value, _got=got):
            if name != pl.META_ITEM:
                _got[name] = np.asarray(value)

        driver = sm.LoopbackDriver()
        recv = sm.ContainerReceiver(consume=consume,
                                    decode_item=decoder.decode_item)
        driver.connect(recv.on_chunk)
        msg = Message(MessageKind.TASK_RESULT, _sd(rnd),
                      {"round": rnd, "num_samples": 5})
        msg, ctx = p.begin_encode(msg)
        sm.ContainerStreamer(driver, 4096, prefetch=3).send_items(
            p.iter_encode_views(msg, ctx), p.n_items(msg))
        decoded[rnd] = got
    for rnd in range(2):
        want = {k: v.astype(np.float16).astype(np.float32)
                for k, v in _sd(rnd).items()}
        assert set(decoded[rnd]) == set(want)
        for k in want:
            np.testing.assert_array_equal(decoded[rnd][k], want[k])


# ---------------------------------------------------------------------------
# telemetry: stall histogram + depth gauge
# ---------------------------------------------------------------------------

def test_encode_wait_telemetry_lands_in_active_registry():
    reg = MetricsRegistry()
    with obs_metrics.activate(reg):
        _container_bytes(pl.build_pipeline(["quantize:nf4", "crc32"]),
                         prefetch=2, rounds=1)
    hist = reg.histogram("wire.encode_wait_us").as_value()
    assert hist["count"] > 0
    assert reg.gauge("wire.encode_ahead_depth").as_value() == 2


def test_no_telemetry_without_active_registry():
    reg = MetricsRegistry()  # never activated
    _container_bytes(pl.build_pipeline(["crc32"]), prefetch=2, rounds=1)
    assert reg.histogram("wire.encode_wait_us").as_value()["count"] == 0


def test_metrics_activate_restores_previous_registry():
    outer, inner = MetricsRegistry(), MetricsRegistry()
    assert obs_metrics.active() is None
    with obs_metrics.activate(outer):
        assert obs_metrics.active() is outer
        with obs_metrics.activate(inner):
            assert obs_metrics.active() is inner
        assert obs_metrics.active() is outer
        with pytest.raises(RuntimeError):
            with obs_metrics.activate(inner):
                raise RuntimeError("boom")
        assert obs_metrics.active() is outer
    assert obs_metrics.active() is None


# ---------------------------------------------------------------------------
# backend selection: scoped override + job-spec key
# ---------------------------------------------------------------------------

def test_ops_backend_scope_restores_on_exit_and_exception():
    before = ops.get_backend()
    with ops.backend("pallas_interpret"):
        assert ops.get_backend() == "pallas_interpret"
        with ops.backend("ref"):
            assert ops.get_backend() == "ref"
        assert ops.get_backend() == "pallas_interpret"
    assert ops.get_backend() == before
    with pytest.raises(ValueError, match="carrier-pigeon"):
        with ops.backend("carrier-pigeon"):
            pass  # pragma: no cover - never entered
    assert ops.get_backend() == before
    with pytest.raises(RuntimeError):
        with ops.backend("ref"):
            raise RuntimeError("boom")
    assert ops.get_backend() == before


def test_job_spec_validates_kernel_backend():
    assert normalize_spec({})["kernel_backend"] is None
    for kb in ops.BACKENDS:
        assert normalize_spec({"kernel_backend": kb})["kernel_backend"] == kb
    with pytest.raises(ValueError, match="kernel_backend"):
        normalize_spec({"kernel_backend": "cuda"})


def test_kernel_backend_scope_helper():
    before = ops.get_backend()
    with kernel_backend_scope({"kernel_backend": "pallas_interpret"}):
        assert ops.get_backend() == "pallas_interpret"
    assert ops.get_backend() == before
    with kernel_backend_scope({"kernel_backend": None}):  # nullcontext
        assert ops.get_backend() == before


@pytest.mark.slow
def test_quantized_federation_bitwise_identical_across_backends():
    """The whole point of the backend knob: ref and pallas_interpret run
    the same federation to bitwise-identical weights, so a job spec can
    flip implementations without changing results (or wire bytes)."""
    base = {
        "arch": "llama3.2-1b", "smoke": True, "rounds": 2, "clients": 2,
        "local_steps": 1, "batch": 4, "seq": 32,
        "pipeline": {"task_result_out": ["quantize:nf4", "crc32"]},
        "server_streaming_agg": True,
    }
    ref = run_job({**base, "kernel_backend": "ref"})
    pi = run_job({**base, "kernel_backend": "pallas_interpret"})
    assert set(ref["final_weights"]) == set(pi["final_weights"])
    for k in ref["final_weights"]:
        np.testing.assert_array_equal(np.asarray(ref["final_weights"][k]),
                                      np.asarray(pi["final_weights"][k]))


# ---------------------------------------------------------------------------
# live federation with lookahead on both directions
# ---------------------------------------------------------------------------

def test_live_federation_bitwise_matches_sim_with_prefetch(monkeypatch):
    """Raise the encode-ahead depth on the live plane's downlink and
    uplink streamers and train a real TCP federation: weights must stay
    bitwise-equal to the sequential simulator (lookahead reorders work,
    never bytes, so the fold arithmetic is untouched)."""
    from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, \
        TrainExecutor
    from repro.launch.federation import FederationServer, FederationClient, \
        build_pipelines_from_spec, weights_bitwise_equal

    monkeypatch.setattr(sm, "DEFAULT_ENCODE_AHEAD", 3)

    w_true = np.arange(1, 9, dtype=np.float32) / 8.0

    def lsq(name, seed):
        rng = np.random.default_rng(seed)
        X = rng.standard_normal((64, 8)).astype(np.float32)
        y = X @ w_true

        def train_fn(params, rnd):
            w = np.asarray(params["w"]).copy()
            for _ in range(3):
                w = w - 0.3 * (X.T @ (X @ w - y) / 64)
            return {"w": w}, 64, {}

        return TrainExecutor(name, train_fn)

    stack = ["quantize:blockwise8", "crc32"]
    spec = {"clients": 2, "rounds": 2, "chunk_mb": 1,
            "pipeline": {"task_data": list(stack),
                         "task_result": list(stack)}}
    init = {"w": np.zeros(8, np.float32)}

    server = FederationServer(spec, join_timeout_s=30).start()
    try:
        pipelines = build_pipelines_from_spec(server.spec)
        errors: list[Exception] = []
        threads = []
        for i in range(2):
            client = FederationClient(
                name=f"site-{i}", executor=lsq(f"site-{i}", i),
                pipelines=pipelines, address=server.address,
                fingerprint=server.fingerprint, timeout_s=60.0)

            def run(c=client):
                try:
                    c.run()
                except Exception as exc:  # noqa: BLE001 - surfaced below
                    errors.append(exc)

            t = threading.Thread(target=run, daemon=True)
            t.start()
            threads.append(t)
        live = server.run(dict(init))
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()
        assert not errors
    finally:
        server.close()

    sim = FLSimulator(
        [lsq(f"site-{i}", i) for i in range(2)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=2, transmission="container"),
        pipelines={"task_data": list(stack), "task_result": list(stack)},
        server_streaming_agg=True,
    )
    assert weights_bitwise_equal(live, sim.run(dict(init)))
