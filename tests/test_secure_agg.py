"""Secure aggregation: individual payloads look uniform to the server;

the aggregate matches plain FedAvg to fixed-point resolution; composes
with the FL simulator and DP filters (paper §V compatibility claims).
"""
import numpy as np
import pytest

from repro.core.messages import Message, MessageKind
from repro.core.pipeline import DPNoiseStage, SecureMaskStage, WirePipeline
from repro.core.secure_agg import MOD, SCALE, SecureAggregator, SecureMaskFilter
from repro.fl import FLSimulator, SimulationConfig, TrainExecutor


def _msg(payload, rnd=0, n=1):
    return Message(MessageKind.TASK_RESULT, payload, {"round": rnd, "num_samples": n})


def test_masks_cancel_exactly():
    rng = np.random.default_rng(0)
    clients = [0, 1, 2]
    xs = [rng.standard_normal((257,)).astype(np.float32) for _ in clients]
    agg = SecureAggregator(num_clients=3)
    for i in clients:
        masked = SecureMaskFilter(i, clients, base_seed=42).process(_msg({"w": xs[i]}))
        assert masked.payload["w"].dtype == np.uint32
        agg.accept(masked)
    out = agg.finish()
    want = np.mean(xs, axis=0)
    np.testing.assert_allclose(out["w"], want, atol=3.0 / SCALE)


def test_individual_payloads_look_uniform():
    """A masked tensor must be statistically indistinguishable from

    uniform mod 2^32 (mean ~ MOD/2, high entropy) even for a constant
    input."""
    x = np.zeros(4096, np.float32)
    masked = SecureMaskFilter(0, [0, 1], base_seed=7).process(_msg({"w": x}))
    g = masked.payload["w"].astype(np.float64)
    assert abs(g.mean() / float(MOD) - 0.5) < 0.02
    assert g.std() / float(MOD) > 0.25  # uniform std is ~0.289


def test_missing_client_fails_closed():
    agg = SecureAggregator(num_clients=3)
    m = SecureMaskFilter(0, [0, 1, 2]).process(_msg({"w": np.ones(8, np.float32)}))
    agg.accept(m)
    with pytest.raises(RuntimeError):
        agg.finish()


def test_secure_agg_through_simulator_with_dp():
    """Full stack: DP noise -> pairwise masking -> streamed wire ->

    SecureAggregator; federation average equals the DP-noised average.
    The DP and masking transforms run as per-item pipeline stages inside
    the streaming loop (client-specific -> install per-proxy uplinks)."""
    clients = [0, 1, 2]
    rng = np.random.default_rng(1)
    locals_ = [rng.standard_normal((64,)).astype(np.float32) for _ in clients]

    def make_exec(i):
        def train_fn(params, rnd):
            return {"w": locals_[i]}, 1, {}

        return TrainExecutor(f"site-{i}", train_fn)

    executors = [make_exec(i) for i in clients]
    sim = FLSimulator(
        executors,
        SecureAggregator(num_clients=3),
        SimulationConfig(num_rounds=1, transmission="container", chunk_size=512),
    )
    for i, proxy in enumerate(sim.controller.clients):
        proxy.pipelines = {
            **proxy.pipelines,
            "task_result": WirePipeline(
                [DPNoiseStage(sigma=0.001, seed=i), SecureMaskStage(i, clients)]
            ),
        }
    final = sim.run({"w": np.zeros(64, np.float32)})
    want = np.mean(locals_, axis=0)
    np.testing.assert_allclose(final["w"], want, atol=0.01)
