"""Model-level flash-attention routing: with the kernel backend forced to

pallas-interpret, the dense model's forward pass must route through the
flash kernel and produce the same logits as the jnp softmax path.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.kernels import ops as kops
from repro.models import create_model


def test_model_forward_matches_between_attention_backends():
    cfg = get_smoke_config("granite-8b").with_overrides(remat=False)
    model = create_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)), jnp.int32)

    kops.set_backend("ref")
    try:
        logits_ref, _ = model.forward(params, tokens)
        kops.set_backend("pallas_interpret")
        logits_flash, _ = model.forward(params, tokens)
    finally:
        kops.set_backend("auto")

    np.testing.assert_allclose(
        np.asarray(logits_flash, np.float32),
        np.asarray(logits_ref, np.float32),
        rtol=5e-3,
        atol=5e-3,
    )


def test_swa_model_routes_window_through_flash():
    cfg = get_smoke_config("granite-8b").with_overrides(remat=False, sliding_window=128)
    model = create_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 256)), jnp.int32)
    kops.set_backend("ref")
    try:
        l_ref, _ = model.forward(params, tokens)
        kops.set_backend("pallas_interpret")
        l_flash, _ = model.forward(params, tokens)
    finally:
        kops.set_backend("auto")
    np.testing.assert_allclose(
        np.asarray(l_flash, np.float32), np.asarray(l_ref, np.float32), rtol=5e-3, atol=5e-3
    )
