"""Loop-aware HLO analyzer validation: per-device FLOPs derived from the

compiled module must match analytic einsum counts, scale with scan trip
count, and agree between scanned and unrolled programs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import create_model
from repro.utils import hlo as H


def _compiled_fwd(L, remat=False):
    cfg = get_smoke_config("granite-8b").with_overrides(num_layers=L, remat=remat)
    model = create_model(cfg)
    p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((2, 32), jnp.int32)

    def loss(params, tokens):
        logits, _ = model.forward(params, tokens)
        return jnp.sum(logits.astype(jnp.float32))

    return jax.jit(loss).lower(p, toks).compile(), cfg


def _analytic_fwd_flops(cfg, B=2, S=32):
    d, f = cfg.d_model, cfg.d_ff
    qf, kvf = cfg.q_feat, cfg.kv_feat
    H_, hd = cfg.num_heads, cfg.resolved_head_dim
    proj = 2 * B * S * (d * qf + 2 * d * kvf + qf * d)
    attn = 2 * B * S * S * H_ * hd * 2
    mlp = 2 * B * S * 3 * d * f
    head = 2 * B * S * d * cfg.vocab_size
    return (proj + attn + mlp) * cfg.num_layers + head


@pytest.mark.parametrize("L", [2, 4])
def test_flops_match_analytic(L):
    compiled, cfg = _compiled_fwd(L)
    got = H.module_flops(compiled.as_text())
    want = _analytic_fwd_flops(cfg)
    assert abs(got - want) / want < 0.01, (got, want)


def test_flops_scale_with_trip_count():
    """cost_analysis() counts scan bodies once; our analyzer must not."""
    c2, _ = _compiled_fwd(2)
    c4, _ = _compiled_fwd(4)
    f2 = H.module_flops(c2.as_text())
    f4 = H.module_flops(c4.as_text())
    # per-layer flops constant => (f4 - head) == 2*(f2 - head)
    head = 2 * 2 * 32 * 256 * 512
    np.testing.assert_allclose(f4 - head, 2 * (f2 - head), rtol=0.01)
    # and the XLA number is trip-count-blind (documents why we parse HLO)
    ca2 = c2.cost_analysis()
    ca4 = c4.cost_analysis()
    ca2 = ca2[0] if isinstance(ca2, (list, tuple)) else ca2
    ca4 = ca4[0] if isinstance(ca4, (list, tuple)) else ca4
    if ca2.get("flops") and ca4.get("flops"):
        assert ca2["flops"] == ca4["flops"]


def test_traffic_scales_with_depth():
    c2, _ = _compiled_fwd(2)
    c4, _ = _compiled_fwd(4)
    t2 = H.module_traffic_bytes(c2.as_text())
    t4 = H.module_traffic_bytes(c4.as_text())
    assert 1.5 < t4 / t2 < 3.0  # grows roughly linearly in depth


def test_collective_parsing_explicit_groups():
    txt = """
ENTRY %main (p: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  ROOT %ar = f32[16,1024]{1,0} all-reduce(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}
"""
    stats = H.collective_stats(txt)
    assert stats["all-reduce"]["count"] == 1
    size = 16 * 1024 * 4
    np.testing.assert_allclose(stats["all-reduce"]["wire_bytes"], 2 * size * 3 / 4)


def test_collective_parsing_iota_groups_and_loops():
    txt = """
%body (t: (s32[], f32[128])) -> (s32[], f32[128]) {
  %t = (s32[], f32[128]) parameter(0)
  %g = f32[128]{0} get-tuple-element(%t), index=1
  %ag = f32[128]{0} all-gather(%g), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %r = (s32[], f32[128]) tuple(%g, %ag)
}
%cond (t: (s32[], f32[128])) -> pred[] {
  %t = (s32[], f32[128]) parameter(0)
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%c, %c), direction=LT
}
ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %w = (s32[], f32[128]) while(%p), condition=%cond, body=%body,\
 backend_config={"known_trip_count":{"n":"10"}}
  ROOT %o = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    stats = H.collective_stats(txt)
    assert stats["all-gather"]["count"] == 10  # multiplied by trip count
    size = 128 * 4
    np.testing.assert_allclose(
        stats["all-gather"]["wire_bytes"], 10 * size * 7 / 8
    )
