"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracle,

swept over shapes and dtypes, plus hypothesis property tests on the codec
invariants (round-trip error bounds, scale invariance, sign preservation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the sweep tests below still run
    from hypothesis_stub import given, settings, st

from repro.kernels import ref
from repro.kernels.quant_blockwise8 import (
    BLOCK8,
    ROWS,
    dequantize_blockwise8_pallas,
    quantize_blockwise8_pallas,
)
from repro.kernels.quant_nf4 import (
    BLOCK4,
    ROWS4,
    dequantize_4bit_pallas,
    quantize_4bit_pallas,
)
from repro.kernels.fused_dequant_agg import dequant_accumulate8_pallas
from repro.core import quantization as Q


def _rand(shape, dtype, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=dtype)


# ---------------------------------------------------------------------------
# blockwise8 kernel vs ref, shape/dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nblocks", [ROWS, 2 * ROWS, 5 * ROWS])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_quantize_blockwise8_matches_ref(nblocks, dtype):
    x = _rand((nblocks, BLOCK8), dtype, seed=nblocks)
    q_k, am_k = quantize_blockwise8_pallas(x, interpret=True)
    q_r, am_r = ref.quantize_blockwise8(x)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(am_k), np.asarray(am_r), rtol=1e-6)


@pytest.mark.parametrize("nblocks", [ROWS, 3 * ROWS])
def test_dequantize_blockwise8_matches_ref(nblocks):
    x = _rand((nblocks, BLOCK8), jnp.float32, seed=7)
    q, am = ref.quantize_blockwise8(x)
    out_k = dequantize_blockwise8_pallas(q, am, interpret=True)
    out_r = ref.dequantize_blockwise8(q, am)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


# ---------------------------------------------------------------------------
# 4-bit kernels vs ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp4", "nf4"])
@pytest.mark.parametrize("nblocks", [ROWS4, 2 * ROWS4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_4bit_matches_ref(fmt, nblocks, dtype):
    x = _rand((nblocks, BLOCK4), dtype, seed=nblocks + len(fmt))
    code = ref.FP4_CODE if fmt == "fp4" else ref.NF4_CODE
    p_k, am_k = quantize_4bit_pallas(x, fmt=fmt, interpret=True)
    p_r, am_r = ref.quantize_4bit(x, code)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(am_k), np.asarray(am_r), rtol=1e-6)


@pytest.mark.parametrize("fmt", ["fp4", "nf4"])
def test_dequantize_4bit_matches_ref(fmt):
    x = _rand((ROWS4, BLOCK4), jnp.float32, seed=11)
    code = ref.FP4_CODE if fmt == "fp4" else ref.NF4_CODE
    p, am = ref.quantize_4bit(x, code)
    out_k = dequantize_4bit_pallas(p, am, fmt=fmt, interpret=True)
    out_r = ref.dequantize_4bit(p, am, code)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


# ---------------------------------------------------------------------------
# fused dequant+accumulate vs ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4])
def test_dequant_accumulate_matches_ref(k):
    rng = np.random.default_rng(k)
    x = jnp.asarray(rng.standard_normal((k, 2 * ROWS, BLOCK8)), jnp.float32)
    qs, ams = jax.vmap(ref.quantize_blockwise8)(x)
    w = jnp.asarray(rng.random(k), jnp.float32)
    out_k = dequant_accumulate8_pallas(qs, ams, w, interpret=True)
    out_r = ref.dequant_accumulate8(qs, ams, w)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_fused_agg_equals_dequant_then_average():
    """The fused kernel must equal dequantize-each-then-weighted-sum."""
    rng = np.random.default_rng(3)
    k = 3
    x = jnp.asarray(rng.standard_normal((k, ROWS, BLOCK8)), jnp.float32)
    qs, ams = jax.vmap(ref.quantize_blockwise8)(x)
    w = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
    fused = ref.dequant_accumulate8(qs, ams, w)
    seq = sum(w[i] * ref.dequantize_blockwise8(qs[i], ams[i]) for i in range(k))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# codec round-trip properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9000),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_blockwise8_roundtrip_error_bound(n, scale, seed):
    """|x - dq(q(x))| <= absmax/254 per block (half a quantization step)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    qt = Q.quantize(x, "blockwise8")
    out = Q.dequantize(qt)
    assert out.shape == x.shape and out.dtype == x.dtype
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 0.5 + 1e-7
    assert float(jnp.max(jnp.abs(out - x))) <= bound * 1.000001


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=4000),
    seed=st.integers(min_value=0, max_value=2**16),
    fmt=st.sampled_from(["fp4", "nf4"]),
)
def test_4bit_roundtrip_bounded_by_codebook_gap(n, seed, fmt):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    qt = Q.quantize(x, fmt)
    out = Q.dequantize(qt)
    assert out.shape == x.shape
    code = np.sort(ref.FP4_CODE if fmt == "fp4" else ref.NF4_CODE)
    max_gap = float(np.max(np.diff(code)))  # worst normalized quantization gap
    # per-block error <= absmax * max_gap / 2; bound globally by global absmax
    bound = float(jnp.max(jnp.abs(x))) * max_gap / 2.0 + 1e-7
    assert float(jnp.max(jnp.abs(out - x))) <= bound * 1.0000001


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_quantize_scale_invariance(seed):
    """Blockwise codes are invariant to positive per-block rescaling

    (up to one ulp-induced code step at round-to-nearest boundaries)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((ROWS, BLOCK8)), jnp.float32)
    q1, _ = ref.quantize_blockwise8(x)
    q2, _ = ref.quantize_blockwise8(x * 37.5)
    diff = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
    assert diff.max() <= 1
    # at most a vanishing fraction of codes sit exactly on a boundary
    assert (diff != 0).mean() < 1e-3


def test_zero_block_roundtrip():
    x = jnp.zeros((5, 17), jnp.float32)
    for fmt in ("blockwise8", "fp4", "nf4", "fp16", "bf16"):
        out = Q.dequantize(Q.quantize(x, fmt))
        np.testing.assert_array_equal(np.asarray(out), np.zeros((5, 17), np.float32))


@pytest.mark.parametrize("fmt", ["fp16", "bf16", "blockwise8", "fp4", "nf4"])
@pytest.mark.parametrize("shape", [(3,), (130,), (7, 513), (2, 3, 65)])
def test_codec_shapes_and_dtypes(fmt, shape):
    x = _rand(shape, jnp.float32, seed=sum(shape))
    qt = Q.quantize(x, fmt)
    out = Q.dequantize(qt)
    assert out.shape == x.shape
    assert out.dtype == x.dtype


def _llama32_1b_shapes():
    """The exact 147-tensor layout of paper Table I (Llama-3.2-1B)."""

    class _Fake:
        def __init__(self, *shape):
            self.shape = shape

    sd = {
        "embed_tokens": _Fake(128256, 2048),
        "norm": _Fake(2048),
        "lm_head": _Fake(128256, 2048),
    }
    for i in range(16):
        sd[f"layers.{i}.self_attn.q_proj"] = _Fake(2048, 2048)
        sd[f"layers.{i}.self_attn.k_proj"] = _Fake(512, 2048)
        sd[f"layers.{i}.self_attn.v_proj"] = _Fake(512, 2048)
        sd[f"layers.{i}.self_attn.o_proj"] = _Fake(2048, 2048)
        sd[f"layers.{i}.mlp.gate_proj"] = _Fake(8192, 2048)
        sd[f"layers.{i}.mlp.up_proj"] = _Fake(8192, 2048)
        sd[f"layers.{i}.mlp.down_proj"] = _Fake(2048, 8192)
        sd[f"layers.{i}.input_layernorm"] = _Fake(2048)
        sd[f"layers.{i}.post_attention_layernorm"] = _Fake(2048)
    return sd


def test_table2_percentages_match_paper():
    """Paper Table II: fp32 5716.26 MB; 16-bit 50.00 %; 8-bit 25.03 %

    (meta 1.54 MB); 4-bit 14.06 % (meta 89.33 MB)."""
    sd = _llama32_1b_shapes()
    assert len(sd) == 147  # Table I: 147 layers
    r32 = Q.message_size_report(sd, "fp32")
    r16 = Q.message_size_report(sd, "fp16")
    r8 = Q.message_size_report(sd, "blockwise8")
    r4 = Q.message_size_report(sd, "nf4")
    assert abs(r32["model_mb"] - 5716.26) < 1.0
    assert abs(r16["fp32_pct"] - 50.0) < 1e-6
    assert abs(r8["fp32_pct"] - 25.03) < 0.01
    assert abs(r4["fp32_pct"] - 14.06) < 0.01
    assert abs(r8["meta_mb"] - 1.54) < 0.02    # paper: 1.54 MB
    assert abs(r4["meta_mb"] - 89.33) < 0.05   # paper: 89.33 MB
