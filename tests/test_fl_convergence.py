"""Multi-client FL convergence with non-IID data and quantized messages —

the paper's §V "extensive multi-client evaluations ... with non-IID
data", in miniature: 4 clients on Dirichlet-partitioned Markov chains,
two-way blockwise8 quantization, container streaming, real runtime. The
global model must converge on ALL clients' distributions (not just one),
and quantized FL must track unquantized FL.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.filters import no_filters, two_way_quantization
from repro.data import dirichlet_partition
from repro.fl import FedAvgAggregator, FLSimulator, SimulationConfig, TrainExecutor
from repro.models import create_model
from repro.optim import adamw_init, adamw_update
from repro.utils.trees import flatten_state_dict, unflatten_state_dict

ROUNDS, LOCAL_STEPS, BATCH, SEQ = 8, 4, 8, 64


def _cfg():
    return get_smoke_config("llama3.2-1b").with_overrides(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256
    )


def _run_federation(fmt, num_clients=4, seed=0):
    cfg = _cfg()
    model = create_model(cfg)
    datasets = dirichlet_partition(cfg.vocab_size, SEQ, num_clients, alpha=0.3, seed=seed)
    assert len({d._mode for d in datasets}) > 1  # genuinely non-IID

    @jax.jit
    def local_step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, jnp.float32(3e-3))
        return params, opt, loss

    def make_client(name, data):
        def train_fn(flat_params, rnd):
            p = unflatten_state_dict(
                {k: jnp.asarray(np.asarray(v)) for k, v in flat_params.items()})
            opt = adamw_init(p)
            loss = None
            for _ in range(LOCAL_STEPS):
                batch = {k: jnp.asarray(v) for k, v in data.sample(BATCH).items()}
                p, opt, loss = local_step(p, opt, batch)
            return flatten_state_dict(p), BATCH * LOCAL_STEPS, {"loss": float(loss)}

        return TrainExecutor(name, train_fn)

    filters = two_way_quantization(fmt) if fmt else no_filters()
    sim = FLSimulator(
        [make_client(f"site-{i}", d) for i, d in enumerate(datasets)],
        FedAvgAggregator(),
        SimulationConfig(num_rounds=ROUNDS, transmission="container"),
        server_filters=filters,
        client_filters=filters,
    )
    init = flatten_state_dict(model.init(jax.random.PRNGKey(seed)))
    final_flat = sim.run(init)
    final = unflatten_state_dict({k: jnp.asarray(np.asarray(v)) for k, v in final_flat.items()})

    # evaluate the GLOBAL model on every client's distribution
    losses = []
    for d in datasets:
        batch = {k: jnp.asarray(v) for k, v in d.sample(16).items()}
        loss, _ = model.loss(final, batch)
        losses.append(float(loss))
    return losses


@pytest.mark.slow
def test_multiclient_noniid_global_convergence():
    losses = _run_federation("blockwise8")
    # initial loss ~ ln(256) = 5.55; the global model must clearly beat it
    # on EVERY client's (distinct) distribution within 8 rounds
    assert max(losses) < 4.6, losses


@pytest.mark.slow
def test_quantized_fl_tracks_unquantized_multiclient():
    l_q = _run_federation("blockwise8")
    l_f = _run_federation(None)
    assert abs(np.mean(l_q) - np.mean(l_f)) < 0.3, (l_q, l_f)
