"""FL runtime: the four filter points, two-way quantization workflow,

FedAvg (incremental + fused-quantized), and end-to-end federated
convergence on a toy task — the paper's Fig. 4/5 claims in miniature.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filters import (
    DequantizeFilter,
    DPGaussianNoiseFilter,
    FilterChain,
    QuantizeFilter,
    no_filters,
    two_way_quantization,
)
from repro.core.messages import Message, MessageKind
from repro.core.quantization import QuantizedTensor
from repro.fl import (
    FedAvgAggregator,
    FLSimulator,
    QuantizedFedAvgAggregator,
    SimulationConfig,
    TrainExecutor,
)


def _msg(payload, **headers):
    return Message(MessageKind.TASK_RESULT, payload, headers)


# ---------------------------------------------------------------------------
# filters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp16", "blockwise8", "fp4", "nf4"])
def test_quantize_dequantize_filter_roundtrip(fmt):
    rng = np.random.default_rng(0)
    payload = {
        "w": rng.standard_normal((65, 33)).astype(np.float32),
        "step": np.asarray(7, np.int32),  # non-float passes through
    }
    m = _msg(dict(payload))
    q = QuantizeFilter(fmt).process(m)
    assert isinstance(q.payload["w"], QuantizedTensor)
    assert q.payload["step"] is payload["step"]
    assert q.headers["quantized_fmt"] == fmt
    out = DequantizeFilter().process(q)
    assert out.payload["w"].shape == (65, 33)
    assert "quantized_fmt" not in out.headers
    # worst-case error = absmax * max_codebook_gap / 2 (~0.17 * absmax for
    # fp4, ~0.13 for nf4); absmax of a (65,33) standard normal is ~4
    tol = {"fp16": 1e-3, "blockwise8": 0.03, "fp4": 0.9, "nf4": 0.6}[fmt]
    np.testing.assert_allclose(np.asarray(out.payload["w"]), payload["w"], atol=tol)


def test_quantized_message_is_smaller():
    payload = {"w": np.zeros((4096, 64), np.float32)}
    base = _msg(dict(payload)).payload_bytes()
    for fmt, factor in [("fp16", 2.0), ("blockwise8", 3.9), ("nf4", 7.0)]:
        q = QuantizeFilter(fmt).process(_msg(dict(payload)))
        assert q.payload_bytes() * factor <= base + 1


def test_dp_filter_composes_with_quantization():
    rng = np.random.default_rng(1)
    payload = {"w": rng.standard_normal((256,)).astype(np.float32)}
    chain = FilterChain([DPGaussianNoiseFilter(sigma=0.1, seed=2), QuantizeFilter("blockwise8")])
    out = chain.process(_msg(dict(payload)))
    assert isinstance(out.payload["w"], QuantizedTensor)
    rec = DequantizeFilter().process(out).payload["w"]
    diff = np.asarray(rec) - payload["w"]
    assert 0.01 < float(np.std(diff)) < 0.3  # noise present but bounded


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------

def test_fedavg_weighted_average():
    agg = FedAvgAggregator()
    agg.accept(_msg({"w": np.full((4,), 1.0, np.float32)}, num_samples=1))
    agg.accept(_msg({"w": np.full((4,), 4.0, np.float32)}, num_samples=3))
    out = agg.finish()
    np.testing.assert_allclose(out["w"], np.full((4,), (1 + 12) / 4.0))


def test_fedavg_rejects_quantized_payload():
    agg = FedAvgAggregator()
    q = QuantizeFilter("blockwise8").process(_msg({"w": np.ones((8,), np.float32)}))
    with pytest.raises(TypeError):
        agg.accept(_msg(q.payload, num_samples=1))


def test_quantized_fedavg_matches_dequant_then_average():
    rng = np.random.default_rng(3)
    ws = [rng.standard_normal((1000,)).astype(np.float32) for _ in range(3)]
    samples = [10, 20, 30]

    qagg = QuantizedFedAvgAggregator()
    ref_agg = FedAvgAggregator()
    for w, n in zip(ws, samples):
        qm = QuantizeFilter("blockwise8").process(
            _msg({"w": w, "bias": np.float32([1.0])}, num_samples=n))
        qm.headers["num_samples"] = n
        qagg.accept(qm)
        dm = DequantizeFilter().process(qm)
        ref_agg.accept(dm)
    out_q = qagg.finish()
    out_r = ref_agg.finish()
    np.testing.assert_allclose(out_q["w"], out_r["w"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out_q["bias"], out_r["bias"], rtol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end federation on a toy least-squares task
# ---------------------------------------------------------------------------

def _make_lsq_executor(name, seed, w_true, n=256, lr=0.3, local_steps=4):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, w_true.size)).astype(np.float32)
    y = X @ w_true

    def train_fn(params, rnd):
        w = jnp.asarray(np.asarray(params["w"]).copy())
        for _ in range(local_steps):
            grad = X.T @ (X @ w - y) / n
            w = w - lr * grad
        return {"w": np.asarray(w)}, n, {"loss": float(np.mean((X @ np.asarray(w) - y) ** 2))}

    return TrainExecutor(name, train_fn)


def _run_sim(fmt, transmission="container", num_rounds=12, num_clients=3):
    w_true = np.arange(1, 9, dtype=np.float32) / 8.0
    executors = [_make_lsq_executor(f"site-{i}", i, w_true) for i in range(num_clients)]
    filters = two_way_quantization(fmt) if fmt else no_filters()
    sim = FLSimulator(
        executors,
        FedAvgAggregator(),
        SimulationConfig(num_rounds=num_rounds, transmission=transmission, chunk_size=4096),
        server_filters=filters,
        client_filters=filters,
    )
    final = sim.run({"w": np.zeros(8, np.float32)})
    return np.asarray(final["w"]), w_true, sim


def test_fl_converges_unquantized():
    w, w_true, _ = _run_sim(None)
    np.testing.assert_allclose(w, w_true, atol=1e-3)


@pytest.mark.parametrize("fmt", ["fp16", "blockwise8", "nf4"])
def test_fl_converges_with_two_way_quantization(fmt):
    """Paper Fig. 5: quantized-message FL tracks unquantized convergence.

    4-bit weight transmission has an irreducible error floor of
    ~absmax * max_gap / 2 per round (paper's curves show the same loss
    jitter); we assert convergence to that neighborhood.
    """
    w, w_true, _ = _run_sim(fmt)
    tol = {"fp16": 5e-3, "blockwise8": 2e-2, "nf4": 0.15}[fmt]
    assert float(np.max(np.abs(w - w_true))) < tol


@pytest.mark.parametrize("transmission", ["regular", "container"])
def test_fl_transmission_modes_agree(transmission):
    w, w_true, sim = _run_sim("blockwise8", transmission=transmission, num_rounds=5)
    assert sim.stats.messages == 2 * 3 * 5  # 2 hops x clients x rounds
    assert sim.stats.bytes_sent > 0


def test_quantization_reduces_wire_bytes():
    """On a realistically-sized payload the wire bytes shrink ~4x (int8)

    and ~8x (nf4) vs fp32, matching paper Table II ratios."""
    rng = np.random.default_rng(0)
    big = {"w": rng.standard_normal((1 << 20,)).astype(np.float32)}  # 4 MiB

    def train_fn(params, rnd):
        return {k: np.asarray(v) for k, v in params.items()}, 1, {}

    def run(fmt):
        filters = two_way_quantization(fmt) if fmt else no_filters()
        sim = FLSimulator(
            [TrainExecutor("s0", train_fn)],
            FedAvgAggregator(),
            SimulationConfig(num_rounds=1),
            server_filters=filters,
            client_filters=filters,
        )
        sim.run(dict(big))
        return sim.stats.bytes_sent

    b32, b8, b4 = run(None), run("blockwise8"), run("nf4")
    assert b32 / 4.1 < b8 < b32 / 3.9
    assert b32 / 8.2 < b4 < b32 / 7.0


def test_tcp_driver_federation():
    w_true = np.arange(1, 5, dtype=np.float32)
    executors = [_make_lsq_executor("site-0", 0, w_true)]
    sim = FLSimulator(
        executors,
        FedAvgAggregator(),
        SimulationConfig(num_rounds=10, transmission="container", driver="tcp", chunk_size=1024),
        server_filters=two_way_quantization("fp16"),
        client_filters=two_way_quantization("fp16"),
    )
    final = sim.run({"w": np.zeros(4, np.float32)})
    np.testing.assert_allclose(np.asarray(final["w"]), w_true, atol=1e-2)
